"""Hypothesis property tests for the serving subsystem's foundational
invariant: bucket padding (drop-id edges + isolated nodes) leaves the
logits over real nodes unchanged — for all four reduces (sum / mean /
max / segment_softmax) at 1e-5, under the same kernel config, on the
pallas path the engine serves."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — property tests skipped (CI installs it)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ops as geot
from repro.core.config_space import KernelConfig
from repro.core.mp import mp
from repro.core.plan import make_graph_plan
from repro.data.graphs import pad_graph, synth_graph, unpad_edges, unpad_nodes
from repro.models import gnn

SET = settings(max_examples=12, deadline=None)
CFG = KernelConfig("SR", 64, 128, 64, 1)


@st.composite
def padded_problem(draw):
    v = draw(st.integers(3, 70))
    e = draw(st.integers(0, 200))
    seed = draw(st.integers(0, 2 ** 16))
    g = synth_graph("prop", v, e, feat=draw(st.integers(1, 9)), seed=seed)
    v_pad = draw(st.integers(v, 2 * v + 8))
    e_pad = draw(st.integers(e, 2 * e + 8))
    return g, pad_graph(g, v_pad, e_pad)


def _plans(g, p):
    return (make_graph_plan(g.edge_index, g.num_nodes, config=CFG),
            make_graph_plan(p.edge_index, p.num_nodes, config=CFG))


@SET
@given(padded_problem(), st.sampled_from(["sum", "mean", "max"]))
def test_padding_invariance_mp(problem, reduce):
    g, p = problem
    plan, plan_p = _plans(g, p)
    want = mp(jnp.asarray(g.x), jnp.asarray(g.edge_index), g.num_nodes,
              reduce=reduce, plan=plan, impl="pallas")
    got = mp(jnp.asarray(p.x), jnp.asarray(p.edge_index), p.num_nodes,
             reduce=reduce, plan=plan_p, impl="pallas")
    np.testing.assert_allclose(unpad_nodes(p, got), want,
                               rtol=1e-5, atol=1e-5)


@SET
@given(padded_problem())
def test_padding_invariance_softmax(problem):
    g, p = problem
    if g.num_edges == 0:
        return
    plan, plan_p = _plans(g, p)
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((g.num_edges, 2)).astype(np.float32)
    pad = np.zeros((p.num_edges - g.num_edges, 2), np.float32)
    want = geot.segment_softmax(jnp.asarray(logits),
                                jnp.asarray(g.edge_index[1]), g.num_nodes,
                                "pallas", None, plan)
    got = geot.segment_softmax(jnp.asarray(np.concatenate([logits, pad])),
                               jnp.asarray(p.edge_index[1]), p.num_nodes,
                               "pallas", None, plan_p)
    np.testing.assert_allclose(unpad_edges(p, got), want,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(padded_problem(), st.sampled_from(list(gnn.MODELS)))
def test_padding_invariance_model_forward(problem, model):
    """End-to-end: a whole padded model forward (every reduce the family
    uses, plus the dense layers) agrees on the real rows."""
    import jax
    g, p = problem
    heads = 2 if model == "gat" else 1
    params = gnn.init(jax.random.PRNGKey(0), model, g.x.shape[1], 8, 3,
                      heads=heads)
    plan, plan_p = _plans(g, p)
    want = gnn.forward(params, model, jnp.asarray(g.x),
                       jnp.asarray(g.edge_index), g.num_nodes,
                       jnp.asarray(g.deg_inv_sqrt), impl="pallas", plan=plan)
    got = gnn.forward(params, model, jnp.asarray(p.x),
                      jnp.asarray(p.edge_index), p.num_nodes,
                      jnp.asarray(p.deg_inv_sqrt), impl="pallas", plan=plan_p)
    np.testing.assert_allclose(unpad_nodes(p, got), want,
                               rtol=1e-5, atol=1e-5)
