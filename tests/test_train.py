"""Training orchestration (ISSUE 7): provider determinism, the Task
protocol, trainer compile discipline (one trace per shape bucket),
TrainState checkpoint round-trips, kill-and-resume trajectory identity,
and fault-tolerant replay through ``fit``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.data.graphs import synth_typed_graph
from repro.checkpoint import checkpoint as ckpt
from repro.models import gnn
from repro.optim import adamw
from repro.train import (DatasetProvider, GraphEpochProvider, LMStatic,
                         LMTask, NodeClassification, Task, TokenProvider,
                         Trainer, TrainerConfig, TrainState, fit)

SHAPES = ((48, 192), (64, 256))


def mk_trainer(model="gcn", steps=12, impl="ref", typed=False, shapes=SHAPES,
               ckpt_dir=None, ckpt_every=3, lr=1e-2, seed=0, **cfg_kw):
    data = GraphEpochProvider(shapes=shapes, graphs_per_shape=2, feat=16,
                              num_classes=8, typed=typed, num_relations=3,
                              seed=seed)
    task = NodeClassification.from_provider(data, model=model, hidden=32,
                                            impl=impl)
    cfg = TrainerConfig(steps=steps, warmup_steps=2,
                        opt=adamw.AdamWConfig(lr=lr, weight_decay=0.0),
                        seed=seed, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                        **cfg_kw)
    return Trainer(task, data, cfg)


# ---------------------------------------------------------------------------
# providers
# ---------------------------------------------------------------------------

def test_provider_deterministic_and_cyclic():
    data = GraphEpochProvider(shapes=SHAPES, graphs_per_shape=2, feat=8,
                              num_classes=4)
    assert isinstance(data, DatasetProvider)
    assert len(data) == 4
    # same step -> the SAME object (plan memo persists across steps)
    assert data.batch(1) is data.batch(1)
    assert data.batch(1) is data.batch(1 + len(data))
    assert data.batch(0) is not data.batch(1)


def test_provider_batching_and_guards():
    data = GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=4,
                              graphs_per_batch=2, feat=8, num_classes=4)
    assert len(data) == 2
    g = data.batch(0)
    assert g.num_graphs == 2 and g.num_nodes == 64
    with pytest.raises(ValueError, match="typed"):
        GraphEpochProvider(typed=True, graphs_per_batch=2,
                           graphs_per_shape=2)
    with pytest.raises(ValueError, match="multiple"):
        GraphEpochProvider(graphs_per_shape=3, graphs_per_batch=2)


def test_token_provider_wraps_synthetic_tokens():
    from repro.data.tokens import TokenDatasetConfig
    data = TokenProvider(TokenDatasetConfig(128, 16, 4))
    a, b = data.batch(3), data.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)


# ---------------------------------------------------------------------------
# task protocol + plan canonicalization
# ---------------------------------------------------------------------------

def test_task_protocol_structural():
    t = NodeClassification()
    assert isinstance(t, Task)
    assert isinstance(LMTask(cfg=None), Task)


def test_prepare_same_bucket_same_treedef():
    """Two different graphs of one shape must produce arrays with the
    SAME pytree treedef — the canonicalized plan aux is what keeps the
    jitted step from retracing."""
    data = GraphEpochProvider(shapes=((48, 192),), graphs_per_shape=2,
                              feat=16, num_classes=8)
    task = NodeClassification.from_provider(data, model="gcn", hidden=32)
    a0, s0 = task.prepare(data.batch(0))
    a1, s1 = task.prepare(data.batch(1))
    assert s0 == s1
    assert (jax.tree_util.tree_structure(a0)
            == jax.tree_util.tree_structure(a1))
    # and the leaves still differ (each graph keeps its own chunk metadata)
    assert a0["plan"].max_chunks == a1["plan"].max_chunks
    assert a0["plan"].config == a1["plan"].config


def test_prepare_model_graph_family_mismatch():
    data = GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=1,
                              feat=8, num_classes=4)
    task = NodeClassification.from_provider(data, model="rgcn")
    with pytest.raises(ValueError, match="disagree"):
        task.prepare(data.batch(0))


def test_explicit_plan_is_authoritative():
    data = GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=1,
                              feat=8, num_classes=4)
    g = data.batch(0)
    task = NodeClassification.from_provider(data, model="gcn", hidden=16)
    myplan = g.make_plan(task.plan_feat)
    arrays, _ = task.prepare(g, plan=myplan)
    assert arrays["plan"] is myplan


def test_gnn_loss_fn_accepts_typed_kwargs():
    """Satellite: models.gnn.loss_fn carries the same typed surface as
    forward (edge_type + permutation triple + rplan)."""
    g = synth_typed_graph("t", 40, 160, num_relations=3, feat=8,
                          num_classes=4, seed=0)
    params = gnn.init(jax.random.PRNGKey(0), "rgcn", 8, 16, 4,
                      num_relations=3)
    loss = gnn.loss_fn(
        params, "rgcn", jnp.asarray(g.x), jnp.asarray(g.edge_index),
        jnp.asarray(g.labels), g.num_nodes, jnp.asarray(g.deg_inv_sqrt),
        edge_type=jnp.asarray(g.edge_type),
        type_perm=jnp.asarray(g.type_perm),
        inv_type_perm=jnp.asarray(g.inv_type_perm),
        type_counts=jnp.asarray(g.type_counts))
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# trainer: compile discipline + loss behaviour
# ---------------------------------------------------------------------------

def test_fit_one_trace_per_bucket_and_loss_decreases():
    t = mk_trainer(steps=16, impl="pallas", lr=1e-2)
    res = t.fit()
    assert res.losses[-1] < res.losses[0]
    assert res.traces == len(res.buckets) == len(SHAPES)
    # a second fit on the warm trainer compiles nothing new
    res2 = t.fit()
    assert res2.traces == res.traces
    assert len(res.losses) == 16


def test_typed_training_one_trace():
    t = mk_trainer(model="rgcn", typed=True, shapes=((48, 192),), steps=10)
    res = t.fit()
    assert res.losses[-1] < res.losses[0]
    assert res.traces == len(res.buckets) == 1
    assert res.buckets[0].typed


def test_fit_functional_entry_point():
    data = GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=1,
                              feat=8, num_classes=4)
    task = NodeClassification.from_provider(data, model="gin", hidden=16,
                                            impl="ref")
    res = fit(task, data, TrainerConfig(steps=4, warmup_steps=1))
    assert len(res.losses) == 4 and np.isfinite(res.losses).all()
    assert repro.fit is fit


def test_metrics_include_accuracy_and_optimizer():
    seen = {}

    def cb(step, metrics, verdict):
        seen.update(metrics)

    mk_trainer(steps=2).fit(metrics_cb=cb)
    for k in ("loss", "accuracy", "grad_norm", "lr"):
        assert k in seen, k


# ---------------------------------------------------------------------------
# checkpoint round-trip + kill-and-resume
# ---------------------------------------------------------------------------

def test_trainstate_checkpoint_roundtrip(tmp_path):
    """The full GNN TrainState (params + AdamW moments + step + PRNG key)
    survives save/restore bitwise."""
    t = mk_trainer(steps=4, ckpt_dir=str(tmp_path))
    res = t.fit()
    state = res.state
    ckpt.save(state, tmp_path / "rt", 4)
    restored = ckpt.restore(t.init_state(), tmp_path / "rt", step=4)
    assert isinstance(restored, TrainState)
    leaves_a = jax.tree_util.tree_leaves(state)
    leaves_b = jax.tree_util.tree_leaves(restored)
    assert len(leaves_a) == len(leaves_b)
    for a, b in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(restored.step) == 4


def test_kill_and_resume_identical_trajectory(tmp_path):
    """A run killed mid-flight and resumed from its checkpoint produces a
    loss trajectory identical to the uninterrupted run (deterministic
    step-indexed data + checkpointed PRNG key)."""
    full = mk_trainer(steps=12).fit()

    class Killed(Exception):
        pass

    def killer(step, metrics, verdict):
        if step == 7:
            raise Killed()          # not in ResilientLoop's catch list

    t_part = mk_trainer(steps=12, ckpt_dir=str(tmp_path), ckpt_every=3)
    with pytest.raises(Killed):
        t_part.fit(metrics_cb=killer)
    assert ckpt.latest_step(tmp_path) == 6

    res = mk_trainer(steps=12, ckpt_dir=str(tmp_path),
                     ckpt_every=3).fit(resume=True)
    assert res.start_step == 6
    assert len(res.losses) == 6
    np.testing.assert_allclose(res.losses, full.losses[6:], atol=1e-6)
    # deterministic replay is in fact bitwise on CPU
    assert res.losses == full.losses[6:]


def test_resume_flag_validation(tmp_path):
    t = mk_trainer(steps=2)
    with pytest.raises(ValueError, match="ckpt_dir"):
        t.fit(resume=True)
    t2 = mk_trainer(steps=2, ckpt_dir=str(tmp_path))
    with pytest.raises(ValueError, match="not both"):
        t2.fit(resume=True, state=t2.init_state())
    # resume over an empty directory is a cold start, not an error
    res = t2.fit(resume=True)
    assert res.start_step == 0 and len(res.losses) == 2


def test_fault_tolerant_replay_inside_fit(tmp_path):
    """A failure the ResilientLoop *can* handle (RuntimeError) restores
    the newest checkpoint at-or-before the failed step and replays to the
    clean run's exact trajectory."""
    clean = mk_trainer(steps=10).fit()

    fired = {"done": False}

    def faulty(step, metrics, verdict):
        if step == 5 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected")

    t = mk_trainer(steps=10, ckpt_dir=str(tmp_path), ckpt_every=2)
    res = t.fit(metrics_cb=faulty)
    assert res.losses == clean.losses
    assert any(e[0] == "failure" for e in res.events)
    assert ("restored", 4) in res.events


# ---------------------------------------------------------------------------
# sharded + LM paths
# ---------------------------------------------------------------------------

def test_sharded_training_matches_single_device():
    from repro.core.dist_mp import make_shard_mesh
    mesh = make_shard_mesh(1)
    t_single = mk_trainer(steps=3, impl="pallas")
    t_shard = mk_trainer(steps=3, impl="pallas")
    t_shard.mesh = mesh
    r1 = t_single.fit()
    r2 = t_shard.fit()
    assert r2.buckets[0].shards == 1
    np.testing.assert_allclose(r2.losses, r1.losses, rtol=1e-5, atol=1e-5)


def test_typed_sharded_raises():
    from repro.core.dist_mp import make_shard_mesh
    data = GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=1,
                              feat=8, num_classes=4, typed=True,
                              num_relations=2)
    task = NodeClassification.from_provider(data, model="rgcn", hidden=16)
    with pytest.raises(NotImplementedError):
        task.prepare(data.batch(0), mesh=make_shard_mesh(1))


def test_lm_task_generic_path():
    from repro.data.tokens import TokenDatasetConfig
    from repro.models.config import ModelConfig
    cfg = ModelConfig("t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=128, dtype="float32", max_seq=64)
    task = LMTask(cfg)
    data = TokenProvider(TokenDatasetConfig(128, 16, 4))
    res = fit(task, data, TrainerConfig(steps=3, warmup_steps=1))
    assert len(res.losses) == 3 and np.isfinite(res.losses).all()
    assert res.buckets == (LMStatic(4, 16),)
    assert res.traces == 1
