"""Pallas kernels vs pure-jnp oracles (interpret=True): shape/dtype sweeps
per kernel, as required for every kernel in kernels/."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config_space import KernelConfig
from repro.kernels import ops as kops, ref

RNG = np.random.default_rng(7)

SHAPES = [(260, 40, 17), (1000, 100, 32), (64, 64, 1), (512, 3, 130),
          (130, 128, 64)]
DTYPES = [np.float32, jnp.bfloat16]
SCHEDS = ["PR", "SR"]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("m,s,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("sched", SCHEDS)
def test_segment_reduce_kernel(m, s, n, dtype, sched):
    idx = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    x = jnp.asarray(RNG.standard_normal((m, n)), dtype)
    cfg = KernelConfig(sched, 64, 128, 128, 8)
    got = kops.segment_reduce(x, jnp.asarray(idx), s, "sum", cfg,
                              interpret=True)
    want = ref.segment_reduce(x.astype(jnp.float32), jnp.asarray(idx), s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("reduce", ["mean", "max"])
def test_segment_reduce_kernel_mean_max(reduce):
    m, s, n = 300, 37, 24
    idx = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    x = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    got = kops.segment_reduce(x, jnp.asarray(idx), s, reduce,
                              KernelConfig("SR", 64, 128, 64, 1),
                              interpret=True)
    want = ref.segment_reduce(x, jnp.asarray(idx), s, reduce)
    ga, wa = np.asarray(got), np.asarray(want)
    mask = np.isfinite(wa)
    assert np.array_equal(np.isfinite(ga), mask)
    np.testing.assert_allclose(ga[mask], wa[mask], rtol=3e-4, atol=3e-4)


def test_segment_reduce_kernel_empty_segments():
    """Gapped ids: many empty segments between occupied ones."""
    m, s = 200, 500
    idx = np.sort(RNG.choice(np.arange(0, s, 7), m)).astype(np.int32)
    x = jnp.asarray(RNG.standard_normal((m, 16)), jnp.float32)
    for sched in SCHEDS:
        got = kops.segment_reduce(x, jnp.asarray(idx), s, "sum",
                                  KernelConfig(sched, 64, 128, 64, 8),
                                  interpret=True)
        want = ref.segment_reduce(x, jnp.asarray(idx), s)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("sched", SCHEDS)
def test_gather_segment_reduce_kernel(reduce, weighted, sched):
    """Every reduce × weighted combo is a single fused launch (PR + max
    falls back to the SR walk inside the kernel)."""
    m, v, s, n = 400, 90, 60, 20
    seg = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    gidx = RNG.integers(0, v, m).astype(np.int32)
    w = jnp.asarray(RNG.standard_normal(m), jnp.float32) if weighted else None
    h = jnp.asarray(RNG.standard_normal((v, n)), jnp.float32)
    cfg = KernelConfig(sched, 64, 128, 128, 8)
    got = kops.gather_segment_reduce(h, jnp.asarray(gidx), jnp.asarray(seg),
                                     s, weight=w, reduce=reduce, config=cfg,
                                     interpret=True)
    want = ref.gather_segment_reduce(h, jnp.asarray(gidx), jnp.asarray(seg),
                                     s, weight=w, reduce=reduce)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_gather_segment_reduce_kernel_mean_max_gapped():
    """Gapped/empty segments: mean divides only live segments (empty → 0),
    max keeps the segment_max identity (-inf) on empty ones."""
    m, v, s, n = 200, 50, 500, 12
    seg = np.sort(RNG.choice(np.arange(0, s, 7), m)).astype(np.int32)
    gidx = RNG.integers(0, v, m).astype(np.int32)
    h = jnp.asarray(RNG.standard_normal((v, n)), jnp.float32)
    cfg = KernelConfig("SR", 64, 128, 64, 1)
    for reduce in ("mean", "max"):
        got = kops.gather_segment_reduce(h, jnp.asarray(gidx),
                                         jnp.asarray(seg), s, reduce=reduce,
                                         config=cfg, interpret=True)
        want = ref.gather_segment_reduce(h, jnp.asarray(gidx),
                                         jnp.asarray(seg), s, reduce=reduce)
        ga, wa = np.asarray(got), np.asarray(want)
        mask = np.isfinite(wa)
        assert np.array_equal(np.isfinite(ga), mask)
        np.testing.assert_allclose(ga[mask], wa[mask], rtol=3e-4, atol=3e-4)


def test_gather_segment_reduce_rejects_unknown_reduce():
    h = jnp.zeros((4, 8))
    idx = jnp.zeros(4, jnp.int32)
    with pytest.raises(ValueError):
        kops.gather_segment_reduce(h, idx, idx, 4, reduce="prod",
                                   interpret=True)


@pytest.mark.parametrize("m,k,n,e", [(130, 16, 16, 3), (300, 64, 48, 4),
                                     (512, 32, 130, 7), (96, 8, 8, 96)])
def test_segment_matmul_kernel(m, k, n, e):
    sizes = RNG.multinomial(m, np.ones(e) / e).astype(np.int32)
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((e, k, n)), jnp.float32)
    got = kops.segment_matmul(x, jnp.asarray(sizes), w, interpret=True)
    want = ref.segment_matmul(x, jnp.asarray(sizes), w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_segment_matmul_kernel_empty_groups():
    m, k, n, e = 128, 8, 8, 6
    sizes = np.array([0, 64, 0, 0, 64, 0], np.int32)
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((e, k, n)), jnp.float32)
    got = kops.segment_matmul(x, jnp.asarray(sizes), w, interpret=True)
    want = ref.segment_matmul(x, jnp.asarray(sizes), w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("ra,rb,m,n", [(40, 60, 300, 16), (100, 100, 513, 64),
                                       (20, 30, 64, 130)])
def test_sddmm_kernel(ra, rb, m, n):
    """SDDMM (paper §VI — the SpMM backward) vs the per-edge-dot oracle."""
    from repro.core import ops as core_ops
    a = jnp.asarray(RNG.standard_normal((ra, n)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((rb, n)), jnp.float32)
    ri = jnp.asarray(RNG.integers(0, ra, m).astype(np.int32))
    ci = jnp.asarray(RNG.integers(0, rb, m).astype(np.int32))
    got = kops.sddmm(a, b, ri, ci, interpret=True)
    want = core_ops.sddmm(a, b, ri, ci)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h_dim", [None, 1, 4])
def test_segment_softmax_kernel(h_dim):
    """Fused single-launch softmax vs the three-pass jnp oracle, 1-D and
    multi-head logits."""
    from repro.core.ops import _segment_softmax_ref
    m, s = 300, 40
    idx = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    shape = (m,) if h_dim is None else (m, h_dim)
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    got = kops.segment_softmax(x, jnp.asarray(idx), s,
                               config=KernelConfig("SR", 64, 128, 64, 1),
                               interpret=True)
    want = _segment_softmax_ref(x, jnp.asarray(idx), s)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_sddmm_accepts_plan_for_config():
    """plan= supplies the tiling config only (API symmetry with
    segment_matmul) — results are identical to the explicit-config call."""
    from repro.core import ops as core_ops
    from repro.core.plan import make_plan
    m, r, n = 300, 40, 16
    seg = np.sort(RNG.integers(0, 30, m)).astype(np.int32)
    plan = make_plan(seg, 30, feat=n, config=KernelConfig("SR", 64, 128, 64, 1))
    a = jnp.asarray(RNG.standard_normal((r, n)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((r, n)), jnp.float32)
    ri = jnp.asarray(RNG.integers(0, r, m).astype(np.int32))
    ci = jnp.asarray(RNG.integers(0, r, m).astype(np.int32))
    got = kops.sddmm(a, b, ri, ci, plan=plan, interpret=True)
    explicit = kops.sddmm(a, b, ri, ci, config=plan.config, interpret=True)
    want = core_ops.sddmm(a, b, ri, ci)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(explicit))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_kernel_respects_generated_rules():
    """config=None routes through the data-aware generated rules."""
    m, s, n = 500, 50, 8
    idx = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    x = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    got = kops.segment_reduce(x, jnp.asarray(idx), s, interpret=True)
    want = ref.segment_reduce(x, jnp.asarray(idx), s)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
