"""The unified public API surface (ISSUE 6).

Locks three contracts:
  * the ``repro`` facade exports everything in ``__all__`` (and
    ``repro.mp_typed`` resolves — the acceptance criterion);
  * every plan-aware entry point accepts the same ``(plan=, config=,
    tune=)`` kwarg trio (signature introspection, core + kernel layers);
  * grouped ``segment_matmul`` / the typed layers match a per-type
    Python-loop reference, forward and grad, with exactly one fused
    ``segment_matmul`` launch per layer (fusion counters).
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import ops as core_ops
from repro.core.plan import RelationPlan, make_relation_plan
from repro.data.graphs import TypedGraph, synth_typed_graph
from repro.kernels import ops as kops
from repro.models import gnn

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_facade_exports_resolve():
    missing = [n for n in repro.__all__ if not hasattr(repro, n)]
    assert not missing, missing
    # the acceptance criterion, verbatim
    assert callable(repro.mp_typed)
    assert repro.TypedGraph is TypedGraph
    assert "rgcn" in repro.TYPED_MODELS and "rgat" in repro.TYPED_MODELS
    # MODELS stays the homogeneous families the serve engine enumerates
    assert repro.MODELS == ("gcn", "gin", "sage", "gat")


def test_core_exports_resolve():
    from repro import core
    missing = [n for n in core.__all__ if not hasattr(core, n)]
    assert not missing, missing


def test_serving_package_is_gone():
    """The serving/ -> serve/ migration is finished: the deprecation shim
    was removed, so the old package simply does not exist anymore."""
    with pytest.raises(ModuleNotFoundError):
        import repro.serving  # noqa: F401
    import pathlib
    import repro as repro_pkg
    pkg_root = pathlib.Path(repro_pkg.__file__).parent
    assert not (pkg_root / "serving").exists()


# ---------------------------------------------------------------------------
# training surface (ISSUE 7): repro.train exports + fit's kwarg trio
# ---------------------------------------------------------------------------

def test_train_surface_exports_resolve():
    from repro import train
    missing = [n for n in train.__all__ if not hasattr(train, n)]
    assert not missing, missing
    # the facade re-exports the orchestration surface
    for name in ("Trainer", "TrainerConfig", "TrainState", "Task",
                 "NodeClassification", "DatasetProvider",
                 "GraphEpochProvider", "fit"):
        assert name in repro.__all__, name
        assert getattr(repro, name) is getattr(train, name)
    # the acceptance criterion, verbatim
    from repro import fit
    assert callable(fit)


def test_fit_kwarg_trio_uniform():
    """repro.train.fit and Task.prepare carry the library-wide
    (plan=, config=, tune=) trio with None defaults, like every other
    plan-aware entry point."""
    import repro.train as train
    for fn in (train.fit, train.NodeClassification.prepare,
               train.LMTask.prepare):
        params = inspect.signature(fn).parameters
        for kw in ("plan", "config", "tune"):
            assert kw in params, f"{fn.__qualname__} missing {kw}="
            assert params[kw].default is None, (
                f"{fn.__qualname__} {kw}= must default to None")
            assert params[kw].kind is inspect.Parameter.KEYWORD_ONLY, (
                f"{fn.__qualname__} {kw}= must be keyword-only")


def test_dataset_provider_protocol_is_structural():
    """Any object with batch(step) satisfies the provider protocol —
    no registration or inheritance required."""
    from repro.train import DatasetProvider, GraphEpochProvider

    class Custom:
        def batch(self, step):
            return step

    assert isinstance(Custom(), DatasetProvider)
    assert isinstance(
        GraphEpochProvider(shapes=((16, 32),), graphs_per_shape=1, feat=4,
                           num_classes=2),
        DatasetProvider)


# ---------------------------------------------------------------------------
# kwarg trio uniformity (plan= / config= / tune=)
# ---------------------------------------------------------------------------

CORE_PLAN_AWARE = [core_ops.segment_reduce, core_ops.index_segment_reduce,
                   core_ops.index_weight_segment_reduce,
                   core_ops.segment_softmax, core_ops.segment_matmul,
                   core_ops.grouped_segment_matmul, core_ops.sddmm]
KERNEL_PLAN_AWARE = [kops.segment_reduce, kops.gather_segment_reduce,
                     kops.segment_softmax, kops.segment_matmul, kops.sddmm]


@pytest.mark.parametrize("fn", CORE_PLAN_AWARE + KERNEL_PLAN_AWARE,
                         ids=lambda f: f"{f.__module__}.{f.__name__}")
def test_kwarg_trio_uniform(fn):
    params = inspect.signature(fn).parameters
    for kw in ("plan", "config", "tune"):
        assert kw in params, f"{fn.__name__} missing {kw}="
        assert params[kw].default is None, (
            f"{fn.__name__} {kw}= must default to None")


# ---------------------------------------------------------------------------
# grouped segment_matmul vs per-type reference loop
# ---------------------------------------------------------------------------

def _loop_matmul(x, sizes, w):
    """Per-type Python-loop reference (what the grouped launch replaces)."""
    out = jnp.zeros((x.shape[0], w.shape[-1]), x.dtype)
    off = 0
    for r, s in enumerate(sizes):
        s = int(s)
        out = jax.lax.dynamic_update_slice(
            out, jax.lax.dynamic_slice(
                x, (off, 0), (s, x.shape[1])) @ w[r], (off, 0))
        off += s
    return out


SIZE_CASES = [
    np.array([40, 0, 7, 130, 3], np.int32),       # skewed + empty
    np.array([0, 0, 0], np.int32),                # all empty
    np.array([256], np.int32),                    # single group
    np.array([1] * 17, np.int32),                 # many tiny groups
]


@pytest.mark.parametrize("sizes", SIZE_CASES, ids=lambda s: f"E{len(s)}")
@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("pad", [0, 9])
def test_grouped_matmul_fwd_grad_parity(sizes, impl, pad):
    m = int(sizes.sum()) + pad
    k, n = 12, 20
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(
        RNG.standard_normal((len(sizes), k, n)).astype(np.float32))
    gs = jnp.asarray(sizes)
    plan = (make_relation_plan(sizes, num_rows=m, feat=n)
            if impl == "pallas" else None)

    got = core_ops.grouped_segment_matmul(x, gs, w, impl, None, plan)
    want = _loop_matmul(x, sizes, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    def loss(f):
        return lambda x, w: jnp.sum(jnp.sin(f(x, w)))

    gx, gw = jax.grad(loss(
        lambda x, w: core_ops.grouped_segment_matmul(
            x, gs, w, impl, None, plan)), argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss(lambda x, w: _loop_matmul(x, sizes, w)),
                      argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                               rtol=1e-5, atol=1e-5)
    # out-of-range (padding) rows drop in forward AND backward
    if pad:
        live = int(sizes.sum())
        assert float(jnp.max(jnp.abs(got[live:]))) == 0.0
        assert float(jnp.max(jnp.abs(gx[live:]))) == 0.0


def test_segment_matmul_alias_is_grouped():
    sizes = np.array([8, 24], np.int32)
    x = jnp.asarray(RNG.standard_normal((32, 8)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((2, 8, 8)).astype(np.float32))
    a = core_ops.segment_matmul(x, jnp.asarray(sizes), w)
    b = core_ops.grouped_segment_matmul(x, jnp.asarray(sizes), w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_relation_plan_validates_and_conflicts():
    sizes = np.array([16, 48], np.int32)
    plan = make_relation_plan(sizes, feat=16)
    assert isinstance(plan, RelationPlan)
    x = jnp.zeros((64, 8), jnp.float32)
    w = jnp.zeros((2, 8, 16), jnp.float32)
    # wrong row/group counts fail loudly
    with pytest.raises(ValueError):
        plan.validate(63, 2)
    with pytest.raises(ValueError):
        plan.validate(64, 3)
    # explicit config conflicting with the plan's tiling raises
    from repro.core.config_space import KernelConfig
    bad = KernelConfig("SR", 128, 256, plan.config.m_b * 2, 1)
    with pytest.raises(ValueError, match="conflicts"):
        kops.segment_matmul(x, jnp.asarray(sizes), w, config=bad,
                            plan=plan, interpret=True)


# ---------------------------------------------------------------------------
# TypedGraph layout + round-trip validation
# ---------------------------------------------------------------------------

def test_typed_graph_layout_roundtrip():
    g = synth_typed_graph("tg", 50, 260, num_relations=6, feat=8, seed=1)
    # dst-sorted primary storage, dst-aligned types
    assert np.all(np.diff(g.edge_index[1]) >= 0)
    # stable argsort ⇒ (type, dst) lexicographic order
    et_t = g.edge_type[g.type_perm]
    dst_t = g.edge_index[1][g.type_perm]
    assert np.all(np.diff(et_t) >= 0)
    same_type = np.diff(et_t) == 0
    assert np.all(np.diff(dst_t)[same_type] >= 0)
    # permutation round-trips; counts agree
    assert np.array_equal(g.type_perm[g.inv_type_perm], np.arange(260))
    assert int(g.type_counts.sum()) == 260
    # relation-plan memo: same key → same object
    assert g.make_relation_plan(feat=8) is g.make_relation_plan(feat=8)


def test_typed_graph_rejects_malformed():
    g = synth_typed_graph("tg", 20, 60, num_relations=3, feat=4, seed=2)
    kw = dict(name="bad", num_nodes=g.num_nodes, x=g.x, labels=g.labels,
              deg_inv_sqrt=g.deg_inv_sqrt)
    with pytest.raises(ValueError, match="edge_type"):
        TypedGraph(edge_index=g.edge_index, edge_type=None,
                   num_relations=3, **kw)
    with pytest.raises(ValueError, match="shape"):
        TypedGraph(edge_index=g.edge_index, edge_type=g.edge_type[:-1],
                   num_relations=3, **kw)
    with pytest.raises(ValueError, match="ids must lie"):
        TypedGraph(edge_index=g.edge_index,
                   edge_type=np.full(60, 3, np.int32), num_relations=3, **kw)
    with pytest.raises(ValueError, match="round-trip"):
        TypedGraph(edge_index=g.edge_index, edge_type=g.edge_type,
                   num_relations=3, type_perm=g.type_perm,
                   inv_type_perm=np.roll(g.inv_type_perm, 1),
                   type_counts=g.type_counts, **kw)


# ---------------------------------------------------------------------------
# RGCN / RGAT parity vs per-type loop reference (fwd + grad, ≤1e-5 fp32)
# ---------------------------------------------------------------------------

def _typed_fixture(num_relations=5, feat=12, seed=4):
    g = synth_typed_graph("parity", 40, 180, num_relations=num_relations,
                          feat=feat, seed=seed)
    return g, jnp.asarray(g.x), jnp.asarray(g.edge_index), \
        jnp.asarray(g.edge_type)


def _loop_typed_messages(g, x, w_rel):
    """(E, N) per-edge transformed sources in dst order, via a per-type
    Python loop — the reference the grouped launch must match."""
    src = g.edge_index[0]
    msg = jnp.zeros((g.num_edges, w_rel.shape[-1]), x.dtype)
    for r in range(g.num_relations):
        sel = np.where(g.edge_type == r)[0]
        msg = msg.at[sel].set(jnp.take(x, src[sel], axis=0) @ w_rel[r])
    return msg


def _ref_rgcn_layer(g, prm, x):
    dst = jnp.asarray(g.edge_index[1])
    msg = _loop_typed_messages(g, x, prm["w_rel"].value)
    s = jax.ops.segment_sum(msg, dst, g.num_nodes, indices_are_sorted=True)
    cnt = jax.ops.segment_sum(jnp.ones(g.num_edges), dst, g.num_nodes,
                              indices_are_sorted=True)
    return (x @ prm["w_self"].value + s / jnp.maximum(cnt, 1.0)[:, None]
            + prm["b"].value)


def _ref_rgat_layer(g, prm, x):
    dst = jnp.asarray(g.edge_index[1])
    et = jnp.asarray(g.edge_type)
    _, heads, d_out = prm["a_src"].value.shape
    msg = _loop_typed_messages(g, x, prm["w_rel"].value)
    msg_h = msg.reshape(g.num_edges, heads, d_out)
    a_src = jnp.take(prm["a_src"].value, et, axis=0)
    a_dst = jnp.take(prm["a_dst"].value, et, axis=0)
    logit = (jnp.einsum("ehd,ehd->eh", msg_h, a_src) +
             jnp.einsum("ek,ehk->eh",
                        jnp.take(x, jnp.asarray(g.edge_index[1]), axis=0),
                        a_dst))
    e = jax.nn.leaky_relu(logit, 0.2)
    alpha = core_ops.segment_softmax(e, dst, g.num_nodes)
    out = 0.0
    for i in range(heads):
        out = out + jax.ops.segment_sum(alpha[:, i:i + 1] * msg_h[:, i, :],
                                        dst, g.num_nodes,
                                        indices_are_sorted=True)
    return out / heads


@pytest.mark.parametrize("model,ref_layer", [("rgcn", _ref_rgcn_layer),
                                             ("rgat", _ref_rgat_layer)])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_typed_layer_parity_fwd_grad(model, ref_layer, impl):
    g, x, ei, et = _typed_fixture()
    prm = gnn.init(jax.random.PRNGKey(1), model, 12, 12, 12, num_layers=1,
                   num_relations=g.num_relations, heads=2)[0]
    layer = gnn._LAYER[model][1]
    kw = dict(edge_type=et, type_perm=jnp.asarray(g.type_perm),
              inv_type_perm=jnp.asarray(g.inv_type_perm),
              type_counts=jnp.asarray(g.type_counts))

    got = layer(prm, x, ei, g.num_nodes, impl=impl, **kw)
    want = ref_layer(g, prm, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    ggot = jax.grad(lambda p, x: jnp.sum(
        layer(p, x, ei, g.num_nodes, impl=impl, **kw) ** 2),
        argnums=(0, 1))(prm, x)
    gwant = jax.grad(lambda p, x: jnp.sum(ref_layer(g, p, x) ** 2),
                     argnums=(0, 1))(prm, x)
    for a, b in zip(jax.tree_util.tree_leaves(ggot),
                    jax.tree_util.tree_leaves(gwant)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("model", ["rgcn", "rgat"])
def test_typed_forward_one_grouped_launch_per_layer(model):
    g, x, ei, et = _typed_fixture()
    num_layers = 3
    params = gnn.init(jax.random.PRNGKey(2), model, 12, 16, 8,
                      num_layers=num_layers, num_relations=g.num_relations)
    rplan = g.make_relation_plan(feat=16)
    with kops.fusion_scope() as counts:
        out = gnn.forward(params, model, x, ei, g.num_nodes, impl="pallas",
                          edge_type=et, type_perm=jnp.asarray(g.type_perm),
                          inv_type_perm=jnp.asarray(g.inv_type_perm),
                          type_counts=jnp.asarray(g.type_counts),
                          rplan=rplan, plan=g.make_plan(feat=16))
        assert out.shape == (g.num_nodes, 8)
        # exactly ONE grouped segment_matmul launch per layer, and no
        # unfused per-type fallback anywhere on the pallas path
        assert counts["fused:segment_matmul"] == num_layers, dict(counts)
        assert not [k for k in counts if k.startswith("unfused:")], \
            dict(counts)


def test_typed_forward_via_facade():
    g = synth_typed_graph("facade", 30, 120, num_relations=4, feat=8, seed=5)
    params = repro.gnn_init(jax.random.PRNGKey(3), "rgcn", 8, 16, 4,
                            num_relations=4)
    out = repro.gnn_forward(params, "rgcn", jnp.asarray(g.x),
                            jnp.asarray(g.edge_index), g.num_nodes,
                            impl="pallas", edge_type=jnp.asarray(g.edge_type))
    assert out.shape == (30, 4)


# ---------------------------------------------------------------------------
# hypothesis property sweep (CI installs hypothesis; skipped locally if absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        sizes=st.lists(st.integers(min_value=0, max_value=40), min_size=1,
                       max_size=12),
        k=st.integers(min_value=1, max_value=24),
        n=st.integers(min_value=1, max_value=24),
        pad=st.integers(min_value=0, max_value=8),
        impl=st.sampled_from(["ref", "pallas"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_grouped_matmul_property(sizes, k, n, pad, impl, seed):
        sizes = np.asarray(sizes, np.int32)
        m = int(sizes.sum()) + pad
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(
            rng.standard_normal((len(sizes), k, n)).astype(np.float32))
        gs = jnp.asarray(sizes)
        got = core_ops.grouped_segment_matmul(x, gs, w, impl)
        want = _loop_matmul(x, sizes, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        gx = jax.grad(lambda x: jnp.sum(
            core_ops.grouped_segment_matmul(x, gs, w, impl)))(x)
        rx = jax.grad(lambda x: jnp.sum(_loop_matmul(x, sizes, w)))(x)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                                   rtol=2e-4, atol=2e-4)
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed — property sweep "
                             "runs in CI")
    def test_grouped_matmul_property():
        pass
