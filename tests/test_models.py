"""Model-family correctness: MoE paths agree, recurrent forms match
stepwise decode, GNN layers match dense-adjacency oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, lm, moe as moe_lib
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)


def tiny(name="t", **kw):
    base = dict(family="dense", num_layers=2, d_model=32, num_heads=4,
                num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
                dtype="float32", max_seq=64)
    base.update(kw)
    return ModelConfig(name, **base)


# ---------------------------------------------------------------------------
# forward ≡ stepwise decode (the strongest end-to-end consistency check)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "rwkv", "hybrid"])
def test_forward_matches_decode(kind):
    if kind == "dense":
        cfg = tiny()
    elif kind == "rwkv":
        cfg = tiny(rwkv=True, pos="none", num_kv_heads=4)
    else:
        cfg = tiny(num_layers=4, attn_every=2, attn_offset=1, pos="none",
                   d_state=4, d_conv=4, expand=2)
    prm = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(prm, cfg, toks, remat_policy="none")

    state = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    step_logits = []
    for t in range(toks.shape[1]):
        lg, state = lm.decode_step(prm, cfg, toks[:, t:t + 1], state)
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_forward_matches_decode_moe():
    cfg = tiny(num_experts=4, top_k=2, moe_d_ff=32, capacity_factor=8.0)
    prm = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 7), 0, cfg.vocab_size)
    # high capacity factor ⇒ no dropped tokens ⇒ paths agree exactly
    full_logits, _ = lm.forward(prm, cfg, toks, remat_policy="none",
                                moe_impl="ragged")
    state = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    outs = []
    for t in range(toks.shape[1]):
        lg, state = lm.decode_step(prm, cfg, toks[:, t:t + 1], state,
                                   moe_impl="ragged")
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE: capacity path == ragged (dropless) path when capacity is ample
# ---------------------------------------------------------------------------

def test_moe_capacity_equals_ragged():
    cfg = tiny(num_experts=8, top_k=2, moe_d_ff=16, capacity_factor=8.0)
    prm = moe_lib.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y1, _ = moe_lib.moe_capacity(prm, x, cfg)
    y2, _ = moe_lib.moe_ragged(prm, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_moe_ragged_pallas_kernel_path():
    cfg = tiny(num_experts=4, top_k=2, moe_d_ff=16, capacity_factor=8.0)
    prm = moe_lib.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))
    y_ref, _ = moe_lib.moe_ragged(prm, x, cfg, impl="ref")
    y_pal, _ = moe_lib.moe_ragged(prm, x, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=1e-3, atol=1e-3)


def test_moe_capacity_drops_overflow():
    cfg = tiny(num_experts=2, top_k=1, moe_d_ff=16, capacity_factor=0.02)
    prm = moe_lib.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model))
    y, _ = moe_lib.moe_capacity(prm, x, cfg)     # must not crash / NaN
    assert not bool(jnp.isnan(y).any())


# ---------------------------------------------------------------------------
# GNN layers vs dense-adjacency oracles (paper's models)
# ---------------------------------------------------------------------------

def _graph(v=30, e=120, f=8, seed=0):
    rng = np.random.default_rng(seed)
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    src = rng.integers(0, v, e).astype(np.int32)
    x = rng.standard_normal((v, f)).astype(np.float32)
    a = np.zeros((v, v), np.float32)
    for i in range(e):
        a[dst[i], src[i]] += 1.0
    return jnp.asarray(np.stack([src, dst])), jnp.asarray(x), a, v


def test_gcn_layer_matches_dense():
    ei, x, a, v = _graph()
    deg = np.maximum(np.asarray(a.sum(1)), 1.0)
    dis = jnp.asarray(1.0 / np.sqrt(deg), dtype=jnp.float32)
    prm = gnn.gcn_layer_init(KEY, 8, 5)
    got = gnn.gcn_layer(prm, x, ei, v, dis)
    norm_a = np.asarray(dis)[:, None] * a * np.asarray(dis)[None, :]
    want = norm_a @ np.asarray(x @ prm["w"].value) + np.asarray(prm["b"].value)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_gin_layer_matches_dense():
    ei, x, a, v = _graph(seed=1)
    prm = gnn.gin_layer_init(KEY, 8, 6)
    got = gnn.gin_layer(prm, x, ei, v)
    h = (1.0 + np.asarray(prm["eps"].value)) * np.asarray(x) + a @ np.asarray(x)
    h = np.maximum(h @ np.asarray(prm["mlp1"].value)
                   + np.asarray(prm["b1"].value), 0.0)
    want = h @ np.asarray(prm["mlp2"].value) + np.asarray(prm["b2"].value)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_sage_mean_matches_dense():
    ei, x, a, v = _graph(seed=2)
    prm = gnn.sage_layer_init(KEY, 8, 4)
    got = gnn.sage_layer(prm, x, ei, v)
    deg = np.maximum(a.sum(1, keepdims=True), 1.0)
    want = (np.asarray(x) @ np.asarray(prm["w_self"].value)
            + (a / deg) @ np.asarray(x) @ np.asarray(prm["w_neigh"].value)
            + np.asarray(prm["b"].value))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_gat_attention_sums_to_one():
    ei, x, a, v = _graph(seed=3)
    prm = gnn.gat_layer_init(KEY, 8, 4)
    out = gnn.gat_layer(prm, x, ei, v)
    assert out.shape == (v, 4) and not bool(jnp.isnan(out).any())


def test_gat_multihead_shapes_and_finite():
    """heads>1: per-head attention + head-averaged output keeps the layer
    width at d_out; end-to-end forward stays finite."""
    ei, x, a, v = _graph(seed=5)
    prm = gnn.gat_layer_init(KEY, 8, 4, heads=3)
    assert prm["w"].value.shape == (8, 12)
    assert prm["a_src"].value.shape == (3, 4)
    out = gnn.gat_layer(prm, x, ei, v)
    assert out.shape == (v, 4) and not bool(jnp.isnan(out).any())
    params = gnn.init(KEY, "gat", 8, 16, 4, heads=3)
    logits = gnn.forward(params, "gat", x, ei, v)
    assert logits.shape == (v, 4) and not bool(jnp.isnan(logits).any())


def test_uniform_layer_signature():
    """Every family answers the same call — no per-model special-casing."""
    ei, x, a, v = _graph(seed=6)
    deg = np.maximum(np.asarray(a.sum(1)), 1.0)
    dis = jnp.asarray(1.0 / np.sqrt(deg), dtype=jnp.float32)
    for model in gnn.MODELS:
        params = gnn.init(KEY, model, 8, 16, 4)
        out = gnn.forward(params, model, x, ei, v, dis)
        assert out.shape == (v, 4)


def test_gnn_training_decreases_loss():
    ei, x, a, v = _graph(v=40, e=200, f=8, seed=4)
    deg = np.maximum(np.asarray(a.sum(1)), 1.0)
    dis = jnp.asarray(1.0 / np.sqrt(deg), dtype=jnp.float32)
    labels = jnp.asarray((np.asarray(x[:, 0]) > 0).astype(np.int32))
    params = gnn.init(KEY, "gcn", 8, 16, 2)
    l0 = float(gnn.loss_fn(params, "gcn", x, ei, labels, v, dis))

    @jax.jit
    def step(params):
        g = jax.grad(gnn.loss_fn)(params, "gcn", x, ei, labels, v, dis)
        return jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)

    for _ in range(300):
        params = step(params)
    l1 = float(gnn.loss_fn(params, "gcn", x, ei, labels, v, dis))
    # deterministic on CPU (fixed seeds); 300 steps of lr=0.5 drop the loss
    # 0.694 -> ~0.506, leaving >2x margin over the threshold
    assert l1 < l0 - 0.08, (l0, l1)
