"""Sharded message-passing integration: runs tests/_sharded_mp_checks.py in
a subprocess with 8 host devices (the main pytest process keeps 1 device,
matching conftest's invariant)."""
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_sharded_mp_checks_subprocess():
    script = pathlib.Path(__file__).parent / "_sharded_mp_checks.py"
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=880,
                         cwd=pathlib.Path(__file__).parents[1])
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL SHARDED MP CHECKS OK" in out.stdout
