"""End-to-end behaviour tests: training learns, serving is consistent,
the dry-run machinery works on a host-scale mesh, GNN end-to-end inference
(the paper's workload) runs through the full public API."""
import dataclasses
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core import ops as geot
from repro.data.graphs import dataset
from repro.data.tokens import SyntheticTokens, TokenDatasetConfig
from repro.models import gnn, lm
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def test_lm_training_learns_markov_language():
    cfg = cfglib.get_config("stablelm-1.6b").reduced(
        vocab_size=512, num_layers=2, d_model=128, d_ff=256)
    prm = lm.init(KEY, cfg)
    opt_cfg = adamw.AdamWConfig(lr=2e-3, weight_decay=0.0)
    opt = adamw.init(prm, opt_cfg)
    data = SyntheticTokens(TokenDatasetConfig(512, 64, 8))

    @jax.jit
    def step(prm, opt, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, remat_policy="none"),
            has_aux=True)(prm)
        prm, opt, _ = adamw.update(g, opt, prm, opt_cfg)
        return prm, opt, l

    losses = []
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        prm, opt, l = step(prm, opt, batch)
        losses.append(float(l))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 1.0, (
        losses[:3], losses[-3:])


def test_gnn_end_to_end_inference():
    """Paper §V-F workload: 3-layer GCN/GIN/SAGE node classification on a
    Table-II-sized graph via the GeoT ops."""
    g = dataset("cora", feat=16)
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    dis = jnp.asarray(g.deg_inv_sqrt)
    for mdl in ("gcn", "gin", "sage"):
        params = gnn.init(KEY, mdl, 16, 32, 7)
        out = jax.jit(lambda p, x: gnn.forward(p, mdl, x, ei, g.num_nodes,
                                               dis))(params, x)
        assert out.shape == (g.num_nodes, 7)
        assert not bool(jnp.isnan(out).any())


def test_fused_vs_unfused_gnn_same_result():
    """Listing 1 vs Listing 2 of the paper: sparse-format-free fusion gives
    identical results to the gather-then-reduce formulation."""
    g = dataset("citeseer", feat=8)
    x = jnp.asarray(g.x)
    src, dst = jnp.asarray(g.edge_index[0]), jnp.asarray(g.edge_index[1])
    unfused = geot.segment_reduce(jnp.take(x, src, axis=0), dst, g.num_nodes)
    fused = geot.index_segment_reduce(x, src, dst, g.num_nodes)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


def test_serve_prefill_decode_consistency():
    cfg = cfglib.get_config("qwen3-8b").reduced()
    prm = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    full, _ = lm.forward(prm, cfg, toks, remat_policy="none")
    st = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    for t in range(8):
        lg, st = lm.decode_step(prm, cfg, toks[:, t:t + 1], st)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.timeout(900)
def test_dryrun_machinery_on_host_mesh():
    """The dry-run path end-to-end (lower+compile+analyses) in a subprocess
    with a small forced device count — validates the exact machinery the
    512-device run uses without touching this process's device state."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys; sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell
res = run_cell("stablelm-1.6b", "decode_32k", multi_pod=False, verbose=False)
assert res["status"] == "ok", res
assert res["cost_analysis"].get("flops", 0) > 0
assert res["collectives"]["total_bytes"] > 0
res2 = run_cell("rwkv6-3b", "long_500k", multi_pod=True, verbose=False)
assert res2["status"] == "ok", res2
print("DRYRUN MACHINERY OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=880,
                         cwd=pathlib.Path(__file__).parents[1])
    assert "DRYRUN MACHINERY OK" in out.stdout, out.stderr[-2000:]


def test_dryrun_results_complete():
    """The committed sweep results cover all 40 cells × 2 meshes with no
    errors (regenerate with scripts/run_dryrun_sweep.sh)."""
    import json
    d = pathlib.Path(__file__).parents[1] / "results" / "dryrun"
    if not d.exists():
        pytest.skip("sweep results not generated")
    files = list(d.glob("*.json"))
    assert len(files) == 80, len(files)
    status = [json.loads(f.read_text()).get("status") for f in files]
    assert status.count("ok") == 64
    assert status.count("skipped") == 16
