"""Neighbor sampling: CSR store correctness, out-of-core shard round
trips, determinism (across runs and thread counts), empty-neighborhood
safety, and the exact-neighborhood parity property (sampled forward ==
full-graph forward on the seed rows)."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.graphs import synth_graph
from repro.data.sampling import (InMemoryStore, NeighborSampler,
                                 ShardedGraphStore, Subgraph,
                                 save_graph_shards)
from repro.models import gnn
from repro.serve.buckets import pad_to_bucket

KEY = jax.random.PRNGKey(0)
G = synth_graph("samp", 256, 1024, feat=16, num_classes=8, seed=3)
STORE = InMemoryStore(G)


# ---------------------------------------------------------------------------
# stores
# ---------------------------------------------------------------------------

def test_inmemory_store_matches_edge_list():
    for d in range(G.num_nodes):
        expect = G.edge_index[0][G.edge_index[1] == d]
        np.testing.assert_array_equal(STORE.in_edges(d), expect)
        assert STORE.in_degree(d) == expect.size


def test_inmemory_store_rejects_unsorted():
    bad = synth_graph("bad", 8, 16, feat=4, seed=0)
    ei = bad.edge_index.copy()
    ei[1] = ei[1][::-1]
    import dataclasses
    with pytest.raises(ValueError, match="sorted"):
        InMemoryStore(dataclasses.replace(bad, edge_index=ei))


@pytest.mark.parametrize("num_shards", [1, 3, 4])
def test_sharded_store_round_trip(tmp_path, num_shards):
    path = save_graph_shards(G, str(tmp_path / f"s{num_shards}"), num_shards)
    sg = ShardedGraphStore(path, cache_shards=2)
    assert (sg.num_nodes, sg.num_edges) == (G.num_nodes, G.num_edges)
    for d in [0, 1, 100, 200, G.num_nodes - 1]:
        np.testing.assert_array_equal(sg.in_edges(d), STORE.in_edges(d))
    ids = np.array([0, 7, 99, 128, 255])
    a, b = STORE.gather_nodes(ids), sg.gather_nodes(ids)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_sharded_store_is_actually_out_of_core(tmp_path):
    """The LRU holds at most cache_shards shard files; scanning the whole
    node range with cache_shards=1 must re-load shards (bounded memory),
    and the number of files on disk matches the shard count."""
    path = save_graph_shards(G, str(tmp_path / "ooc"), 4)
    assert len([f for f in os.listdir(path) if f.endswith(".npz")]) == 4
    sg = ShardedGraphStore(path, cache_shards=1)
    for d in range(0, G.num_nodes, 16):
        sg.in_edges(d)
    assert len(sg._lru) == 1
    assert sg.loads >= 4


def test_sharded_sampler_matches_inmemory(tmp_path):
    path = save_graph_shards(G, str(tmp_path / "eq"), 3)
    sg = ShardedGraphStore(path, cache_shards=2)
    a = NeighborSampler(STORE, fanouts=(4, 3), batch_size=16, seed=7)
    b = NeighborSampler(sg, fanouts=(4, 3), batch_size=16, seed=7)
    for step in range(4):
        sa, sb = a.sample_batch(step), b.sample_batch(step)
        np.testing.assert_array_equal(sa.node_ids, sb.node_ids)
        np.testing.assert_array_equal(sa.edge_index, sb.edge_index)
        np.testing.assert_array_equal(sa.x, sb.x)


# ---------------------------------------------------------------------------
# sampler invariants
# ---------------------------------------------------------------------------

def test_subgraph_structure():
    s = NeighborSampler(G, fanouts=(4, 3), batch_size=16, seed=7)
    sub = s.sample_batch(0)
    assert isinstance(sub, Subgraph)
    assert sub.num_seeds == 16
    # dst-sorted (the kernel/plan contract), seeds are rows [0, 16)
    assert np.all(np.diff(sub.edge_index[1]) >= 0)
    np.testing.assert_array_equal(sub.seed_nodes, sub.node_ids[:16])
    # node data comes from the parent graph, including its deg_inv_sqrt
    np.testing.assert_array_equal(sub.x, G.x[sub.node_ids])
    np.testing.assert_array_equal(sub.deg_inv_sqrt,
                                  G.deg_inv_sqrt[sub.node_ids])
    # fanout cap: no destination exceeds its per-hop budget
    counts = np.bincount(sub.edge_index[1], minlength=sub.num_nodes)
    assert counts[:16].max() <= 4
    # every edge is a real parent edge
    gsrc = sub.node_ids[sub.edge_index[0]]
    gdst = sub.node_ids[sub.edge_index[1]]
    parent = set(zip(G.edge_index[0].tolist(), G.edge_index[1].tolist()))
    assert all((int(a), int(b)) in parent for a, b in zip(gsrc, gdst))


def test_sampler_determinism_across_runs():
    for _ in range(2):
        a = NeighborSampler(G, fanouts=(4, 3), batch_size=16, seed=7)
        b = NeighborSampler(G, fanouts=(4, 3), batch_size=16, seed=7)
        for step in [0, 1, 5, 17]:
            sa, sb = a.sample_batch(step), b.sample_batch(step)
            np.testing.assert_array_equal(sa.node_ids, sb.node_ids)
            np.testing.assert_array_equal(sa.edge_index, sb.edge_index)


def test_sampler_determinism_under_threads():
    """The batch stream is a pure function of (seed, step): producing the
    same steps from many threads, in scrambled order, yields bitwise the
    reference batches — the property that makes prefetch depth/thread
    count invisible to training."""
    s = NeighborSampler(G, fanouts=(4, 3), batch_size=16, seed=7)
    ref = {step: s.sample_batch(step) for step in range(8)}
    results: dict = {}
    errors: list = []

    def worker(steps):
        try:
            for st in steps:
                results[st] = s.sample_batch(st)
        except Exception as e:                      # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(list(range(8))[i::4],))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for step, sub in ref.items():
        np.testing.assert_array_equal(results[step].node_ids, sub.node_ids)
        np.testing.assert_array_equal(results[step].edge_index,
                                      sub.edge_index)


def test_seed_epoch_coverage():
    s = NeighborSampler(G, fanouts=(2,), batch_size=64, seed=1)
    seen = np.concatenate([s.seeds_for(st) for st in range(len(s))])
    assert np.unique(seen).size == seen.size          # no repeats in epoch
    # different epochs permute differently
    assert not np.array_equal(s.seeds_for(0), s.seeds_for(len(s)))


def test_sampler_rejects_bad_args():
    with pytest.raises(ValueError, match="fanout"):
        NeighborSampler(G, fanouts=(0,))
    with pytest.raises(ValueError, match="at least one hop"):
        NeighborSampler(G, fanouts=())
    s = NeighborSampler(G, fanouts=(2,), batch_size=4)
    with pytest.raises(ValueError, match="unique"):
        s.sample(np.array([1, 1]))
    with pytest.raises(ValueError, match="out of range"):
        s.sample(np.array([G.num_nodes]))


# ---------------------------------------------------------------------------
# empty neighborhoods (satellite regression)
# ---------------------------------------------------------------------------

def _isolated_nodes():
    iso = np.where(STORE.indptr[1:] == STORE.indptr[:-1])[0]
    assert iso.size > 0, "power-law synth graph should have isolated nodes"
    return iso


def test_empty_neighborhood_yields_valid_subgraph():
    iso = _isolated_nodes()
    s = NeighborSampler(G, fanouts=(4, 4), batch_size=4, seed=0)
    sub = s.sample(iso[:3])
    assert sub.num_edges == 0
    assert sub.edge_index.shape == (2, 0)
    assert sub.edge_index.dtype == np.int32
    assert sub.num_nodes == 3 and sub.num_seeds == 3


def test_empty_neighborhood_through_pad_and_planned_forward():
    """Regression: isolated seeds must survive the whole path — sampler →
    bucket pad → stamped plan → planned pallas forward — and produce the
    same logits as the dense reference (their logits depend only on their
    own features)."""
    iso = _isolated_nodes()
    s = NeighborSampler(G, fanouts=(4, 4), batch_size=4, seed=0)
    sub = s.sample(iso[:3])
    padded, bucket = pad_to_bucket(sub)
    from repro.serve.plan_cache import BucketEntry, bucket_max_chunks
    from repro.core.heuristics import select_config
    cfg = select_config(max(bucket.num_edges, 1), 1, 32, tune=False)
    entry = BucketEntry(bucket, 32, cfg,
                        max_chunks=bucket_max_chunks(bucket, cfg))
    plan = entry.stamp(padded.edge_index[1])
    params = gnn.init(KEY, "gcn", 16, 32, 8, num_layers=2)
    out = gnn.forward(params, "gcn", jnp.asarray(padded.x),
                      jnp.asarray(padded.edge_index), padded.num_nodes,
                      jnp.asarray(padded.deg_inv_sqrt), impl="pallas",
                      plan=plan)
    ref = gnn.forward(params, "gcn", jnp.asarray(padded.x),
                      jnp.asarray(padded.edge_index), padded.num_nodes,
                      jnp.asarray(padded.deg_inv_sqrt), impl="ref")
    np.testing.assert_allclose(np.asarray(out)[:3], np.asarray(ref)[:3],
                               atol=1e-5)


# ---------------------------------------------------------------------------
# exact-neighborhood parity (satellite property test)
# ---------------------------------------------------------------------------

def _check_exact_parity(model, depth, batch, step, seed):
    """An exact-neighborhood depth-L subgraph reproduces the depth-L
    model's seed logits: every aggregation any seed's receptive field
    needs is complete, and the parent deg_inv_sqrt makes the GCN weights
    identical."""
    params = gnn.init(KEY, model, 16, 32, 8, num_layers=depth)
    full = np.asarray(gnn.forward(params, model, jnp.asarray(G.x),
                                  jnp.asarray(G.edge_index), G.num_nodes,
                                  jnp.asarray(G.deg_inv_sqrt), impl="ref"))
    s = NeighborSampler(G, fanouts=(None,) * depth, exact=True,
                        batch_size=batch, seed=seed)
    sub = s.sample_batch(step)
    out = np.asarray(gnn.forward(params, model, jnp.asarray(sub.x),
                                 jnp.asarray(sub.edge_index), sub.num_nodes,
                                 jnp.asarray(sub.deg_inv_sqrt), impl="ref"))
    np.testing.assert_allclose(out[:sub.num_seeds], full[sub.seed_nodes],
                               atol=1e-5, rtol=1e-5)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(model=st.sampled_from(["gcn", "sage"]),
           depth=st.integers(1, 2),
           batch=st.integers(1, 12),
           step=st.integers(0, 30),
           seed=st.integers(0, 2 ** 16))
    def test_exact_sampled_forward_matches_full_graph(model, depth, batch,
                                                      step, seed):
        _check_exact_parity(model, depth, batch, step, seed)
else:
    # deterministic fallback: the parity property still runs where
    # hypothesis is unavailable, over a fixed sweep of the same space
    @pytest.mark.parametrize("model", ["gcn", "sage"])
    @pytest.mark.parametrize("depth", [1, 2])
    def test_exact_sampled_forward_matches_full_graph(model, depth):
        for batch, step, seed in [(1, 0, 0), (8, 3, 11), (12, 17, 12345)]:
            _check_exact_parity(model, depth, batch, step, seed)
