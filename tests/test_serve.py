"""Serving subsystem: bucket padding invariance, plan/executable cache
accounting, continuous batching, and GNNServer end-to-end parity."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config_space import KernelConfig
from repro.core.mp import mp
from repro.core.plan import make_graph_plan
from repro.data.graphs import (batch_graphs, pad_graph, synth_graph,
                               unpad_edges, unpad_graph, unpad_nodes)
from repro.kernels import ops as kops
from repro.models import gnn
from repro.serve import (BucketPolicy, GNNServer, GraphBatcher, GraphRequest,
                         PlanCache, ShapeBucket, bucket_for, pad_to_bucket)
from repro.serve.plan_cache import BucketEntry

KEY = jax.random.PRNGKey(0)
CFG = KernelConfig("SR", 64, 128, 64, 1)


# ---------------------------------------------------------------------------
# pad_graph round trips
# ---------------------------------------------------------------------------

def test_pad_graph_round_trip():
    g = synth_graph("g", 50, 170, feat=8, seed=0)
    p = pad_graph(g, 64, 256)
    assert (p.num_nodes, p.num_edges) == (64, 256)
    assert (p.orig_num_nodes, p.orig_num_edges) == (50, 170)
    # padded edges carry the drop id; destinations stay sorted
    assert np.all(p.edge_index[1, 170:] == 64)
    assert np.all(np.diff(p.edge_index[1]) >= 0)
    vals = np.arange(64 * 3).reshape(64, 3)
    np.testing.assert_array_equal(unpad_nodes(p, vals), vals[:50])
    evals = np.arange(256)
    np.testing.assert_array_equal(unpad_edges(p, evals), evals[:170])
    back = unpad_graph(p)
    np.testing.assert_array_equal(back.edge_index, g.edge_index)
    np.testing.assert_array_equal(back.x, g.x)
    np.testing.assert_array_equal(back.deg_inv_sqrt, g.deg_inv_sqrt)
    assert back.num_nodes == g.num_nodes
    # double padding keeps the innermost real sizes
    pp = pad_graph(p, 128, 512)
    assert (pp.orig_num_nodes, pp.orig_num_edges) == (50, 170)


def test_pad_graph_rejects_shrink():
    g = synth_graph("g", 50, 170, feat=4, seed=0)
    with pytest.raises(ValueError, match="shrink"):
        pad_graph(g, 32, 256)


def test_unpad_is_noop_on_unpadded():
    g = synth_graph("g", 20, 40, feat=4, seed=1)
    vals = np.arange(20)
    assert unpad_nodes(g, vals) is vals
    assert unpad_graph(g) is g


# ---------------------------------------------------------------------------
# batch_graphs: single-graph fast path + padded-member guard
# ---------------------------------------------------------------------------

def test_batch_single_graph_fast_path_preserves_plan_memo():
    g = synth_graph("g", 40, 120, feat=8, seed=2)
    plan = g.make_plan(feat=16, config=CFG)
    b = batch_graphs([g])
    assert b.num_graphs == 1
    # arrays shared, not copied; the memoized plan is carried over
    assert b.edge_index is g.edge_index and b.x is g.x
    assert b.make_plan(feat=16, config=CFG) is plan
    np.testing.assert_array_equal(b.node_ptr, [0, 40])
    np.testing.assert_array_equal(b.edge_ptr, [0, 120])


def test_batch_rejects_padded_members():
    g = synth_graph("g", 40, 120, feat=4, seed=2)
    p = pad_graph(g, 64, 128)
    with pytest.raises(ValueError, match="padded"):
        batch_graphs([p, g])


# ---------------------------------------------------------------------------
# buckets
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    pol = BucketPolicy(min_nodes=64, min_edges=64)
    assert bucket_for(1, 1, pol) == ShapeBucket(64, 64)
    assert bucket_for(64, 65, pol) == ShapeBucket(64, 128)
    assert bucket_for(700, 3000, pol) == ShapeBucket(1024, 4096)
    with pytest.raises(ValueError):
        BucketPolicy(growth=1.0)


def test_pad_to_bucket_round_trip():
    g = synth_graph("g", 90, 300, feat=8, seed=3)
    padded, bucket = pad_to_bucket(g)
    assert bucket == ShapeBucket(128, 512)
    assert (padded.num_nodes, padded.num_edges) == (128, 512)
    assert unpad_graph(padded).num_nodes == 90


# ---------------------------------------------------------------------------
# padding invariance (the property the whole serving path stands on):
# logits over the real nodes are unchanged by drop-id padding, for all
# four reduces, under the same kernel config. Deterministic sweep here;
# the randomized hypothesis version lives in test_serve_property.py.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
@pytest.mark.parametrize("ve", [(37, 120), (64, 64), (5, 0)])
def test_padding_invariance_mp(reduce, ve):
    g = synth_graph("det", *ve, feat=7, seed=11)
    p = pad_graph(g, ve[0] + 27, ve[1] + 40)
    plan = make_graph_plan(g.edge_index, g.num_nodes, config=CFG)
    plan_p = make_graph_plan(p.edge_index, p.num_nodes, config=CFG)
    want = mp(jnp.asarray(g.x), jnp.asarray(g.edge_index), g.num_nodes,
              reduce=reduce, plan=plan, impl="pallas")
    got = mp(jnp.asarray(p.x), jnp.asarray(p.edge_index), p.num_nodes,
             reduce=reduce, plan=plan_p, impl="pallas")
    np.testing.assert_allclose(unpad_nodes(p, got), want,
                               rtol=1e-5, atol=1e-5)


def test_padding_invariance_softmax():
    from repro.core import ops as geot
    g = synth_graph("det", 37, 120, feat=4, seed=11)
    p = pad_graph(g, 64, 160)
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((g.num_edges, 2)).astype(np.float32)
    pad = np.zeros((p.num_edges - g.num_edges, 2), np.float32)
    plan = make_graph_plan(g.edge_index, g.num_nodes, config=CFG)
    plan_p = make_graph_plan(p.edge_index, p.num_nodes, config=CFG)
    want = geot.segment_softmax(jnp.asarray(logits),
                                jnp.asarray(g.edge_index[1]), g.num_nodes,
                                "pallas", None, plan)
    got = geot.segment_softmax(jnp.asarray(np.concatenate([logits, pad])),
                               jnp.asarray(p.edge_index[1]), p.num_nodes,
                               "pallas", None, plan_p)
    np.testing.assert_allclose(unpad_edges(p, got), want,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fusion accounting scopes
# ---------------------------------------------------------------------------

def test_fusion_scope_isolates_and_accumulates():
    kops.reset_fusion_counts()
    kops.account("fused", "outer_op")
    with kops.fusion_scope() as inner:
        assert kops.fusion_counts() == {}          # scope starts clean
        kops.account("fused", "inner_op")
        with kops.fusion_scope() as nested:
            kops.account("unfused", "nested_op")
        assert dict(nested) == {"unfused:nested_op": 1}
        # nested events folded back into the enclosing scope
        assert inner["unfused:nested_op"] == 1
        assert inner["fused:inner_op"] == 1
    counts = kops.fusion_counts()                  # global accumulates all
    assert counts["fused:outer_op"] == 1
    assert counts["fused:inner_op"] == 1
    assert counts["unfused:nested_op"] == 1
    kops.reset_fusion_counts()


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def _entry(bucket):
    return BucketEntry(bucket, feat=16, config=CFG)


def test_plan_cache_lru_eviction():
    cache = PlanCache(capacity=2)
    b1, b2, b3 = (ShapeBucket(64, 64), ShapeBucket(64, 128),
                  ShapeBucket(128, 128))
    cache.get_or_build(b1, lambda: _entry(b1))
    cache.get_or_build(b2, lambda: _entry(b2))
    cache.get_or_build(b1, lambda: _entry(b1))     # touch b1 -> b2 is LRU
    cache.get_or_build(b3, lambda: _entry(b3))     # evicts b2
    assert cache.stats.evictions == 1
    assert set(cache.keys()) == {b1, b3}
    assert cache.lookup(b2) is None                # b2 is gone (miss)


def test_plan_cache_hit_accounting_and_weights():
    cache = PlanCache(capacity=4)
    b = ShapeBucket(64, 64)
    cache.get_or_build(b, lambda: _entry(b), weight=3)   # 3-request miss
    cache.get_or_build(b, lambda: _entry(b), weight=5)   # 5-request hit
    assert (cache.stats.hits, cache.stats.misses) == (5, 3)
    assert cache.stats.hit_rate == pytest.approx(5 / 8)
    assert cache.stats.plan_builds == 1
    assert cache.stats.plan_build_s > 0


def test_plan_cache_warm_is_not_a_miss():
    cache = PlanCache(capacity=4)
    b = ShapeBucket(64, 64)
    cache.warm(b, lambda: _entry(b))
    assert (cache.stats.hits, cache.stats.misses) == (0, 0)
    assert cache.stats.prefills == 1
    assert cache.lookup(b) is not None             # served as a hit
    assert cache.stats.hits == 1


def test_stamp_keeps_treedef_and_covers_any_member():
    """Stamped plans share the template's treedef (no retrace trigger) and
    the bucket-static max_chunks bounds every member's tight value."""
    b = ShapeBucket(128, 256)
    entry = _entry(b)
    g = synth_graph("g", 100, 200, feat=8, seed=4)
    p = pad_graph(g, 128, 256)
    plan = entry.stamp(p.edge_index[1])
    t1 = jax.tree_util.tree_structure(entry.template)
    t2 = jax.tree_util.tree_structure(plan)
    assert t1 == t2
    assert int(jnp.max(plan.chunk_count)) <= entry.max_chunks
    with pytest.raises(ValueError, match="padded edges"):
        entry.stamp(g.edge_index[1])               # unpadded: wrong length


def test_cache_hit_zero_make_plan_zero_compile(monkeypatch):
    """The acceptance property at unit scale: a second same-bucket request
    performs no plan construction, no config selection, and no trace."""
    import repro.core.heuristics as heuristics
    import repro.core.plan as plan_mod

    params = gnn.init(KEY, "gin", 8, 16, 4)
    srv = GNNServer(params, "gin", impl="pallas",
                    policy=BucketPolicy(min_nodes=32, min_edges=32))
    g1 = synth_graph("a", 30, 60, feat=8, seed=0)
    g2 = synth_graph("b", 25, 50, feat=8, seed=1)   # same (32, 64) bucket
    srv.submit(g1)
    srv.run_until_drained()
    assert srv.compiles == 1

    calls = {"make_plan": 0, "select_config": 0}
    real_mp, real_sc = plan_mod.make_plan, heuristics.select_config

    def spy_mp(*a, **k):
        calls["make_plan"] += 1
        return real_mp(*a, **k)

    def spy_sc(*a, **k):
        calls["select_config"] += 1
        return real_sc(*a, **k)

    monkeypatch.setattr(plan_mod, "make_plan", spy_mp)
    monkeypatch.setattr(heuristics, "select_config", spy_sc)
    srv.submit(g2)
    srv.run_until_drained()
    assert calls == {"make_plan": 0, "select_config": 0}
    assert srv.compiles == 1                        # zero new traces
    assert srv.cache.stats.hits == 1
    assert srv.results[1].cache_hit and not srv.results[1].compiled


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

def _req(uid, v, e, t=0.0):
    return GraphRequest(uid=uid, graph=synth_graph(f"r{uid}", v, e, feat=4,
                                                   seed=uid), t_submit=t)


def test_batcher_budget_and_fifo():
    b = GraphBatcher(max_batch_nodes=100, max_batch_graphs=8)
    for uid, v in enumerate([40, 40, 40, 10]):
        b.submit(_req(uid, v, 2 * v))
    first = b.next_batch(now=0.0)
    assert [r.uid for r in first] == [0, 1]         # 3rd would blow budget
    second = b.next_batch(now=0.0)
    assert [r.uid for r in second] == [2, 3]
    assert b.next_batch(now=0.0) == []


def test_batcher_oversize_singleton():
    b = GraphBatcher(max_batch_nodes=50)
    b.submit(_req(0, 200, 300))
    batch = b.next_batch(now=0.0)
    assert [r.uid for r in batch] == [0]


def test_batcher_edge_budget():
    b = GraphBatcher(max_batch_nodes=1000, max_batch_edges=100)
    b.submit(_req(0, 10, 80))
    b.submit(_req(1, 10, 80))
    assert [r.uid for r in b.next_batch(now=0.0)] == [0]


def test_batcher_deadline_holds_then_releases():
    b = GraphBatcher(max_batch_nodes=1000, max_batch_graphs=8,
                     max_wait_s=10.0)
    b.submit(_req(0, 10, 20, t=100.0))
    assert b.next_batch(now=100.1) == []            # under budget + deadline
    assert len(b.queue) == 1                        # requeued intact
    assert [r.uid for r in b.next_batch(now=110.1)] == [0]   # deadline hit
    b.submit(_req(1, 10, 20, t=200.0))
    assert [r.uid for r in b.next_batch(now=200.0, flush=True)] == [1]


def test_batcher_saturated_batch_releases_with_empty_queue():
    """A batch at the graph-count cap cannot grow; holding it for the
    deadline would be pure added latency."""
    b = GraphBatcher(max_batch_nodes=1000, max_batch_graphs=2,
                     max_wait_s=60.0)
    b.submit(_req(0, 10, 20, t=0.0))
    b.submit(_req(1, 10, 20, t=0.0))
    assert [r.uid for r in b.next_batch(now=0.1)] == [0, 1]


def test_batcher_releases_when_budget_full():
    b = GraphBatcher(max_batch_nodes=50, max_wait_s=1e9)
    b.submit(_req(0, 40, 60, t=0.0))
    b.submit(_req(1, 40, 60, t=0.0))
    # deadline far away, but the next request cannot fit: release now
    assert [r.uid for r in b.next_batch(now=0.0)] == [0]


# ---------------------------------------------------------------------------
# GNNServer end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", gnn.MODELS)
@pytest.mark.timeout(300)
def test_server_parity_all_models(model):
    """Served logits == direct per-request planned forward, compiles
    bounded by buckets, every request completes."""
    heads = 2 if model == "gat" else 1
    params = gnn.init(KEY, model, 8, 16, 4, heads=heads)
    srv = GNNServer(params, model, impl="pallas",
                    policy=BucketPolicy(min_nodes=32, min_edges=32),
                    max_batch_nodes=128, max_batch_graphs=3)
    rng = np.random.default_rng(0)
    graphs = [synth_graph(f"g{i}", int(rng.integers(16, 100)),
                          int(rng.integers(20, 250)), feat=8, seed=i)
              for i in range(6)]
    for g in graphs:
        srv.submit(g)
    srv.run_until_drained()
    s = srv.stats()
    assert len(srv.results) == 6
    assert s["compiles"] <= s["buckets"]
    for uid, g in enumerate(graphs):
        plan = g.make_plan(feat=16)
        want = gnn.forward(params, model, jnp.asarray(g.x),
                           jnp.asarray(g.edge_index), g.num_nodes,
                           jnp.asarray(g.deg_inv_sqrt), impl="pallas",
                           plan=plan)
        np.testing.assert_allclose(srv.results[uid].logits, np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert srv.results[uid].logits.shape == (g.num_nodes, 4)
        assert srv.results[uid].latency_s >= srv.results[uid].serve_s


@pytest.mark.timeout(120)
def test_server_warmup_makes_serving_hot():
    params = gnn.init(KEY, "sage", 8, 16, 4)
    srv = GNNServer(params, "sage", impl="pallas",
                    policy=BucketPolicy(min_nodes=32, min_edges=32),
                    max_batch_nodes=64, max_batch_graphs=1)
    # singleton batches => the request's own bucket, known a priori
    shapes = [(20, 40), (30, 100), (50, 200), (25, 60), (60, 180)]
    buckets = [ShapeBucket(32, 64), ShapeBucket(32, 128),
               ShapeBucket(64, 256)]
    assert srv.warmup(buckets) == 3
    assert srv.warmup(buckets) == 0                 # idempotent
    compiles_after_warmup = srv.compiles
    for i, (v, e) in enumerate(shapes):
        srv.submit(synth_graph(f"g{i}", v, e, feat=8, seed=i))
    srv.run_until_drained()
    s = srv.stats()
    assert srv.compiles == compiles_after_warmup    # serving traced nothing
    assert s["cache"]["hit_rate"] == 1.0
    assert s["cache"]["prefills"] == 3
    assert s["cache"]["misses"] == 0


@pytest.mark.timeout(120)
def test_server_warmup_tiny_bucket_and_capacity_guard():
    params = gnn.init(KEY, "gcn", 8, 16, 4)
    srv = GNNServer(params, "gcn", impl="pallas",
                    policy=BucketPolicy(min_nodes=1, min_edges=1))
    # a V=1 bucket is legal under min_nodes=1 and must warm cleanly
    assert srv.warmup([ShapeBucket(1, 1)]) == 1
    tiny = GNNServer(params, "gcn", cache_capacity=2)
    with pytest.raises(ValueError, match="capacity"):
        tiny.warmup([ShapeBucket(64, 64), ShapeBucket(64, 128),
                     ShapeBucket(128, 128)])


@pytest.mark.timeout(180)
def test_tuned_warmup_feeds_measured_lookup(tmp_path, monkeypatch):
    """tune=True sweeps land under the exact shape-class key (and DB) the
    serving-tier measured_config lookup reads back."""
    from repro.core.autotune import PerfDB
    from repro.serve import measured_config
    monkeypatch.setenv("REPRO_AUTOTUNE_MAX_CONFIGS", "3")
    monkeypatch.setenv("REPRO_AUTOTUNE_REPS", "1")
    db = PerfDB(tmp_path / "perfdb.json")
    params = gnn.init(KEY, "gin", 8, 16, 4)
    srv = GNNServer(params, "gin", impl="pallas", tune=True, perfdb=db,
                    policy=BucketPolicy(min_nodes=32, min_edges=32))
    b = ShapeBucket(32, 64)
    srv.warmup([b])
    cfg = measured_config(b, srv.feat, db=db)
    assert cfg is not None
    # a second engine on the same DB resolves the measured winner for free
    srv2 = GNNServer(params, "gin", impl="pallas", perfdb=db,
                     policy=BucketPolicy(min_nodes=32, min_edges=32))
    assert srv2._build_entry(b).config == cfg


@pytest.mark.timeout(120)
def test_server_empty_edge_and_tiny_graphs():
    params = gnn.init(KEY, "gcn", 8, 16, 4)
    srv = GNNServer(params, "gcn", impl="pallas",
                    policy=BucketPolicy(min_nodes=32, min_edges=32))
    g0 = synth_graph("iso", 5, 0, feat=8, seed=0)   # no edges at all
    g1 = synth_graph("one", 1, 0, feat=8, seed=1)
    srv.submit(g0)
    srv.submit(g1)
    srv.run_until_drained()
    want = gnn.forward(params, "gcn", jnp.asarray(g0.x),
                       jnp.asarray(g0.edge_index), g0.num_nodes,
                       jnp.asarray(g0.deg_inv_sqrt), impl="pallas",
                       plan=g0.make_plan(feat=16))
    np.testing.assert_allclose(srv.results[0].logits, np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert srv.results[1].logits.shape == (1, 4)


def test_server_rejects_padded_submission():
    params = gnn.init(KEY, "gcn", 8, 16, 4)
    srv = GNNServer(params, "gcn")
    g = pad_graph(synth_graph("g", 10, 20, feat=8, seed=0), 32, 32)
    with pytest.raises(ValueError, match="unpadded"):
        srv.submit(g)


@pytest.mark.timeout(120)
def test_server_rejects_duplicate_uid():
    params = gnn.init(KEY, "gcn", 8, 16, 4)
    srv = GNNServer(params, "gcn", impl="pallas",
                    policy=BucketPolicy(min_nodes=32, min_edges=32))
    g = synth_graph("g", 10, 20, feat=8, seed=0)
    srv.submit(g, uid=5)
    with pytest.raises(ValueError, match="duplicate"):
        srv.submit(g, uid=5)                       # still queued
    srv.run_until_drained()
    with pytest.raises(ValueError, match="duplicate"):
        srv.submit(g, uid=5)                       # already served


@pytest.mark.timeout(120)
def test_server_request_stats_and_throughput():
    params = gnn.init(KEY, "gin", 8, 16, 4)
    srv = GNNServer(params, "gin", impl="pallas",
                    policy=BucketPolicy(min_nodes=32, min_edges=32),
                    max_batch_nodes=256, max_batch_graphs=4)
    t0 = time.perf_counter()
    for i in range(4):
        srv.submit(synth_graph(f"g{i}", 40, 100, feat=8, seed=i))
    srv.run_until_drained()
    s = srv.stats()
    assert s["requests"] == 4 and s["batches"] >= 1
    assert s["throughput_rps"] > 0
    assert 0 < s["latency_mean_s"] <= s["latency_p95_s"] + 1e-9
    assert s["latency_p95_s"] < time.perf_counter() - t0 + 1.0
    assert s["pad_node_overhead"] >= 1.0 and s["pad_edge_overhead"] >= 1.0
    first = srv.results[0]
    assert first.batch_size >= 1 and first.bucket.num_nodes >= 32
    # the compiling batch carries a fused-kernel audit; GIN's aggregation
    # is one fused launch per layer, never an unfused fallback
    compile_steps = [r for r in srv.results.values() if r.compiled]
    assert compile_steps
    for r in compile_steps:
        assert any(k.startswith("fused:") for k in r.fusion)


# ---------------------------------------------------------------------------
# LM continuous batching (serve/lm.py — ported from the retired
# tests/test_serving.py when the serving/ shim package was removed):
# slot turnover, ragged positions, exact equivalence with serial decoding
# ---------------------------------------------------------------------------

from repro.models import lm  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.serve.lm import ContinuousBatcher, Request  # noqa: E402


def _lm_cfg():
    return ModelConfig("t", family="dense", num_layers=2, d_model=32,
                       num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                       vocab_size=128, dtype="float32", max_seq=64)


def _serial_decode(params, cfg, prompt, gen, max_len=32):
    """Reference: one request alone in a batch-1 batcher-free loop."""
    state = lm.init_decode_state(cfg, 1, max_len, jnp.float32)
    logits = None
    for t in prompt:
        logits, state = lm.decode_step(
            params, cfg, jnp.asarray([[t]], jnp.int32), state)
    out = []
    tok = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
    for _ in range(gen):
        out.append(tok)
        logits, state = lm.decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), state)
        tok = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
    return out


def test_batcher_matches_serial_decoding():
    cfg = _lm_cfg()
    params = lm.init(KEY, cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    gens = [4, 6, 3, 5]

    batcher = ContinuousBatcher(params, cfg, batch_size=2, max_len=32)
    for uid, (p, g) in enumerate(zip(prompts, gens)):
        batcher.submit(Request(uid=uid, prompt=p, max_new_tokens=g))
    finished = batcher.run_until_drained()

    assert set(finished) == {0, 1, 2, 3}
    for uid, (p, g) in enumerate(zip(prompts, gens)):
        want = _serial_decode(params, cfg, p, g)
        assert finished[uid] == want, (uid, finished[uid], want)


def test_batcher_slot_turnover():
    """More requests than slots: slots are reused mid-flight."""
    cfg = _lm_cfg()
    params = lm.init(KEY, cfg)
    rng = np.random.default_rng(1)
    batcher = ContinuousBatcher(params, cfg, batch_size=2, max_len=32)
    for uid in range(5):
        batcher.submit(Request(
            uid=uid, prompt=rng.integers(0, 128, 4).astype(np.int32),
            max_new_tokens=3))
    finished = batcher.run_until_drained()
    assert len(finished) == 5
    assert all(len(v) == 3 for v in finished.values())


def test_batcher_streams_tokens():
    cfg = _lm_cfg()
    params = lm.init(KEY, cfg)
    seen = []
    batcher = ContinuousBatcher(params, cfg, batch_size=1, max_len=32)
    batcher.submit(Request(
        uid=7, prompt=np.asarray([1, 2, 3], np.int32), max_new_tokens=4,
        on_token=lambda uid, tok: seen.append((uid, tok))))
    finished = batcher.run_until_drained()
    assert [t for _, t in seen] == finished[7]
    assert all(uid == 7 for uid, _ in seen)


def test_ragged_decode_matches_scalar_path():
    """decode_step(lengths=[n,n]) ≡ decode_step (shared counter) when all
    slots are aligned."""
    cfg = _lm_cfg()
    params = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    s1 = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    s2 = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    for t in range(6):
        lg1, s1 = lm.decode_step(params, cfg, toks[:, t:t + 1], s1)
        lg2, s2 = lm.decode_step(params, cfg, toks[:, t:t + 1], s2,
                                 lengths=jnp.full((2,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)
