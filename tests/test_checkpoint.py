"""Checkpoint: atomicity, retention, async, cross-process stability,
elastic restore."""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.models.params import P
from repro.optim.adamw import QTensor


def _tree():
    return {
        "w": P(jnp.arange(12.0).reshape(3, 4), ("embed", "mlp")),
        "opt": {"mu": QTensor(jnp.ones((3, 4), jnp.int8),
                              jnp.asarray(0.5, jnp.float32))},
        "step": jnp.asarray(7, jnp.int32),
        "bf": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 10)
    back = ckpt.restore(tree, tmp_path)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_atomicity_incomplete_ignored(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 10)
    # simulate a crash mid-save: directory without manifest
    (tmp_path / "step_20").mkdir()
    (tmp_path / "step_20" / "junk.npy").write_bytes(b"xx")
    assert ckpt.latest_step(tmp_path) == 10
    back = ckpt.restore(tree, tmp_path)
    assert int(back["step"]) == 7


def test_retention(tmp_path):
    tree = _tree()
    for s in (10, 20, 30):
        ckpt.save(tree, tmp_path, s, keep=2)
    assert not (tmp_path / "step_10").exists()
    assert ckpt.latest_step(tmp_path) == 30


def test_async_save(tmp_path):
    tree = _tree()
    th = ckpt.save_async(tree, tmp_path, 5)
    th.join()
    assert ckpt.latest_step(tmp_path) == 5


def test_shape_mismatch_rejected(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 1)
    bad = dict(tree, w=P(jnp.zeros((5, 4)), ("embed", "mlp")))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(bad, tmp_path)


def test_cross_process_restore(tmp_path):
    """Filenames must be stable across processes (hash salting regression)."""
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(tree, tmp_path, 3)
    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "import jax.numpy as jnp\n"
        "from repro.checkpoint import checkpoint as ckpt\n"
        f"t = ckpt.restore({{'a': jnp.zeros(4)}}, r'{tmp_path}')\n"
        "assert float(t['a'][3]) == 3.0\n"
        "print('OK')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=pathlib.Path(__file__).parents[1])
    assert "OK" in out.stdout, out.stderr


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto a different device layout (single-device here; the
    sharding argument path is the one the multi-pod restart uses)."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tree, tmp_path, 1)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    back = ckpt.restore(tree, tmp_path, shardings={"w": sh})
    assert back["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_latest_step_at_or_before(tmp_path):
    """The failure-recovery bound: never answer a step newer than the
    caller's failure point."""
    from repro.checkpoint import checkpoint as ckpt

    for s in (2, 5, 9):
        ckpt.save({"x": np.ones(3) * s}, tmp_path, s)
    assert ckpt.latest_step(tmp_path) == 9
    assert ckpt.latest_step(tmp_path, at_or_before=9) == 9
    assert ckpt.latest_step(tmp_path, at_or_before=5) == 5
    assert ckpt.latest_step(tmp_path, at_or_before=4) == 2
    assert ckpt.latest_step(tmp_path, at_or_before=1) is None
