"""Substrate: data determinism, optimizer convergence (all state dtypes),
gradient compression, schedules, fault-tolerance machinery."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.graphs import all_dataset_names, dataset, synth_graph
from repro.data.tokens import SyntheticTokens, TokenDatasetConfig
from repro.distributed.fault_tolerance import (ResilientLoop,
                                               ResilientLoopConfig,
                                               StepTimeout, StepWatchdog,
                                               StragglerMonitor)
from repro.optim import adamw, compression
from repro.optim.schedule import warmup_cosine


# ---- data -----------------------------------------------------------------

def test_token_data_deterministic_and_sharded():
    ds0 = SyntheticTokens(TokenDatasetConfig(256, 32, 8), host_id=0,
                          num_hosts=2)
    ds1 = SyntheticTokens(TokenDatasetConfig(256, 32, 8), host_id=1,
                          num_hosts=2)
    a, b = ds0.batch(5), ds0.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds0.batch(5)["tokens"], ds1.batch(5)["tokens"])
    assert a["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_token_data_learnable():
    """Markov structure: successor entropy ≪ vocab entropy."""
    ds = SyntheticTokens(TokenDatasetConfig(128, 64, 16, branching=2))
    b = ds.batch(0)
    follows = {}
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            follows.setdefault(int(t), set()).add(int(l))
    avg_succ = np.mean([len(v) for v in follows.values()])
    assert avg_succ <= 2.5, avg_succ


def test_graph_dataset_stats_match_table2():
    g = dataset("ogbn-arxiv", feat=4)
    assert g.num_nodes == 169_343 and g.num_edges == 1_166_243
    assert np.all(np.diff(g.edge_index[1]) >= 0)
    assert set(all_dataset_names()) >= {"cora", "reddit2", "flickr"}


def test_graph_power_law_skew():
    g = synth_graph("s", 2000, 20000, alpha=1.3)
    deg = np.bincount(g.edge_index[1], minlength=2000)
    assert deg.max() > 10 * max(deg.mean(), 1.0)


# ---- optimizer ------------------------------------------------------------

@pytest.mark.parametrize("sd", ["float32", "bfloat16", "int8"])
def test_adamw_converges(sd):
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype=sd)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    st = adamw.init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, st, _ = adamw.update(g, st, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.ones(4)}
    st = adamw.init(params, cfg)
    _, _, m = adamw.update({"w": jnp.full(4, 100.0)}, st, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    ef = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    n = 20
    for _ in range(n):
        c, ef = compression.compress(x, ef)
        acc = acc + compression.decompress(c)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(x),
                               atol=0.02)


def test_schedule_shape():
    assert float(warmup_cosine(0, 10, 100)) == 0.0
    assert float(warmup_cosine(10, 10, 100)) == pytest.approx(1.0)
    assert float(warmup_cosine(100, 10, 100)) == pytest.approx(0.1)


# ---- fault tolerance ------------------------------------------------------

def test_watchdog_times_out():
    wd = StepWatchdog(0.1)
    with pytest.raises(StepTimeout):
        wd.run(lambda: time.sleep(1.0))
    assert wd.run(lambda: 42) == 42


def test_straggler_escalates_to_evict():
    m = StragglerMonitor(factor=2.0, tolerance=2)
    for _ in range(10):
        m.record(1.0)
    assert m.record(10.0)["action"] == "warn"
    assert m.record(10.0)["action"] == "evict"


def test_resilient_loop_replays_exactly(tmp_path):
    """After a mid-run failure the loop restores and replays to the same
    final state as a failure-free run (deterministic data)."""
    def mk_step(fail_at=None):
        fired = {"done": False}

        def step(state, i):
            if fail_at is not None and i == fail_at and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("injected")
            return {"x": state["x"] * 1.5 + i}, {}
        return step

    clean = ResilientLoop(
        ResilientLoopConfig(str(tmp_path / "a"), ckpt_every=4),
        mk_step(None), {"x": jnp.ones(())})
    s_clean = clean.run(10)

    faulty = ResilientLoop(
        ResilientLoopConfig(str(tmp_path / "b"), ckpt_every=4),
        mk_step(fail_at=6), {"x": jnp.ones(())})
    s_faulty = faulty.run(10)
    assert float(s_clean["x"]) == pytest.approx(float(s_faulty["x"]))
    assert ("failure", 6, "RuntimeError('injected')") in faulty.events


def test_resilient_loop_gives_up(tmp_path):
    def step(state, i):
        raise RuntimeError("always down")
    loop = ResilientLoop(
        ResilientLoopConfig(str(tmp_path), max_restarts=2), step, {})
    with pytest.raises(RuntimeError, match="always down"):
        loop.run(3)


def test_watchdog_reaps_timed_out_threads():
    """Regression: a timed-out step's thread used to be dropped on the
    floor; the watchdog now tracks it and reaps it once it finishes."""
    wd = StepWatchdog(0.05)
    with pytest.raises(StepTimeout):
        wd.run(lambda: time.sleep(0.4))
    assert len(wd._timed_out) == 1
    deadline = time.monotonic() + 5.0
    while wd.reap() and time.monotonic() < deadline:
        time.sleep(0.02)
    assert wd.reap() == 0
    assert not wd._timed_out
    # and a later run() starts from a clean slate
    assert wd.run(lambda: 7) == 7


def test_resilient_restore_never_jumps_past_failure(tmp_path):
    """Regression: a checkpoint *newer* than the failed step (stale steps
    from an earlier run sharing the directory) must not be restored — it
    would jump the loop past its failure point with foreign state."""
    from repro.checkpoint import checkpoint as ckpt

    d = str(tmp_path / "shared")
    # an earlier run left a step-8 checkpoint with different state behind
    ckpt.save({"x": jnp.asarray(999.0)}, d, 8)

    def mk_step(fail_at):
        fired = {"done": False}

        def step(state, i):
            if i == fail_at and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("injected")
            return {"x": state["x"] * 1.5 + i}, {}
        return step

    loop = ResilientLoop(ResilientLoopConfig(d, ckpt_every=4),
                         mk_step(fail_at=3), {"x": jnp.ones(())})
    s = loop.run(6)

    clean = ResilientLoop(ResilientLoopConfig(str(tmp_path / "c"),
                                              ckpt_every=4),
                          mk_step(fail_at=None), {"x": jnp.ones(())})
    s_clean = clean.run(6)
    assert float(s["x"]) == pytest.approx(float(s_clean["x"]))
    # failure hit before the run's own first save: restored the entry
    # state, not the stale step-8 checkpoint
    assert ("restored_entry", 0) in loop.events
    assert ("restored", 8) not in loop.events


def test_resilient_loop_without_ckpt_dir(tmp_path):
    """ckpt_dir='' runs checkpoint-less: failures roll back to the entry
    state and nothing is ever written to disk."""
    def mk_step(fail_at):
        fired = {"done": False}

        def step(state, i):
            if i == fail_at and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("injected")
            return {"x": state["x"] * 1.5 + i}, {}
        return step

    loop = ResilientLoop(ResilientLoopConfig("", ckpt_every=2),
                         mk_step(fail_at=3), {"x": jnp.ones(())})
    s = loop.run(5)
    want = ResilientLoop(ResilientLoopConfig("", ckpt_every=2),
                         mk_step(fail_at=None), {"x": jnp.ones(())}).run(5)
    assert float(s["x"]) == pytest.approx(float(want["x"]))
    assert ("restored_entry", 0) in loop.events
    assert not any(e[0] == "saved" for e in loop.events)


def test_schedule_registry():
    from repro.optim import schedule

    assert schedule.get("warmup_cosine") is warmup_cosine
    assert float(schedule.get("constant")(50, 10, 100)) == 1.0
    assert float(schedule.get("constant")(5, 10, 100)) == pytest.approx(0.5)
    with pytest.raises(ValueError, match="unknown LR schedule"):
        schedule.get("nope")
