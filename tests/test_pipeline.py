"""Out-of-core pipeline: producer/prefetch determinism, PlanCache
thread-safety under concurrent producers, zero-retrace sampled training
through repro.fit, and the GNNServer sampled-ingest path."""
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.data.graphs import synth_graph
from repro.data.pipeline import (PrefetchPipeline, SampledBatch,
                                 SampledBatchProducer)
from repro.data.sampling import NeighborSampler
from repro.models import gnn
from repro.serve import GNNServer, PlanCache
from repro.serve.buckets import ShapeBucket
from repro.serve.plan_cache import BucketEntry, bucket_max_chunks
from repro.train import SampledNodeProvider

KEY = jax.random.PRNGKey(0)
G = synth_graph("pipe", 256, 1024, feat=16, num_classes=8, seed=3)


def _sampler(**kw):
    kw.setdefault("fanouts", (4, 3))
    kw.setdefault("batch_size", 16)
    kw.setdefault("seed", 7)
    return NeighborSampler(G, **kw)


# ---------------------------------------------------------------------------
# producer
# ---------------------------------------------------------------------------

def test_producer_batch_contents():
    prod = SampledBatchProducer(_sampler(), feat=32)
    b = prod.produce(0)
    assert isinstance(b, SampledBatch)
    v, e = b.bucket.num_nodes, b.bucket.num_edges
    assert b.graph.num_nodes == v and b.graph.num_edges == e
    assert b.arrays["x"].shape == (v, 16)
    assert b.arrays["edge_index"].shape == (2, e)
    # label_mask is 1.0 exactly on the seed rows
    mask = np.asarray(b.arrays["label_mask"])
    np.testing.assert_array_equal(mask, (np.arange(v) < b.num_seeds)
                                  .astype(np.float32))
    # the plan carries the bucket entry's static aux (treedef sharing)
    entry = prod.entry_for(b.bucket)
    assert b.plan.max_chunks == entry.max_chunks
    assert b.plan.config == entry.config
    assert b.plan.stats == entry.template.stats


def test_same_bucket_batches_share_treedef():
    prod = SampledBatchProducer(_sampler(), feat=32)
    batches = [prod.produce(s) for s in range(6)]
    by_bucket: dict = {}
    for b in batches:
        by_bucket.setdefault(b.bucket, []).append(b)
    shared = [v for v in by_bucket.values() if len(v) > 1]
    assert shared, "expected at least one bucket to repeat within 6 steps"
    for group in shared:
        d0 = jax.tree_util.tree_structure((group[0].arrays, group[0].plan))
        for b in group[1:]:
            assert jax.tree_util.tree_structure((b.arrays, b.plan)) == d0
    # one plan build per distinct bucket, not per batch
    assert prod.cache.stats.plan_builds == len(by_bucket)


# ---------------------------------------------------------------------------
# prefetch pipeline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth,threads", [(1, 1), (2, 2), (3, 4)])
def test_prefetch_equals_blocking(depth, threads):
    """Any depth/thread combination yields the bit-identical batch stream
    of the synchronous loader."""
    ref_prod = SampledBatchProducer(_sampler(), feat=32)
    ref = [ref_prod.produce(s) for s in range(6)]
    prod = SampledBatchProducer(_sampler(), feat=32)
    with PrefetchPipeline(prod, depth=depth, num_threads=threads) as pipe:
        for s in range(6):
            b = pipe.batch(s)
            assert b.bucket == ref[s].bucket
            assert b.num_seeds == ref[s].num_seeds
            for k in ("x", "edge_index", "labels", "label_mask"):
                np.testing.assert_array_equal(np.asarray(b.arrays[k]),
                                              np.asarray(ref[s].arrays[k]))
        stats = pipe.stats()
        assert stats["batches"] == 6
        assert stats["sync_falls"] == 1          # cold start only


def test_prefetch_random_access_falls_back():
    prod = SampledBatchProducer(_sampler(), feat=32)
    with PrefetchPipeline(prod, depth=2) as pipe:
        pipe.batch(0)
        b = pipe.batch(10)                        # out of window: sync
        assert b.step == 10
        assert pipe.sync_falls == 2
        ref = SampledBatchProducer(_sampler(), feat=32).produce(10)
        np.testing.assert_array_equal(np.asarray(b.arrays["edge_index"]),
                                      np.asarray(ref.arrays["edge_index"]))


def test_pipeline_close_is_idempotent_and_final():
    prod = SampledBatchProducer(_sampler(), feat=32)
    pipe = PrefetchPipeline(prod, depth=2)
    pipe.batch(0)
    pipe.close()
    pipe.close()
    with pytest.raises(RuntimeError, match="closed"):
        pipe.batch(1)


def test_depth0_is_blocking():
    prod = SampledBatchProducer(_sampler(), feat=32)
    with PrefetchPipeline(prod, depth=0) as pipe:
        assert pipe._pool is None
        b = pipe.batch(0)
        assert b.wait_s >= b.produce_s * 0.5      # nothing hidden

        assert pipe.stats()["overlap"] <= 0.5


# ---------------------------------------------------------------------------
# PlanCache thread-safety (satellite regression)
# ---------------------------------------------------------------------------

def test_plan_cache_concurrent_get_or_build():
    """N threads racing on M keys must build each entry exactly once and
    lose no counter increments — the invariant the async producer's
    zero-retrace accounting rests on."""
    cache = PlanCache(capacity=32)
    from repro.core.heuristics import select_config
    buckets = [ShapeBucket(64 << i, 256 << i) for i in range(4)]

    def build(b):
        cfg = select_config(b.num_edges, min(b.num_edges, b.num_nodes), 64,
                            tune=False)
        return BucketEntry(b, 64, cfg,
                           max_chunks=bucket_max_chunks(b, cfg))

    built: dict = {}
    lock = threading.Lock()

    def hammer(tid):
        out = []
        for i in range(40):
            b = buckets[(tid + i) % len(buckets)]
            e = cache.get_or_build(b, lambda b=b: build(b))
            with lock:
                prev = built.setdefault(b, e)
            assert prev is e, "two threads built the same key"
            out.append(e)
        return out

    with ThreadPoolExecutor(max_workers=8) as pool:
        list(pool.map(hammer, range(8)))
    assert cache.stats.plan_builds == len(buckets)
    assert cache.stats.misses == len(buckets)
    assert cache.stats.lookups == 8 * 40
    assert len(cache) == len(buckets)


def test_plan_cache_concurrent_eviction_consistency():
    cache = PlanCache(capacity=2)
    from repro.core.heuristics import select_config
    cfg = select_config(256, 64, 64, tune=False)

    def build(i):
        b = ShapeBucket(64, 256)
        return BucketEntry(b, 64, cfg, max_chunks=bucket_max_chunks(b, cfg))

    def hammer(tid):
        for i in range(60):
            cache.get_or_build((tid + i) % 5, lambda i=i: build(i))

    with ThreadPoolExecutor(max_workers=6) as pool:
        list(pool.map(hammer, range(6)))
    assert len(cache) == 2                        # capacity respected
    s = cache.stats
    assert s.evictions == s.plan_builds - len(cache)
    assert s.hits + s.misses == 6 * 60


# ---------------------------------------------------------------------------
# training integration
# ---------------------------------------------------------------------------

def test_sampled_training_zero_retraces():
    with SampledNodeProvider(G, fanouts=(4, 3), batch_size=32, plan_feat=64,
                             depth=2, seed=5) as data:
        task = repro.NodeClassification.from_provider(data, model="gcn",
                                                      hidden=64,
                                                      impl="pallas")
        res = repro.fit(task, data, repro.TrainerConfig(steps=20))
        assert res.traces == len(res.buckets)
        assert all(s.sampled for s in res.buckets)
        assert np.all(np.isfinite(res.losses))
        stats = data.stats()
        assert stats["batches"] == 20
        # one plan build per distinct bucket across producer threads
        assert stats["cache"]["plan_builds"] == len(res.buckets)


def test_sampled_loss_ignores_non_seed_rows():
    """The masked loss is a function of the seed rows only: perturbing a
    neighbor row's label must not change it."""
    task = repro.NodeClassification(model="gcn", d_in=16, hidden=32,
                                    num_classes=8, num_layers=2, impl="ref")
    params = task.init(KEY)
    prod = SampledBatchProducer(_sampler(), feat=32)
    b = prod.produce(0)
    arrays, static = task.prepare(b)
    assert static.sampled
    loss1, m1 = task.loss(params, arrays, static, KEY)
    labels = np.asarray(arrays["labels"]).copy()
    labels[b.num_seeds:] = (labels[b.num_seeds:] + 1) % 8
    arrays2 = dict(arrays, labels=jnp.asarray(labels))
    loss2, m2 = task.loss(params, arrays2, static, KEY)
    assert float(loss1) == pytest.approx(float(loss2), abs=1e-7)
    assert float(m1["accuracy"]) == pytest.approx(float(m2["accuracy"]),
                                                  abs=1e-7)


def test_sampled_rejects_mesh_and_typed():
    task = repro.NodeClassification(model="rgcn", d_in=16, num_classes=8)
    prod = SampledBatchProducer(_sampler(), feat=32)
    b = prod.produce(0)
    with pytest.raises(ValueError, match="relational"):
        task.prepare(b)


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_serve_sampled_parity_and_single_compile():
    params = gnn.init(KEY, "gcn", 16, 32, 8, num_layers=2)
    server = GNNServer(params, "gcn", impl="pallas", feat=32)
    with server.sampled_pipeline(_sampler(), depth=2) as pipe:
        for step in range(6):
            b = pipe.batch(step)
            logits = server.serve_sampled(b)
            assert logits.shape == (b.num_seeds, 8)
            ref = gnn.forward(params, "gcn", jnp.asarray(b.graph.x),
                              jnp.asarray(b.graph.edge_index),
                              b.graph.num_nodes,
                              jnp.asarray(b.graph.deg_inv_sqrt), impl="ref")
            np.testing.assert_allclose(logits,
                                       np.asarray(ref)[:b.num_seeds],
                                       atol=1e-4)
    # producer threads + serving loop shared one cache: one compile per
    # bucket, total
    assert server.compiles == len(server.cache)


def test_serve_sampled_foreign_batch_restamps():
    """A batch produced against its own (non-engine) cache may carry a
    different canonical config; serve_sampled must re-stamp rather than
    retrace-or-crash."""
    params = gnn.init(KEY, "gcn", 16, 32, 8, num_layers=2)
    server = GNNServer(params, "gcn", impl="pallas", feat=32)
    prod = SampledBatchProducer(_sampler(), feat=128)   # different feat
    b = prod.produce(0)
    logits = server.serve_sampled(b)
    assert logits.shape == (b.num_seeds, 8)
    compiles_before = server.compiles
    server.serve_sampled(prod.produce(1))
    assert server.compiles == compiles_before
