"""SegmentPlan (precomputed reduction schedules): plan-vs-planless
equivalence across impls, tight grid bounds on skewed/gapped inputs,
block-diagonal multi-graph batching, and grads through plan-carrying ops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core.config_space import KernelConfig
from repro.core.plan import make_graph_plan, make_plan
from repro.data.graphs import batch_graphs, synth_graph, unbatch_nodes
from repro.kernels import ops as kops, ref
from repro.models import gnn

RNG = np.random.default_rng(11)
CFG = KernelConfig("SR", 32, 128, 64, 1)
CFG_PR = KernelConfig("PR", 32, 128, 64, 8)


def _skewed_idx(m=600, s=50, heavy=400):
    """One segment owns `heavy` of the m rows — power-law-style imbalance."""
    idx = np.concatenate([np.zeros(heavy, np.int32),
                          RNG.integers(1, s, m - heavy).astype(np.int32)])
    return np.sort(idx), s


def _gapped_idx(m=300, s=500):
    """Occupied ids far apart: most segments empty."""
    return np.sort(RNG.choice(np.arange(0, s, 7), m)).astype(np.int32), s


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------

def test_plan_tight_max_chunks_on_skew():
    idx, s = _skewed_idx()
    plan = make_plan(idx, s, feat=16, config=CFG)
    m_pad = (len(idx) + CFG.m_b - 1) // CFG.m_b * CFG.m_b
    assert plan.worst_case_chunks == m_pad // CFG.m_b
    # the acceptance bound: the planned grid is strictly tighter than the
    # plan-less worst case on a skewed graph
    assert plan.max_chunks < m_pad // CFG.m_b
    assert plan.grid_savings > 1.0
    assert plan.stats.max_degree == 400


def test_plan_metadata_matches_kernel_metadata():
    from repro.kernels.segment_reduce import chunk_metadata
    for make in (_skewed_idx, _gapped_idx):
        idx, s = make()
        plan = make_plan(idx, s, feat=16, config=CFG)
        m_pad = (len(idx) + CFG.m_b - 1) // CFG.m_b * CFG.m_b
        idxp = jnp.pad(jnp.asarray(idx), (0, m_pad - len(idx)),
                       constant_values=s)
        cf, cc = chunk_metadata(idxp, s, CFG.s_b, CFG.m_b, m_pad)
        np.testing.assert_array_equal(np.asarray(plan.chunk_first),
                                      np.asarray(cf))
        np.testing.assert_array_equal(np.asarray(plan.chunk_count),
                                      np.asarray(cc))
        assert plan.max_chunks == max(1, int(np.asarray(cc).max()))


def test_plan_rejects_unsorted_and_mismatched():
    with pytest.raises(ValueError):
        make_plan(np.array([3, 1, 2], np.int32), 5)
    idx, s = _skewed_idx()
    plan = make_plan(idx, s, feat=16, config=CFG)
    with pytest.raises(ValueError):
        kops.segment_reduce(jnp.zeros((7, 8)), jnp.zeros(7, jnp.int32), s,
                            plan=plan, interpret=True)
    with pytest.raises(ValueError):   # conflicting explicit tiling
        kops.segment_reduce(jnp.zeros((len(idx), 8)), jnp.asarray(idx), s,
                            config=KernelConfig("SR", 64, 128, 128, 1),
                            plan=plan, interpret=True)


def test_plan_is_a_pytree():
    idx, s = _skewed_idx()
    plan = make_plan(idx, s, feat=16, config=CFG)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    assert len(leaves) == 2
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert plan2.max_chunks == plan.max_chunks
    assert plan2.config == plan.config


# ---------------------------------------------------------------------------
# plan-vs-planless equivalence: all three reduces × ref/blocked/pallas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "blocked", "pallas"])
@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
def test_segment_reduce_plan_equivalence(impl, reduce):
    for make in (_skewed_idx, _gapped_idx):
        idx, s = make()
        x = jnp.asarray(RNG.standard_normal((len(idx), 24)), jnp.float32)
        plan = make_plan(idx, s, feat=24, config=CFG)
        planless = ops.segment_reduce(x, jnp.asarray(idx), s, reduce, impl,
                                      CFG)
        planned = ops.segment_reduce(x, jnp.asarray(idx), s, reduce, impl,
                                     None, plan)
        pa, pb = np.asarray(planless), np.asarray(planned)
        mask = np.isfinite(pa)
        assert np.array_equal(np.isfinite(pb), mask)
        np.testing.assert_allclose(pb[mask], pa[mask], rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("impl", ["ref", "blocked", "pallas"])
def test_index_segment_reduce_plan_equivalence(impl):
    idx, s = _skewed_idx()
    m, v, n = len(idx), 80, 24
    gidx = jnp.asarray(RNG.integers(0, v, m).astype(np.int32))
    h = jnp.asarray(RNG.standard_normal((v, n)), jnp.float32)
    plan = make_plan(idx, s, feat=n, config=CFG)
    for reduce in ("sum", "mean", "max"):
        planless = ops.index_segment_reduce(h, gidx, jnp.asarray(idx), s,
                                            reduce, impl, CFG)
        planned = ops.index_segment_reduce(h, gidx, jnp.asarray(idx), s,
                                           reduce, impl, None, plan)
        pa, pb = np.asarray(planless), np.asarray(planned)
        mask = np.isfinite(pa)       # max: empty segments are -inf
        assert np.array_equal(np.isfinite(pb), mask)
        np.testing.assert_allclose(pb[mask], pa[mask], rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("impl", ["ref", "blocked", "pallas"])
def test_index_weight_segment_reduce_plan_equivalence(impl):
    idx, s = _skewed_idx()
    m, v, n = len(idx), 80, 24
    gidx = jnp.asarray(RNG.integers(0, v, m).astype(np.int32))
    w = jnp.asarray(RNG.standard_normal(m), jnp.float32)
    h = jnp.asarray(RNG.standard_normal((v, n)), jnp.float32)
    plan = make_plan(idx, s, feat=n, config=CFG)
    planless = ops.index_weight_segment_reduce(h, gidx, w, jnp.asarray(idx),
                                               s, "sum", impl, CFG)
    planned = ops.index_weight_segment_reduce(h, gidx, w, jnp.asarray(idx),
                                              s, "sum", impl, None, plan)
    np.testing.assert_allclose(np.asarray(planned), np.asarray(planless),
                               rtol=3e-4, atol=3e-4)


def test_pallas_pr_schedule_with_plan():
    idx, s = _skewed_idx()
    x = jnp.asarray(RNG.standard_normal((len(idx), 24)), jnp.float32)
    plan = make_plan(idx, s, feat=24, config=CFG_PR)
    got = kops.segment_reduce(x, jnp.asarray(idx), s, "sum", plan=plan,
                              interpret=True)
    want = ref.segment_reduce(x, jnp.asarray(idx), s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# grads through plan-carrying ops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "blocked", "pallas"])
def test_grad_through_plan(impl):
    idx, s = _skewed_idx(m=300, s=30, heavy=200)
    m, v, n = len(idx), 40, 16
    gidx = jnp.asarray(RNG.integers(0, v, m).astype(np.int32))
    w = jnp.asarray(RNG.standard_normal(m), jnp.float32)
    h = jnp.asarray(RNG.standard_normal((v, n)), jnp.float32)
    plan = make_plan(idx, s, feat=n, config=CFG)

    def f(h, w, plan_, impl_):
        y = ops.index_weight_segment_reduce(h, gidx, w, jnp.asarray(idx), s,
                                            "sum", impl_, None, plan_)
        return jnp.sum(y ** 2)

    dh, dw = jax.grad(f, argnums=(0, 1))(h, w, plan, impl)
    dh_ref, dw_ref = jax.grad(f, argnums=(0, 1))(h, w, None, "ref")
    np.testing.assert_allclose(np.asarray(dh), np.asarray(dh_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=1e-3, atol=1e-3)


def test_segment_reduce_grad_with_plan_inside_jit():
    idx, s = _skewed_idx(m=300, s=30, heavy=200)
    x = jnp.asarray(RNG.standard_normal((len(idx), 16)), jnp.float32)
    plan = make_plan(idx, s, feat=16, config=CFG)

    @jax.jit
    def g(x, plan):
        return jax.grad(lambda x: ops.segment_reduce(
            x, jnp.asarray(idx), s, "sum", "pallas", None, plan).sum())(x)

    np.testing.assert_allclose(np.asarray(g(x, plan)),
                               np.ones_like(np.asarray(x)), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end GNN: pallas + plan matches ref, forward and backward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "gin", "sage", "gat"])
def test_gnn_pallas_plan_matches_ref(model):
    g = synth_graph("t", 60, 300, feat=8, seed=3)
    plan = g.make_plan(feat=16, config=CFG)
    assert plan.num_segments == g.num_nodes
    params = gnn.init(jax.random.PRNGKey(0), model, 8, 16, 4)
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    dis = jnp.asarray(g.deg_inv_sqrt)
    want = gnn.forward(params, model, x, ei, g.num_nodes, dis, impl="ref")
    got = gnn.forward(params, model, x, ei, g.num_nodes, dis, impl="pallas",
                      plan=plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    labels = jnp.asarray(g.labels % 4)
    g_ref = jax.grad(gnn.loss_fn)(params, model, x, ei, labels, g.num_nodes,
                                  dis, "ref")
    g_pal = jax.grad(gnn.loss_fn)(params, model, x, ei, labels, g.num_nodes,
                                  dis, "pallas", plan)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# block-diagonal multi-graph batching
# ---------------------------------------------------------------------------

def test_batch_graphs_structure():
    gs = [synth_graph(f"g{i}", 20 + 10 * i, 80 + 40 * i, feat=8, seed=i)
          for i in range(3)]
    b = batch_graphs(gs)
    assert b.num_graphs == 3
    assert b.num_nodes == sum(g.num_nodes for g in gs)
    assert b.num_edges == sum(g.num_edges for g in gs)
    dst = b.edge_index[1]
    assert (dst[1:] >= dst[:-1]).all(), "batched destinations must stay sorted"
    # every edge stays within its member graph's node-id block
    for i, g in enumerate(gs):
        e0, e1 = b.edge_ptr[i], b.edge_ptr[i + 1]
        blk = b.edge_index[:, e0:e1]
        assert (blk >= b.node_ptr[i]).all() and (blk < b.node_ptr[i + 1]).all()


@pytest.mark.parametrize("model", ["gcn", "gin", "sage", "gat"])
def test_batched_forward_matches_per_graph(model):
    gs = [synth_graph(f"g{i}", 25 + 5 * i, 90 + 30 * i, feat=8, seed=10 + i)
          for i in range(3)]
    b = batch_graphs(gs)
    plan = b.make_plan(feat=16, config=CFG)
    params = gnn.init(jax.random.PRNGKey(1), model, 8, 16, 4)

    out_b = gnn.forward(params, model, jnp.asarray(b.x),
                        jnp.asarray(b.edge_index), b.num_nodes,
                        jnp.asarray(b.deg_inv_sqrt), impl="pallas", plan=plan)
    parts = unbatch_nodes(b, np.asarray(out_b))
    for g, part in zip(gs, parts):
        want = gnn.forward(params, model, jnp.asarray(g.x),
                           jnp.asarray(g.edge_index), g.num_nodes,
                           jnp.asarray(g.deg_inv_sqrt), impl="ref")
        np.testing.assert_allclose(part, np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_batched_backward_matches_per_graph():
    gs = [synth_graph(f"g{i}", 25, 90, feat=8, seed=20 + i) for i in range(2)]
    b = batch_graphs(gs)
    plan = b.make_plan(feat=16, config=CFG)
    params = gnn.init(jax.random.PRNGKey(2), "gcn", 8, 16, 4)
    labels_b = jnp.asarray(b.labels % 4)

    g_batched = jax.grad(gnn.loss_fn)(params, "gcn", jnp.asarray(b.x),
                                      jnp.asarray(b.edge_index), labels_b,
                                      b.num_nodes,
                                      jnp.asarray(b.deg_inv_sqrt),
                                      "pallas", plan)
    # mean CE over the batch == weighted mean of per-graph mean CEs
    total = sum(g.num_nodes for g in gs)

    def per_graph_loss(params):
        acc = 0.0
        for g in gs:
            acc = acc + (g.num_nodes / total) * gnn.loss_fn(
                params, "gcn", jnp.asarray(g.x), jnp.asarray(g.edge_index),
                jnp.asarray(g.labels % 4), g.num_nodes,
                jnp.asarray(g.deg_inv_sqrt), "ref")
        return acc

    g_loop = jax.grad(per_graph_loss)(params)
    for a, b_ in zip(jax.tree_util.tree_leaves(g_loop),
                     jax.tree_util.tree_leaves(g_batched)):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_unbatch_edges_round_trip():
    """batch_graphs → unbatch_edges recovers every member's edges (after
    removing the node-id offsets), mirroring unbatch_nodes."""
    from repro.data.graphs import unbatch_edges
    gs = [synth_graph(f"g{i}", 20 + 7 * i, 60 + 25 * i, feat=4, seed=40 + i)
          for i in range(3)]
    b = batch_graphs(gs)
    parts = unbatch_edges(b, b.edge_index.T)        # (E_total, 2) per-edge
    assert len(parts) == len(gs)
    for i, (g, part) in enumerate(zip(gs, parts)):
        np.testing.assert_array_equal(
            part.T - b.node_ptr[i], g.edge_index)
    # per-edge payloads split on the same boundaries
    w = np.arange(b.num_edges, dtype=np.float32)
    for i, part in enumerate(unbatch_edges(b, w)):
        np.testing.assert_array_equal(
            part, w[b.edge_ptr[i]:b.edge_ptr[i + 1]])
    # single (unbatched) graph: identity
    assert unbatch_edges(gs[0], w)[0] is w


def test_graph_plan_batched_has_tight_grid():
    """The batched graph keeps per-member skew visible to the plan."""
    gs = [synth_graph(f"g{i}", 50, 400, feat=8, seed=30 + i, alpha=1.2)
          for i in range(4)]
    b = batch_graphs(gs)
    plan = make_graph_plan(b.edge_index, b.num_nodes, feat=16, config=CFG)
    assert plan.max_chunks < plan.worst_case_chunks
    x = jnp.asarray(RNG.standard_normal((b.num_edges, 8)), jnp.float32)
    got = kops.segment_reduce(x, jnp.asarray(b.edge_index[1]), b.num_nodes,
                              "sum", plan=plan, interpret=True)
    want = ref.segment_reduce(x, jnp.asarray(b.edge_index[1]), b.num_nodes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
