"""Observability subsystem: registry semantics, export formats, span
trees, attribution, and the regression contracts the PR pinned —
thread-safe fusion counters, well-defined cold/reset engine stats, and
schema stability of the exported metric set."""
import json
import threading

import jax
import numpy as np
import pytest

import repro
from repro import obs
from repro.kernels import ops as kops
from repro.obs.registry import MetricsRegistry


@pytest.fixture(autouse=True)
def clean_obs():
    """Every test starts enabled with a zeroed registry / span ring /
    event ring, and leaves the switch enabled for the next test."""
    obs.enable()
    obs.reset()
    yield
    obs.enable()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_gauge_labels_and_values():
    reg = MetricsRegistry()
    c = reg.counter("t.count", ("who",))
    c.inc(who="a")
    c.inc(2.5, who="a")
    c.inc(who="b")
    assert c.value(who="a") == 3.5
    assert c.value(who="b") == 1.0
    assert c.value(who="nobody") == 0.0          # unseen series reads 0
    g = reg.gauge("t.level", ())
    g.set(7)
    g.set(3)
    assert g.value() == 3.0


def test_registration_idempotent_and_mismatch_raises():
    reg = MetricsRegistry()
    a = reg.counter("t.c", ("x",))
    assert reg.counter("t.c", ("x",)) is a
    with pytest.raises(ValueError):
        reg.gauge("t.c", ("x",))                 # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("t.c", ("y",))               # label mismatch
    with pytest.raises(ValueError):
        a.inc(y=1)                               # wrong label name


def test_histogram_exact_percentiles_and_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("t.lat", (), buckets=(10.0, 50.0, 100.0))
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count() == 100
    assert h.total() == sum(range(1, 101))
    assert h.percentile(50) == 50.0              # exact, not interpolated
    assert h.percentile(95) == 95.0
    assert h.percentile(99) == 99.0
    s = h.series()
    assert s.counts == [10, 40, 50, 0]           # <=10, <=50, <=100, +inf


def test_snapshot_and_delta():
    reg = MetricsRegistry()
    c = reg.counter("t.c", ("k",))
    h = reg.histogram("t.h", ())
    c.inc(3, k="a")
    h.observe(1.0)
    snap = reg.snapshot()
    assert {r["name"] for r in snap} == {"t.c", "t.h"}
    hist_row = next(r for r in snap if r["name"] == "t.h")
    assert hist_row["count"] == 1 and "p95" in hist_row
    c.inc(2, k="a")
    h.observe(4.0)
    d = {r["name"]: r for r in reg.delta(snap)}
    assert d["t.c"]["value"] == 2.0              # windowed, not cumulative
    assert d["t.h"]["count"] == 1 and d["t.h"]["sum"] == 4.0


def test_reset_keeps_instrument_handles():
    reg = MetricsRegistry()
    c = reg.counter("t.c", ())
    c.inc(5)
    reg.reset()
    assert c.value() == 0.0
    c.inc()                                      # old handle still live
    assert c.value() == 1.0


def test_disabled_mode_vital_vs_optional():
    reg = MetricsRegistry()
    vital = reg.counter("t.vital", (), vital=True)
    opt = reg.counter("t.opt", ())
    obs.disable()
    try:
        vital.inc()
        opt.inc()
        with obs.span("t.stage") as s:
            s.set(ignored=True)                  # null span: no-op
        assert vital.value() == 1.0              # vital always counts
        assert opt.value() == 0.0                # optional is a no-op
        assert obs.spans("t.stage") == []        # no span recorded
    finally:
        obs.enable()


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def test_jsonl_export_parses_and_stamps():
    reg = MetricsRegistry()
    reg.counter("t.c", ("k",)).inc(k="a")
    lines = obs.to_jsonl(reg).splitlines()
    rows = [json.loads(ln) for ln in lines]
    kinds = [r["record"] for r in rows]
    assert "metric" in kinds and kinds[-1] == "meta"
    m = next(r for r in rows if r["record"] == "metric")
    assert m["name"] == "t.c" and m["labels"] == {"k": "a"}


def test_prometheus_export_format():
    reg = MetricsRegistry()
    reg.counter("serve.plan_cache.hits", ("cache",)).inc(5, cache="c0")
    reg.histogram("t.lat", (), buckets=(1.0, 2.0)).observe(1.5)
    text = obs.to_prometheus(reg)
    assert 'repro_serve_plan_cache_hits{cache="c0"} 5.0' in text
    assert "# TYPE repro_serve_plan_cache_hits counter" in text
    assert 'repro_t_lat_bucket{le="2.0"} 1' in text
    assert "repro_t_lat_count 1" in text


def test_write_jsonl_atomic_and_flusher(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    obs.get_registry().counter("t.flush", (), vital=True).inc()
    obs.start_flusher(path, every_s=3600)        # no tick: final write only
    obs.stop_flusher()
    rows = [json.loads(ln) for ln in open(path).read().splitlines()]
    assert any(r.get("name") == "t.flush" for r in rows)


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_tree_nesting_and_ring():
    with obs.span("root", step=1) as r:
        with obs.span("child.a"):
            with obs.span("leaf"):
                pass
        with obs.span("child.b"):
            pass
    roots = obs.spans("root")
    assert len(roots) == 1 and roots[0] is r
    assert r.stages() == {"root", "child.a", "leaf", "child.b"}
    assert r.find("leaf").name == "leaf"
    assert [c.name for c in r.children] == ["child.a", "child.b"]
    assert r.dur_s >= r.children[0].dur_s >= 0.0
    assert r.attrs == {"step": 1}


def test_thread_span_trees_do_not_interleave():
    def worker():
        with obs.span("worker.root"):
            with obs.span("worker.leaf"):
                pass

    with obs.span("main.root"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    main = obs.spans("main.root")[0]
    work = obs.spans("worker.root")[0]
    assert main.stages() == {"main.root"}        # worker never attached
    assert work.stages() == {"worker.root", "worker.leaf"}


def test_chrome_trace_export_valid():
    with obs.span("outer", bucket="B(64,128)"):
        with obs.span("inner"):
            pass
    doc = obs.chrome_trace()
    json.dumps(doc)                              # must be serializable
    events = doc["traceEvents"]
    assert {e["name"] for e in events} == {"outer", "inner"}
    for e in events:
        assert e["ph"] == "X"
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
    outer = next(e for e in events if e["name"] == "outer")
    assert outer["args"]["bucket"] == "B(64,128)"


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

def test_attribution_records_and_counters():
    obs.record_compile("serve.forward", "bucket_miss", bucket="B(64,128)")
    obs.record_compile("train.step", "new_bucket", static="sig")
    obs.record_tune("segment_reduce", cache_hit=False, timings=8)
    obs.record_tune("segment_reduce", cache_hit=True)
    compiles = obs.why_compiled()
    assert [e["cause"] for e in compiles] == ["bucket_miss", "new_bucket"]
    assert compiles[0]["bucket"] == "B(64,128)"
    reg = obs.get_registry()
    assert reg.get("compile.events").value(
        site="serve.forward", cause="bucket_miss") == 1.0
    assert reg.get("autotune.tunes").value(
        op="segment_reduce", outcome="sweep") == 1.0
    assert reg.get("autotune.tunes").value(
        op="segment_reduce", outcome="hit") == 1.0
    assert obs.attributions("tune")[0]["timings"] == 8


# ---------------------------------------------------------------------------
# satellite: thread-safe fusion counters (kernels/ops.py)
# ---------------------------------------------------------------------------

def test_fusion_account_concurrent_no_lost_updates():
    kops.reset_fusion_counts()
    n_threads, per_thread = 8, 200

    def hammer():
        for _ in range(per_thread):
            kops.account("fused", "concurrency_test")

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    counts = kops.fusion_counts()
    assert counts["fused:concurrency_test"] == n_threads * per_thread
    kops.reset_fusion_counts()


def test_fusion_scope_isolated_from_other_threads():
    """A scope opened in one thread must never capture launches accounted
    from other threads (prefetch producers) — they fold into the global."""
    kops.reset_fusion_counts()
    started, release = threading.Event(), threading.Event()

    def producer():
        started.set()
        release.wait(timeout=5)
        kops.account("fused", "producer_op")

    t = threading.Thread(target=producer)
    t.start()
    started.wait(timeout=5)
    with kops.fusion_scope() as mine:
        kops.account("fused", "my_op")
        release.set()
        t.join()
        assert dict(mine) == {"fused:my_op": 1}  # producer's not captured
    assert kops.fusion_counts()["fused:producer_op"] == 1
    assert kops.fusion_counts()["fused:my_op"] == 1
    kops.reset_fusion_counts()


def test_fusion_launches_mirrored_to_registry():
    before = obs.get_registry().counter(
        "kernel.launches", ("kind", "op")).value(
        kind="fused", op="mirror_test")
    kops.account("fused", "mirror_test")
    after = obs.get_registry().get("kernel.launches").value(
        kind="fused", op="mirror_test")
    assert after == before + 1
    kops.reset_fusion_counts()


# ---------------------------------------------------------------------------
# satellite: engine cold stats + reset parity
# ---------------------------------------------------------------------------

def _tiny_server(**kw):
    params = repro.gnn_init(jax.random.PRNGKey(0), "gcn", 8, 16, 4)
    return repro.GNNServer(params, "gcn", **kw)


def test_server_cold_stats_well_defined():
    srv = _tiny_server()
    st = srv.stats()
    assert st["requests"] == 0 and st["batches"] == 0
    assert st["compiles"] == 0 and st["buckets"] == 0
    assert st["mean_batch_size"] == 0.0
    assert st["throughput_rps"] == 0.0
    assert st["latency_mean_s"] == 0.0 and st["latency_p95_s"] == 0.0
    assert st["pad_node_overhead"] == 1.0        # no padding observed
    assert st["pad_edge_overhead"] == 1.0
    assert st["cache"]["hit_rate"] == 0.0
    for v in st.values():                        # nothing NaN anywhere
        if isinstance(v, float):
            assert np.isfinite(v)


def test_server_reset_returns_to_cold_window():
    srv = _tiny_server(max_batch_graphs=4)
    for i in range(4):
        srv.submit(repro.synth_graph(f"g{i}", 16, 48, feat=8))
    srv.run_until_drained()
    busy = srv.stats()
    assert busy["requests"] == 4 and busy["batches"] >= 1
    assert busy["compiles"] >= 1
    kept_buckets = busy["buckets"]
    srv.reset()
    st = srv.stats()
    assert st["requests"] == 0 and st["batches"] == 0
    assert st["compiles"] == 0 and st["throughput_rps"] == 0.0
    assert st["latency_mean_s"] == 0.0
    assert st["pad_node_overhead"] == 1.0
    assert st["buckets"] == kept_buckets         # cache lines survive
    assert srv.results == {}
    # the kept executables still serve without recompiling
    srv.submit(repro.synth_graph("again", 16, 48, feat=8))
    srv.run_until_drained()
    assert srv.stats()["requests"] == 1


# ---------------------------------------------------------------------------
# satellite: schema stability
# ---------------------------------------------------------------------------

def test_exported_schema_is_exactly_the_documented_set(tmp_path):
    """Exercise every instrumented subsystem, then assert the registry's
    exported names + label sets are exactly repro.obs.OBS_SCHEMA — a
    rename or an undocumented metric breaks here first."""
    # serving (engine + batcher + plan cache + kernel launches + compile)
    srv = _tiny_server(max_batch_graphs=4)
    for i in range(3):
        srv.submit(repro.synth_graph(f"s{i}", 16, 48, feat=8))
    srv.run_until_drained()
    # out-of-core pipeline (producer + prefetch counters)
    big = repro.synth_graph("ooc", 128, 512, feat=8, num_classes=4)
    sampler = repro.NeighborSampler(big, fanouts=(4,), batch_size=8, seed=0)
    producer = repro.SampledBatchProducer(sampler, feat=8)
    producer.buckets_for_warmup(probe_steps=2)
    with repro.PrefetchPipeline(producer, depth=0) as pipe:
        pipe.batch(0)
    # training
    data = repro.GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=1,
                                    feat=8, num_classes=4)
    task = repro.NodeClassification.from_provider(data, model="gcn",
                                                  hidden=8)
    repro.fit(task, data, repro.TrainerConfig(steps=1))
    # autotune attribution (measure_fn: no kernels actually timed)
    from repro.core import autotune
    db = autotune.PerfDB(str(tmp_path / "perfdb"))
    autotune.tune(idx_size=64, num_segments=32, feat=8, db=db,
                  measure_fn=lambda cfg: 1.0)

    schema = obs.get_registry().schema()
    # instruments registered under the test-local "t." namespace (this
    # file) are excluded: registration is process-permanent by design
    exported = {n: tuple(labels) for n, labels in schema.items()
                if not n.startswith("t.")}
    assert exported == obs.OBS_SCHEMA


def test_jsonl_dump_matches_schema(tmp_path):
    srv = _tiny_server(max_batch_graphs=2)
    srv.submit(repro.synth_graph("g", 16, 48, feat=8))
    srv.run_until_drained()
    path = str(tmp_path / "m.jsonl")
    obs.write_jsonl(path)
    for ln in open(path).read().splitlines():
        row = json.loads(ln)
        if row["record"] != "metric":
            continue
        assert row["name"] in obs.OBS_SCHEMA
        assert set(row["labels"]) == set(obs.OBS_SCHEMA[row["name"]])


# ---------------------------------------------------------------------------
# acceptance: complete span trees + attribution through the real paths
# ---------------------------------------------------------------------------

def test_serving_request_span_tree_complete():
    srv = _tiny_server(max_batch_graphs=2)
    srv.submit(repro.synth_graph("a", 16, 48, feat=8))
    srv.run_until_drained()                      # cold: pays the compile
    srv.submit(repro.synth_graph("b", 16, 48, feat=8))
    srv.run_until_drained()                      # warm: cache hit
    roots = obs.spans("serve.step")
    assert len(roots) == 2
    cold, warm = roots
    assert {"serve.batch", "serve.pad", "serve.plan_cache", "serve.stamp",
            "serve.compile"} <= cold.stages()
    assert "serve.execute" in warm.stages()      # no recompile stage
    assert "serve.compile" not in warm.stages()
    assert "bucket" in cold.attrs
    # every compile carries an attribution naming bucket and cause
    compiles = obs.why_compiled()
    assert len(compiles) == srv.compiles >= 1
    for e in compiles:
        assert e["site"] == "serve.forward"
        assert e["cause"] == "bucket_miss"
        assert "bucket" in e and "engine" in e
    json.dumps(obs.chrome_trace(roots))          # exportable


def test_warmup_compiles_attributed_as_warmup():
    from repro.serve import bucket_for
    srv = _tiny_server()
    srv.warmup([bucket_for(16, 48, srv.policy)])
    assert [e["cause"] for e in obs.why_compiled()] == ["warmup"]


def test_training_step_span_tree_complete():
    data = repro.GraphEpochProvider(shapes=((32, 96),), graphs_per_shape=1,
                                    feat=8, num_classes=4)
    task = repro.NodeClassification.from_provider(data, model="gcn",
                                                  hidden=8)
    res = repro.fit(task, data, repro.TrainerConfig(steps=2))
    assert res.traces == 1
    roots = obs.spans("train.step")
    assert len(roots) == 2
    first, second = roots
    assert {"train.sample", "train.prepare",
            "train.compile"} <= first.stages()
    assert "train.execute" in second.stages()
    assert "train.compile" not in second.stages()
    compiles = obs.why_compiled()
    assert [e["cause"] for e in compiles] == ["new_bucket"]
    assert compiles[0]["site"] == "train.step"
    json.dumps(obs.chrome_trace(roots))


def test_pipeline_produce_span_tree_complete():
    big = repro.synth_graph("ooc", 128, 512, feat=8, num_classes=4)
    sampler = repro.NeighborSampler(big, fanouts=(4,), batch_size=8, seed=0)
    producer = repro.SampledBatchProducer(sampler, feat=8)
    with repro.PrefetchPipeline(producer, depth=0) as pipe:
        pipe.batch(0)
    root = obs.spans("pipeline.produce")[0]
    assert {"pipeline.sample", "pipeline.pad", "pipeline.plan_cache",
            "pipeline.stamp", "pipeline.device_put"} <= root.stages()
    assert "bucket" in root.attrs


def test_report_smoke():
    srv = _tiny_server(max_batch_graphs=2)
    srv.submit(repro.synth_graph("g", 16, 48, feat=8))
    srv.run_until_drained()
    text = obs.report()
    assert "serve.requests" in text
    assert "compile" in text
