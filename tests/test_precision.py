"""Mixed-precision (io dtype) contract across the kernel stack.

Every kernel carries its io dtype end to end — bf16 in ⇒ bf16 out — while
accumulating in fp32 (kernel scratch, MXU preferred_element_type, and the
custom-VJP scatter-adds). Parity is checked against the *cast-then-reduce*
fp32 oracle (upcast the io-dtype inputs, reduce in fp32) at dtype-tiered
tolerances:

    fp32  ≤ 1e-5 relative   (same-precision accumulation, near-exact)
    bf16  ≤ 2e-2 relative   (8-bit mantissa io, fp32 accumulate)

Covers: all four reduce families (sum/mean/max × weighted) + softmax,
mixed x-bf16/weight-fp32, bf16 grads and grads-of-grads through the custom
VJPs, the fused transform-reduce (forward + grads), segment_matmul / sddmm
dtype honoring, and the fused kernel's VMEM ``fusable`` gate. A hypothesis
sweep (CI) fuzzes shapes × dtypes over the same oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core.config_space import KernelConfig
from repro.core.mp import choose_order, mp

RNG = np.random.default_rng(31)
CFG = KernelConfig("SR", 64, 128, 64, 1)
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
            else dict(rtol=1e-5, atol=1e-5))


def _graph(v=70, e=340, f=12, seed=0):
    rng = np.random.default_rng(seed)
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    src = rng.integers(0, v, e).astype(np.int32)
    x32 = rng.standard_normal((v, f)).astype(np.float32)
    w32 = rng.standard_normal(e).astype(np.float32)
    return jnp.asarray(src), jnp.asarray(dst), jnp.asarray(x32), \
        jnp.asarray(w32), v


def _reduce_oracle(h, gidx, weight, seg, s, reduce):
    """Cast-then-reduce in fp32: the precision baseline every io dtype is
    measured against."""
    msg = jnp.take(h.astype(jnp.float32), gidx, axis=0)
    if weight is not None:
        msg = msg * weight.astype(jnp.float32)[:, None]
    if reduce == "max":
        out = jax.ops.segment_max(msg, seg, s, indices_are_sorted=True)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    out = jax.ops.segment_sum(msg, seg, s, indices_are_sorted=True)
    if reduce == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(seg, jnp.float32), seg, s,
                                  indices_are_sorted=True)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


# ---------------------------------------------------------------------------
# forward parity: every reduce family × weighted × io dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
def test_gather_reduce_io_dtype(dtype, reduce, weighted):
    src, dst, x32, w32, v = _graph(seed=1)
    x = x32.astype(dtype)
    w = w32.astype(dtype) if weighted else None
    if weighted:
        got = ops.index_weight_segment_reduce(x, src, w, dst, v, reduce,
                                              "pallas", CFG)
    else:
        got = ops.index_segment_reduce(x, src, dst, v, reduce, "pallas", CFG)
    assert got.dtype == dtype, "io dtype must survive the kernel"
    want = _reduce_oracle(x, src, w, dst, v, reduce)
    if reduce == "max":
        got = jnp.where(jnp.isneginf(got.astype(jnp.float32)),
                        jnp.zeros((), jnp.float32),
                        got.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_segment_softmax_io_dtype(dtype):
    rng = np.random.default_rng(2)
    m, s, heads = 300, 40, 4
    idx = jnp.asarray(np.sort(rng.integers(0, s, m)).astype(np.int32))
    e = jnp.asarray(rng.standard_normal((m, heads)) * 5.0, dtype)
    p = ops.segment_softmax(e, idx, s, "pallas", CFG)
    assert p.dtype == dtype
    m_ = jax.ops.segment_max(e.astype(jnp.float32), idx, s,
                             indices_are_sorted=True)
    m_ = jnp.where(jnp.isfinite(m_), m_, 0.0)
    z = jnp.exp(e.astype(jnp.float32) - jnp.take(m_, idx, axis=0))
    denom = jax.ops.segment_sum(z, idx, s, indices_are_sorted=True)
    want = z / jnp.take(jnp.maximum(denom, 1e-20), idx, axis=0)
    np.testing.assert_allclose(np.asarray(p, np.float32), np.asarray(want),
                               **_tol(dtype))
    # live segments still sum to 1 within the io dtype's resolution
    sums = jax.ops.segment_sum(p.astype(jnp.float32), idx, s,
                               indices_are_sorted=True)
    live = np.unique(np.asarray(idx))
    np.testing.assert_allclose(np.asarray(sums)[live], 1.0,
                               **_tol(dtype))


def test_mixed_bf16_x_fp32_weight():
    """x in bf16 with fp32 edge weights (the GCN normalizer pattern): the
    kernel pads/carries each operand in its own dtype and accumulates fp32;
    output follows x's io dtype."""
    src, dst, x32, w32, v = _graph(seed=3)
    x = x32.astype(jnp.bfloat16)
    got = ops.index_weight_segment_reduce(x, src, w32, dst, v, "sum",
                                          "pallas", CFG)
    assert got.dtype == jnp.bfloat16
    want = _reduce_oracle(x, src, w32, dst, v, "sum")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(jnp.bfloat16))


# ---------------------------------------------------------------------------
# grads: fp32 accumulation inside the custom VJPs, io dtype on the way out
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
def test_bf16_grads_match_fp32_oracle(dtype, reduce):
    """A *linear* loss pins the cotangent exactly (a nonlinear loss would
    re-amplify the forward's io-dtype rounding through its derivative and
    measure that instead of the VJP): what remains is purely the custom
    VJP's scatter/weight/cast path, which must hold the tiered tolerance
    against both the same-dtype ref impl and the all-fp32 oracle."""
    src, dst, x32, w32, v = _graph(seed=4)
    c = jnp.asarray(np.random.default_rng(14)
                    .standard_normal((v, x32.shape[1])).astype(np.float32))

    def loss(x, w, impl):
        y = mp(x, jnp.stack([src, dst]), v, reduce=reduce, edge_weight=w,
               impl=impl, config=CFG)
        return jnp.vdot(c, y.astype(jnp.float32))

    for weighted in (False, True):
        x = x32.astype(dtype)
        w = w32.astype(dtype) if weighted else None
        gx, gw = jax.grad(loss, (0, 1))(x, w, "pallas") if weighted else \
            (jax.grad(loss, (0,))(x, w, "pallas")[0], None)
        assert gx.dtype == dtype, "grads come back in the input's io dtype"
        # kernel-VJP parity at the *same* io dtype
        gref = jax.grad(loss, (0,))(x, w, "ref")[0]
        np.testing.assert_allclose(np.asarray(gx, np.float32),
                                   np.asarray(gref, np.float32),
                                   **_tol(dtype))
        # and against the all-fp32 oracle at the tiered tolerance — except
        # max, whose subgradient *routing* legitimately changes when bf16
        # rounding moves which edge attains the maximum (the same-dtype
        # check above already pins the VJP)
        if reduce != "max" or dtype == jnp.float32:
            gx32 = jax.grad(loss, (0,))(x32, w32 if weighted else None,
                                        "ref")[0]
            np.testing.assert_allclose(np.asarray(gx, np.float32),
                                       np.asarray(gx32), **_tol(dtype))
        if weighted:
            assert gw.dtype == dtype


def test_bf16_grad_of_grad():
    """Second-order (HVP) through the custom VJPs at bf16 io: the backward
    pass is itself built from differentiable segment ops, so grad-of-grad
    must both run and stay near the fp32 oracle."""
    src, dst, x32, _, v = _graph(v=40, e=160, f=8, seed=5)
    ei = jnp.stack([src, dst])
    vec32 = jnp.asarray(np.random.default_rng(6)
                        .standard_normal(x32.shape).astype(np.float32))

    def make_hvp(impl, dtype):
        def loss(x):
            y = mp(x.astype(dtype), ei, v, reduce="sum", impl=impl,
                   config=CFG)
            return jnp.sum(jnp.sin(y.astype(jnp.float32)))

        def hvp(x, vec):
            return jax.grad(
                lambda x_: jnp.vdot(jax.grad(loss)(x_).astype(jnp.float32),
                                    vec))(x)
        return hvp

    got = np.asarray(make_hvp("pallas", jnp.bfloat16)(x32, vec32),
                     np.float32)
    want = np.asarray(make_hvp("ref", jnp.float32)(x32, vec32), np.float32)
    # norm-relative: the curvature term sin(y) re-amplifies the forward's
    # bf16 rounding per element, so element-wise rtol would measure the
    # loss surface's sharpness, not the VJP chain being tested
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 2e-2, f"HVP norm-relative error {rel:.3e} exceeds bf16 tier"


# ---------------------------------------------------------------------------
# fused transform-reduce: forward + grads, both io dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("reduce", ["sum", "mean"])
@pytest.mark.parametrize("weighted", [False, True])
def test_fused_transform_reduce_io_dtype(dtype, reduce, weighted):
    src, dst, x32, w32, v = _graph(seed=7)
    d_out = 24
    wm32 = jnp.asarray(np.random.default_rng(8)
                       .standard_normal((x32.shape[1], d_out))
                       .astype(np.float32) / 4.0)
    x, wm = x32.astype(dtype), wm32.astype(dtype)
    ew = w32.astype(dtype) if weighted else None
    got = ops.fused_transform_reduce(x, wm, src, ew, dst, v, reduce,
                                     "pallas", CFG)
    assert got.dtype == dtype
    agg = _reduce_oracle(x, src, ew, dst, v, reduce)
    # the kernel's documented contract casts the fp32 aggregate to the io
    # dtype once, right before the MXU transform (its native operand
    # width) — the oracle models the same cast
    want = agg.astype(dtype).astype(jnp.float32) @ wm.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_transform_reduce_grads(dtype):
    src, dst, x32, w32, v = _graph(seed=9)
    wm32 = jnp.asarray(np.random.default_rng(10)
                       .standard_normal((x32.shape[1], 16))
                       .astype(np.float32) / 4.0)
    c = jnp.asarray(np.random.default_rng(15)
                    .standard_normal((v, 16)).astype(np.float32))

    def loss(x, wm, ew, impl):
        y = ops.fused_transform_reduce(x, wm, src, ew, dst, v, "mean",
                                       impl, CFG)
        # linear loss: the cotangent is exact, so the comparison isolates
        # the fused custom-VJP path (see test_bf16_grads_match_fp32_oracle)
        return jnp.vdot(c, y.astype(jnp.float32))

    args = (x32.astype(dtype), wm32.astype(dtype), w32.astype(dtype))
    grads = jax.grad(loss, (0, 1, 2))(*args, "pallas")
    for g, a in zip(grads, args):
        assert g.dtype == a.dtype
    want = jax.grad(loss, (0, 1, 2))(x32, wm32, w32, "ref")
    for g, w_ in zip(grads, want):
        ga, wa = np.asarray(g, np.float32), np.asarray(w_, np.float32)
        # norm-relative: dW contracts the bf16-rounded recomputed aggregate
        # over every segment, so a single element can exceed an element-wise
        # tier while the tensor stays well inside it
        rel = np.linalg.norm(ga - wa) / max(np.linalg.norm(wa), 1e-12)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        assert rel < tol, f"grad norm-relative error {rel:.3e}"


def test_fusable_gates_vmem():
    """The fused kernel's VMEM predicate: small layers fit, absurd widths
    don't — the pallas wrapper raises past the budget and choose_order never
    volunteers an unfusable arm."""
    from repro.kernels.fused_transform_reduce import fusable
    assert fusable(64, 64, jnp.float32, CFG)
    assert not fusable(4096, 4096, jnp.float32, CFG)
    # bf16 halves the W-tile/staging bytes ⇒ never *less* fusable than fp32
    for d in (256, 512, 1024, 2048):
        assert fusable(d, d, jnp.bfloat16, CFG) or \
            not fusable(d, d, jnp.float32, CFG)
    src, dst, x32, _, v = _graph(seed=11)
    with pytest.raises(ValueError, match="VMEM"):
        from repro.kernels import ops as kops
        kops.fused_transform_reduce(
            jnp.zeros((v, 4096), jnp.float32), jnp.zeros((4096, 4096)),
            src, dst, v, config=CFG)
    assert choose_order(4096, 4096, num_edges=int(src.shape[0]),
                        num_nodes=v, config=CFG,
                        allow_fused=True) != "fused"


# ---------------------------------------------------------------------------
# matmul-family kernels honor the io dtype (fp32-accumulate contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
def test_segment_matmul_io_dtype(dtype):
    rng = np.random.default_rng(12)
    sizes = np.array([40, 0, 25, 63], np.int32)
    m, g = int(sizes.sum()), len(sizes)
    x = jnp.asarray(rng.standard_normal((m, 24)), dtype)
    w = jnp.asarray(rng.standard_normal((g, 24, 16)) / 5.0, dtype)
    out = ops.grouped_segment_matmul(x, jnp.asarray(sizes), w, "pallas")
    assert out.dtype == dtype, "output follows the input io dtype"
    want, off = [], 0
    for i, n in enumerate(sizes):
        want.append(x[off:off + n].astype(jnp.float32)
                    @ w[i].astype(jnp.float32))
        off += n
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.concatenate(want)),
                               **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_sddmm_io_dtype(dtype):
    rng = np.random.default_rng(13)
    v, m, f = 50, 220, 24
    a = jnp.asarray(rng.standard_normal((v, f)), dtype)
    b = jnp.asarray(rng.standard_normal((v, f)), dtype)
    row = jnp.asarray(rng.integers(0, v, m).astype(np.int32))
    col = jnp.asarray(rng.integers(0, v, m).astype(np.int32))
    out = ops.sddmm(a, b, row, col, "pallas", CFG)
    assert out.dtype == dtype, "fp32-accumulate / input-dtype-out"
    want = jnp.sum(jnp.take(a.astype(jnp.float32), row, axis=0)
                   * jnp.take(b.astype(jnp.float32), col, axis=0), axis=-1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), **_tol(dtype))


# ---------------------------------------------------------------------------
# hypothesis sweep (CI): shapes × dtype × reduce against the same oracle
# ---------------------------------------------------------------------------

def test_precision_sweep_deterministic():
    """Container-friendly stand-in for the hypothesis sweep below: a fixed
    lattice of shapes × dtype × reduce against the cast-then-reduce oracle
    (hypothesis is a CI-only dependency)."""
    for seed, (v, e, f) in enumerate([(17, 60, 5), (90, 500, 33),
                                      (3, 9, 1), (128, 128, 128)]):
        src, dst, x32, w32, v = _graph(v=v, e=e, f=f, seed=40 + seed)
        for dtype in DTYPES:
            for reduce in ("sum", "mean"):
                x = x32.astype(dtype)
                got = ops.index_segment_reduce(x, src, dst, v, reduce,
                                               "pallas", CFG)
                want = _reduce_oracle(x, src, None, dst, v, reduce)
                np.testing.assert_allclose(np.asarray(got, np.float32),
                                           np.asarray(want), **_tol(dtype))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 200), st.integers(1, 60), st.integers(1, 40),
           st.integers(0, 2 ** 16), st.booleans(),
           st.sampled_from(["sum", "mean", "max"]))
    def test_precision_sweep_hypothesis(e, v, f, seed, use_bf16, reduce):
        rng = np.random.default_rng(seed)
        dst = jnp.asarray(np.sort(rng.integers(0, v, e)).astype(np.int32))
        src = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
        dtype = jnp.bfloat16 if use_bf16 else jnp.float32
        x = jnp.asarray(rng.standard_normal((v, f)), dtype)
        got = ops.index_segment_reduce(x, src, dst, v, reduce, "pallas", CFG)
        assert got.dtype == dtype
        want = _reduce_oracle(x, src, None, dst, v, reduce)
        g32 = got.astype(jnp.float32)
        if reduce == "max":
            g32 = jnp.where(jnp.isneginf(g32), 0.0, g32)
        np.testing.assert_allclose(np.asarray(g32), np.asarray(want),
                                   **_tol(dtype))
