"""Continuous-batching scheduler: slot turnover, ragged positions, and
exact equivalence with independent (one-request-at-a-time) decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.config import ModelConfig
from repro.serve.lm import ContinuousBatcher, Request

KEY = jax.random.PRNGKey(0)


def _cfg():
    return ModelConfig("t", family="dense", num_layers=2, d_model=32,
                       num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                       vocab_size=128, dtype="float32", max_seq=64)


def _serial_decode(params, cfg, prompt, gen, max_len=32):
    """Reference: one request alone in a batch-1 batcher-free loop."""
    state = lm.init_decode_state(cfg, 1, max_len, jnp.float32)
    logits = None
    for t in prompt:
        logits, state = lm.decode_step(
            params, cfg, jnp.asarray([[t]], jnp.int32), state)
    out = []
    tok = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
    for _ in range(gen):
        out.append(tok)
        logits, state = lm.decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), state)
        tok = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
    return out


def test_batcher_matches_serial_decoding():
    cfg = _cfg()
    params = lm.init(KEY, cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    gens = [4, 6, 3, 5]

    batcher = ContinuousBatcher(params, cfg, batch_size=2, max_len=32)
    for uid, (p, g) in enumerate(zip(prompts, gens)):
        batcher.submit(Request(uid=uid, prompt=p, max_new_tokens=g))
    finished = batcher.run_until_drained()

    assert set(finished) == {0, 1, 2, 3}
    for uid, (p, g) in enumerate(zip(prompts, gens)):
        want = _serial_decode(params, cfg, p, g)
        assert finished[uid] == want, (uid, finished[uid], want)


def test_batcher_slot_turnover():
    """More requests than slots: slots are reused mid-flight."""
    cfg = _cfg()
    params = lm.init(KEY, cfg)
    rng = np.random.default_rng(1)
    batcher = ContinuousBatcher(params, cfg, batch_size=2, max_len=32)
    for uid in range(5):
        batcher.submit(Request(
            uid=uid, prompt=rng.integers(0, 128, 4).astype(np.int32),
            max_new_tokens=3))
    finished = batcher.run_until_drained()
    assert len(finished) == 5
    assert all(len(v) == 3 for v in finished.values())


def test_batcher_streams_tokens():
    cfg = _cfg()
    params = lm.init(KEY, cfg)
    seen = []
    batcher = ContinuousBatcher(params, cfg, batch_size=1, max_len=32)
    batcher.submit(Request(
        uid=7, prompt=np.asarray([1, 2, 3], np.int32), max_new_tokens=4,
        on_token=lambda uid, tok: seen.append((uid, tok))))
    finished = batcher.run_until_drained()
    assert [t for _, t in seen] == finished[7]
    assert all(uid == 7 for uid, _ in seen)


def test_ragged_decode_matches_scalar_path():
    """decode_step(lengths=[n,n]) ≡ decode_step (shared counter) when all
    slots are aligned."""
    cfg = _cfg()
    params = lm.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    s1 = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    s2 = lm.init_decode_state(cfg, 2, 16, jnp.float32)
    for t in range(6):
        lg1, s1 = lm.decode_step(params, cfg, toks[:, t:t + 1], s1)
        lg2, s2 = lm.decode_step(params, cfg, toks[:, t:t + 1], s2,
                                 lengths=jnp.full((2,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)
