"""Core GeoT ops: blocked algorithm vs oracle, autograd, fusion ops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core.config_space import KernelConfig

RNG = np.random.default_rng(0)


def _case(m, s, n, dtype=np.float32):
    idx = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    x = RNG.standard_normal((m, n)).astype(dtype)
    return jnp.asarray(x), jnp.asarray(idx)


CASES = [(1000, 100, 32), (517, 50, 7), (2048, 3, 128), (64, 64, 1),
         (300, 290, 16), (1, 1, 5), (128, 1, 64)]


@pytest.mark.parametrize("m,s,n", CASES)
@pytest.mark.parametrize("sched", ["SR", "PR"])
def test_blocked_matches_ref_sum(m, s, n, sched):
    x, idx = _case(m, s, n)
    ref = ops.segment_reduce(x, idx, s, "sum", "ref")
    for mb in (64, 256):
        cfg = KernelConfig(sched, 128, 128, mb, 8)
        out = ops.segment_reduce(x, idx, s, "sum", "blocked", cfg)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("reduce", ["mean", "max"])
def test_blocked_mean_max(reduce):
    x, idx = _case(777, 91, 9)
    ref = ops.segment_reduce(x, idx, 91, reduce, "ref")
    out = ops.segment_reduce(x, idx, 91, reduce, "blocked",
                             KernelConfig("SR", 128, 128, 128, 1))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_index_segment_reduce_matches_compose():
    h = jnp.asarray(RNG.standard_normal((40, 16)).astype(np.float32))
    gidx = jnp.asarray(RNG.integers(0, 40, 200).astype(np.int32))
    seg = jnp.asarray(np.sort(RNG.integers(0, 30, 200)).astype(np.int32))
    fused = ops.index_segment_reduce(h, gidx, seg, 30)
    composed = ops.segment_reduce(jnp.take(h, gidx, axis=0), seg, 30)
    np.testing.assert_allclose(fused, composed, rtol=1e-6)


def test_index_weight_segment_reduce_is_spmm():
    """The fused weighted op == dense A @ H with A the COO matrix (§IV)."""
    v, s, m, n = 30, 25, 150, 8
    h = RNG.standard_normal((v, n)).astype(np.float32)
    gidx = RNG.integers(0, v, m).astype(np.int32)
    seg = np.sort(RNG.integers(0, s, m)).astype(np.int32)
    w = RNG.standard_normal(m).astype(np.float32)
    a = np.zeros((s, v), np.float32)
    for i in range(m):
        a[seg[i], gidx[i]] += w[i]
    want = a @ h
    got = ops.index_weight_segment_reduce(
        jnp.asarray(h), jnp.asarray(gidx), jnp.asarray(w), jnp.asarray(seg), s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
def test_segment_reduce_grad(reduce):
    x, idx = _case(200, 40, 8)

    def f(x):
        return jnp.sum(jnp.sin(ops.segment_reduce(x, idx, 40, reduce)))

    def f_ref(x):
        from repro.kernels import ref
        return jnp.sum(jnp.sin(ref.segment_reduce(x, idx, 40, reduce)))

    g = jax.grad(f)(x)
    g_ref = jax.grad(f_ref)(x)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-5)


def test_fused_op_grads_match_reference():
    v, s, m, n = 25, 20, 120, 6
    h = jnp.asarray(RNG.standard_normal((v, n)).astype(np.float32))
    gidx = jnp.asarray(RNG.integers(0, v, m).astype(np.int32))
    seg = jnp.asarray(np.sort(RNG.integers(0, s, m)).astype(np.int32))
    w = jnp.asarray(RNG.standard_normal(m).astype(np.float32))

    def f(h, w):
        y = ops.index_weight_segment_reduce(h, gidx, w, seg, s)
        return jnp.sum(y ** 2)

    def f_ref(h, w):
        y = jax.ops.segment_sum(h[gidx] * w[:, None], seg, s)
        return jnp.sum(y ** 2)

    for got, want in zip(jax.grad(f, (0, 1))(h, w),
                         jax.grad(f_ref, (0, 1))(h, w)):
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_max_grad_splits_ties_no_overcount():
    """Duplicate edges tied at the segment max share the cotangent — the
    scatter-add must see a total of 1x y_bar per output, not one per tie."""
    h = jnp.asarray(RNG.standard_normal((4, 3)).astype(np.float32))
    gidx = jnp.asarray(np.array([2, 2, 1], np.int32))   # edge 0 == edge 1
    seg = jnp.asarray(np.array([0, 0, 1], np.int32))
    w = jnp.ones((3,), jnp.float32)

    def f(h, weighted):
        if weighted:
            return jnp.sum(ops.index_weight_segment_reduce(
                h, gidx, w, seg, 2, "max"))
        return jnp.sum(ops.index_segment_reduce(h, gidx, seg, 2, "max"))

    for weighted in (False, True):
        dh = jax.grad(f)(h, weighted)
        # row 2 feeds segment 0 through two tied edges: gradient must be 1
        np.testing.assert_allclose(np.asarray(dh)[2], 1.0, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(dh)[1], 1.0, rtol=1e-6)

    # tied rows within one segment of plain segment_reduce
    x = jnp.asarray(np.array([[5.0], [5.0], [1.0]], np.float32))
    idx = jnp.asarray(np.array([0, 0, 0], np.int32))
    dx = jax.grad(lambda x: jnp.sum(ops.segment_reduce(x, idx, 1, "max")))(x)
    np.testing.assert_allclose(np.asarray(dx)[:, 0], [0.5, 0.5, 0.0])


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("w_dtype", [jnp.bfloat16, jnp.float32])
def test_weighted_max_grad_nonzero_in_bf16(impl, w_dtype):
    """The winner mask must mirror the forward's arithmetic per impl —
    recomputing the message at a different precision than the forward
    (f32 vs a bf16 product, or vice versa) silently zeroes the grad. Both
    impls and mixed h/weight dtypes must keep every winning segment's
    gradient alive."""
    v, s, m, n = 12, 8, 40, 4
    h = jnp.asarray(RNG.standard_normal((v, n)), jnp.bfloat16)
    w = jnp.asarray(RNG.standard_normal(m), w_dtype)
    gidx = jnp.asarray(RNG.integers(0, v, m).astype(np.int32))
    seg = jnp.asarray(np.sort(RNG.integers(0, s, m)).astype(np.int32))

    def f(h, w):
        y = ops.index_weight_segment_reduce(h, gidx, w, seg, s, "max", impl)
        return jnp.sum(jnp.where(jnp.isfinite(y), y, 0.0).astype(jnp.float32))

    dh, dw = jax.grad(f, (0, 1))(h, w)
    assert float(jnp.abs(dh.astype(jnp.float32)).sum()) > 0.0
    assert float(jnp.abs(dw.astype(jnp.float32)).sum()) > 0.0
    # every live segment has a winner: its cotangent must reach some edge
    g_msg = jnp.abs(dw.astype(jnp.float32))
    live = np.unique(np.asarray(seg))
    reached = np.zeros(s, bool)
    np.add.at(reached, np.asarray(seg), np.asarray(g_msg) > 0)
    assert reached[live].all()


def test_segment_softmax_normalizes():
    x, idx = _case(300, 40, 1)
    p = ops.segment_softmax(x[:, 0], idx, 40)
    sums = jax.ops.segment_sum(p, idx, 40, indices_are_sorted=True)
    live = np.unique(np.asarray(idx))
    np.testing.assert_allclose(np.asarray(sums)[live], 1.0, rtol=1e-5)


def test_sddmm():
    h1 = RNG.standard_normal((20, 8)).astype(np.float32)
    h2 = RNG.standard_normal((30, 8)).astype(np.float32)
    r = RNG.integers(0, 20, 50).astype(np.int32)
    c = RNG.integers(0, 30, 50).astype(np.int32)
    got = ops.sddmm(jnp.asarray(h1), jnp.asarray(h2), jnp.asarray(r),
                    jnp.asarray(c))
    want = np.einsum("ed,ed->e", h1[r], h2[c])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_segment_matmul_matches_ragged_dot():
    m, k, n, e = 96, 16, 24, 5
    sizes = RNG.multinomial(m, np.ones(e) / e).astype(np.int32)
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((e, k, n)).astype(np.float32))
    got = ops.segment_matmul(x, jnp.asarray(sizes), w)
    want = jax.lax.ragged_dot(x, w, jnp.asarray(sizes))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
