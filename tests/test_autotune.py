"""Wall-clock autotuner + PerfDB (paper §III-C measured tier):

* a real (interpreted) sweep returns a lattice-valid, VMEM-feasible config;
* the PerfDB round-trips through disk — the second ``tune()`` performs
  **zero** timings, even from a fresh process-analogue ``PerfDB`` object;
* the selection precedence holds: measured > generated rules > hand-crafted;
* ``snap_config`` survives degenerate tree predictions (zeros, NaN, inf);
* ``train_rules --from-perfdb`` distills measured records into a loadable
  rules module.
"""
import numpy as np
import pytest

from repro.core import heuristics, perfdb
from repro.core.autotune import (
    PerfDB,
    config_projection,
    perf_key,
    quantize_features,
    tune,
)
from repro.core.config_space import (
    VMEM_BYTES,
    KernelConfig,
    all_configs,
    default_config,
)
from repro.core.features import InputFeatures

M, S, F = 1000, 125, 16


def _counting_measure(best: KernelConfig):
    """Fake timer: `best` wins, everything else is slower; counts calls."""
    calls = []

    def measure(cfg: KernelConfig) -> float:
        calls.append(cfg)
        if config_projection("segment_reduce", cfg) == \
                config_projection("segment_reduce", best):
            return 10.0
        return 1000.0 + len(calls)

    return measure, calls


# ---------------------------------------------------------------------------
# real sweep
# ---------------------------------------------------------------------------

@pytest.mark.timeout(120)
def test_tuned_config_on_lattice_and_vmem_feasible(tmp_path):
    res = tune(op="segment_reduce", idx_size=256, num_segments=64, feat=8,
               db=PerfDB(tmp_path), max_configs=3, reps=1, warmup=1)
    lattice = {c.astuple() for c in all_configs(8)}
    assert res.config.astuple() in lattice
    assert res.config.vmem_bytes() <= VMEM_BYTES
    assert not res.cache_hit
    assert res.timings_performed == len(res.timings) == 3
    # the winner's stored timing is the sweep minimum
    assert res.time_of(res.config) == min(res.timings.values())


@pytest.mark.timeout(120)
@pytest.mark.parametrize("op", ["gather_segment_reduce_mean",
                                "gather_segment_reduce_max",
                                "segment_softmax"])
def test_new_op_keys_are_tunable(tmp_path, op):
    """The fused-mean/max gather and segment_softmax kernels register their
    own op keys: a real (tiny, interpreted) sweep runs and caches."""
    res = tune(op=op, idx_size=96, num_segments=24, feat=4,
               db=PerfDB(tmp_path), max_configs=2, reps=1, warmup=0)
    assert res.timings_performed == len(res.timings) == 2
    again = tune(op=op, idx_size=96, num_segments=24, feat=4,
                 db=PerfDB(tmp_path))
    assert again.cache_hit and again.config == res.config


def test_select_config_rejects_unregistered_op():
    with pytest.raises(ValueError):
        heuristics.select_config(100, 10, 8, op="nope")


def test_softmax_config_projection_ignores_schedule():
    a = KernelConfig("SR", 64, 128, 256, 1)
    b = KernelConfig("PR", 64, 512, 256, 16)
    assert config_projection("segment_softmax", a) == \
        config_projection("segment_softmax", b)
    assert config_projection("gather_segment_reduce_max", a) == \
        config_projection("gather_segment_reduce_max",
                          KernelConfig("PR", 64, 128, 256, 8))


# ---------------------------------------------------------------------------
# cache round-trip
# ---------------------------------------------------------------------------

def test_perfdb_roundtrip_second_tune_does_zero_timings(tmp_path):
    best = heuristics.hand_crafted_config(M, S, F)
    measure, calls = _counting_measure(best)
    r1 = tune(op="segment_reduce", idx_size=M, num_segments=S, feat=F,
              db=PerfDB(tmp_path), max_configs=6, measure_fn=measure)
    assert not r1.cache_hit and r1.timings_performed == len(calls) > 0
    n_cold = len(calls)

    # fresh PerfDB object on the same directory = new-process analogue
    r2 = tune(op="segment_reduce", idx_size=M, num_segments=S, feat=F,
              db=PerfDB(tmp_path), max_configs=6, measure_fn=measure)
    assert r2.cache_hit
    assert r2.timings_performed == 0
    assert len(calls) == n_cold                      # zero new timings
    assert r2.config.astuple() == r1.config.astuple()
    assert r2.timings == r1.timings

    # nearby shape, same quantized class -> same entry, still no timings
    r3 = tune(op="segment_reduce", idx_size=M + 7, num_segments=S, feat=F,
              db=PerfDB(tmp_path), max_configs=6, measure_fn=measure)
    assert r3.cache_hit and len(calls) == n_cold


def test_quantized_key_buckets_nearby_shapes():
    a = perf_key("cpu", "segment_reduce", InputFeatures(1000, 125, 16))
    b = perf_key("cpu", "segment_reduce", InputFeatures(1040, 130, 16))
    c = perf_key("cpu", "segment_reduce", InputFeatures(64_000, 125, 16))
    assert a == b
    assert a != c
    # IEEE -0.0 (avg degree just below 1) and +0.0 land in the same bin —
    # a '-0' key would split one shape class into two sweeps
    neg = perf_key("cpu", "segment_reduce", InputFeatures(1000, 1100, 16))
    pos = perf_key("cpu", "segment_reduce", InputFeatures(1000, 950, 16))
    assert neg == pos
    assert "-0," not in neg
    assert quantize_features(InputFeatures(1000, 125, 16)) == \
        quantize_features(InputFeatures(1040, 130, 16))


def test_perfdb_ignores_corrupt_file(tmp_path):
    (tmp_path / "perfdb.json").write_text("{not json")
    db = PerfDB(tmp_path)
    assert len(db) == 0
    db.put("k", {"op": "segment_reduce"})
    assert PerfDB(tmp_path).get("k") == {"op": "segment_reduce"}


# ---------------------------------------------------------------------------
# precedence: measured > generated rules > hand-crafted
# ---------------------------------------------------------------------------

def test_selection_precedence(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    rules_cfg = heuristics.select_config(M, S, F, tune=False)
    hand_cfg = heuristics.hand_crafted_config(M, S, F)
    # the two lower tiers disagree here (PR tree pick vs SR static rule) —
    # precondition for the precedence assertions below to mean anything
    assert rules_cfg.astuple() != hand_cfg.astuple()

    # seed the db with a sweep whose winner is the hand config (any config
    # != rules_cfg would do)
    measure, _ = _counting_measure(hand_cfg)
    db = PerfDB(tmp_path)
    tune(op="segment_reduce", idx_size=M, num_segments=S, feat=F, db=db,
         max_configs=6, measure_fn=measure)

    # tier 1: measured entry wins when tuning is requested
    got = heuristics.select_config(M, S, F, tune=True, db=db)
    assert got.astuple() == hand_cfg.astuple()
    # tier 2: without tuning, the generated rules decide
    assert heuristics.select_config(M, S, F, tune=False).astuple() == \
        rules_cfg.astuple()
    # tier 2 via env: REPRO_AUTOTUNE=0 means tune=None stays off
    assert heuristics.select_config(M, S, F).astuple() == rules_cfg.astuple()
    # tier 3: no generated rules -> hand-crafted fallback
    monkeypatch.setattr(heuristics, "_generated_rules", None)
    assert heuristics.select_config(M, S, F, tune=False).astuple() == \
        default_config(F).astuple()


def test_make_plan_tune_uses_perfdb_entry(tmp_path, monkeypatch):
    """make_plan(tune=True) resolves its config through the measured tier
    (REPRO_PERFDB_PATH routes it at the db the test seeded)."""
    from repro.core.plan import make_plan

    monkeypatch.setenv("REPRO_PERFDB_PATH", str(tmp_path))
    rng = np.random.default_rng(0)
    idx = np.sort(rng.integers(0, S, size=M)).astype(np.int32)

    target = KernelConfig("SR", 64, 128, 128, 1)
    measure, calls = _counting_measure(target)
    live = int(np.unique(idx).size)
    tune(op="segment_reduce", idx_size=M, num_segments=live, feat=F,
         db=PerfDB(tmp_path), max_configs=6, extra_configs=(target,),
         measure_fn=measure)
    n_cold = len(calls)

    plan = make_plan(idx, S, feat=F, tune=True)
    assert plan.config.astuple() == target.astuple()
    assert len(calls) == n_cold                      # cache hit, no timings
    # default path is unchanged by the existence of a perfdb
    plan_default = make_plan(idx, S, feat=F)
    assert plan_default.config.astuple() == \
        heuristics.select_config(M, live, F, tune=False).astuple()


# ---------------------------------------------------------------------------
# snap_config hardening
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("raw", [
    np.zeros(4),
    np.full(4, np.nan),
    np.array([np.inf, 0.0, np.nan, -5.0]),
    np.array([-1e30, 1e30, 0.5, 3.0]),
])
def test_snap_config_degenerate_predictions(raw):
    for sched in ("SR", "PR"):
        cfg = perfdb.snap_config(sched, raw)
        assert cfg.schedule == sched
        assert cfg.astuple() in {c.astuple() for c in all_configs()}
        assert cfg.vmem_bytes() <= VMEM_BYTES
        assert all(np.isfinite(v) for v in cfg.astuple()[1:])


# ---------------------------------------------------------------------------
# measured retraining pipeline
# ---------------------------------------------------------------------------

def test_train_rules_from_perfdb(tmp_path):
    from repro.core import train_rules

    # two shape classes, distinct winners, both schedules swept
    db = PerfDB(tmp_path)
    swept = 0
    for m, s, f, best in [
        (1000, 125, 16, KernelConfig("SR", 64, 128, 128, 1)),
        (64_000, 125, 64, KernelConfig("SR", 128, 128, 256, 1)),
    ]:
        measure, _ = _counting_measure(best)
        res = tune(op="segment_reduce", idx_size=m, num_segments=s, feat=f,
                   db=db, max_configs=8, extra_configs=(best,),
                   measure_fn=measure)
        swept += res.timings_performed

    records = train_rules.records_from_perfdb(tmp_path)
    assert len(records) == swept > 0       # every measurement becomes a row
    assert {r.schedule for r in records} == {"SR", "PR"}

    out = tmp_path / "rules.py"
    train_rules.train(out_path=out, records=records, verbose=False,
                      source="measured-test")
    ns: dict = {}
    exec(out.read_text(), ns)  # noqa: S102 — our own codegen
    cfg = ns["select"](*InputFeatures(1000, 125, 16).as_vector())
    assert cfg.astuple() in {c.astuple() for c in all_configs()}
    # the measured winner (an SR config) must be reachable: wall-clock on
    # this backend decided the schedule rule, not the analytical model
    assert ns["select_sr"](10.0, 3.0, 4.0).schedule == "SR"


def test_train_rules_cli_from_perfdb(tmp_path):
    from repro.core import train_rules

    best = KernelConfig("SR", 64, 128, 128, 1)
    measure, _ = _counting_measure(best)
    tune(op="segment_reduce", idx_size=M, num_segments=S, feat=F,
         db=PerfDB(tmp_path), max_configs=6, measure_fn=measure)
    out = tmp_path / "rules_cli.py"
    train_rules.main(["--from-perfdb", str(tmp_path), "--out", str(out)])
    assert "AUTO-GENERATED" in out.read_text()


def test_train_rules_cli_empty_perfdb_errors(tmp_path):
    from repro.core import train_rules

    with pytest.raises(SystemExit):
        train_rules.main(["--from-perfdb", str(tmp_path / "empty"),
                          "--out", str(tmp_path / "x.py")])
