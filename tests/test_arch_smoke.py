"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward +
one train step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — see launch/dryrun.py and tests/test_dryrun_small.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.configs import shapes as shapelib
from repro.models import lm
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _reduced(name):
    return cfglib.get_config(name).reduced()


def _batch(cfg, b=2, s=16):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (b, cfg.num_prefix_embeds, cfg.d_model))
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(KEY, (b, 12, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", cfglib.ARCH_NAMES)
def test_full_config_is_exact(arch):
    """The registered config carries the exact assigned hyperparameters."""
    cfg = cfglib.get_config(arch)
    spec = {
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (got, spec)


@pytest.mark.parametrize("arch", cfglib.ARCH_NAMES)
def test_reduced_forward_and_train_step(arch):
    cfg = _reduced(arch)
    prm = lm.init(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = lm.forward(prm, cfg, batch["tokens"],
                             prefix_embeds=batch.get("prefix_embeds"),
                             enc_embeds=batch.get("enc_embeds"),
                             remat_policy="none")
    prefix = cfg.num_prefix_embeds if cfg.family == "vlm" else 0
    assert logits.shape == (2, 16 + prefix, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    # one train step
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init(prm, opt_cfg)

    def loss(p):
        return lm.loss_fn(p, cfg, batch, remat_policy="none")[0]

    l, grads = jax.value_and_grad(loss)(prm)
    assert np.isfinite(float(l))
    new_prm, opt, metrics = adamw.update(grads, opt, prm, opt_cfg)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree_util.tree_leaves(prm)[0]
    d1 = jax.tree_util.tree_leaves(new_prm)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", cfglib.ARCH_NAMES)
def test_reduced_decode_step(arch):
    cfg = _reduced(arch)
    prm = lm.init(KEY, cfg)
    state = lm.init_decode_state(cfg, 2, 8, jnp.float32)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    enc = None
    if cfg.family == "audio":
        enc = jax.random.normal(KEY, (2, 12, cfg.d_model))
    lg, state = lm.decode_step(prm, cfg, tok, state, enc_out=enc)
    assert lg.shape == (2, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg).any())
    assert int(state.length) == 1


@pytest.mark.parametrize("arch", cfglib.ARCH_NAMES)
def test_input_specs_cover_all_cells(arch):
    cfg = cfglib.get_config(arch)
    for shape in shapelib.SHAPE_NAMES:
        if shapelib.cell_applicable(cfg, shape):
            continue
        specs = shapelib.input_specs(cfg, shape)
        cell = shapelib.SHAPES[shape]
        assert specs["tokens"].shape[0] == cell.global_batch
        for sds in specs.values():
            assert isinstance(sds, jax.ShapeDtypeStruct)


def test_long_context_skips_documented():
    skips = [a for a in cfglib.ARCH_NAMES
             if shapelib.cell_applicable(cfglib.get_config(a), "long_500k")]
    runs = [a for a in cfglib.ARCH_NAMES
            if not shapelib.cell_applicable(cfglib.get_config(a), "long_500k")]
    assert sorted(runs) == ["jamba-v0.1-52b", "rwkv6-3b"]
    assert len(skips) == 8
