"""Partitioned-graph subsystem: partition invariants, exact round-trips,
halo metadata, the PartitionedPlan's static consistency, the Graph plan
memo, and the empty-edge regression (all pure-host — the sharded execution
parity lives in tests/_sharded_mp_checks.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import make_partitioned_plan
from repro.data.graphs import synth_graph
from repro.data.partition import (partition_graph, unpartition_edges,
                                  unpartition_nodes)


def _graphs():
    return [synth_graph("skew", 60, 300, feat=8, seed=0, alpha=1.2),
            synth_graph("small", 9, 20, feat=4, seed=1),
            synth_graph("empty", 12, 0, feat=4, seed=2)]


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_partition_invariants(shards):
    for g in _graphs():
        pg = partition_graph(g, shards)
        node_ptr = np.asarray(pg.node_ptr)
        # contiguous partition of the node space
        assert node_ptr[0] == 0 and node_ptr[-1] == g.num_nodes
        assert np.all(np.diff(node_ptr) >= 0)
        valid = np.asarray(pg.edge_valid)
        assert int(valid.sum()) == g.num_edges
        dst = np.asarray(pg.dst_global)
        src_local = np.asarray(pg.src_local)
        for s in range(shards):
            d = dst[s][valid[s]]
            # per-shard edge lists stay dst-sorted (kernel precondition)
            assert np.all(d[1:] >= d[:-1])
            # remapped sources stay inside the shard's node block
            vs = node_ptr[s + 1] - node_ptr[s]
            assert np.all(src_local[s][valid[s]] < vs)
            assert np.all(src_local[s][valid[s]] >= 0)
            # padding uses the kernels' drop id
            assert np.all(dst[s][~valid[s]] == g.num_nodes)
        # every edge appears exactly once across shards
        slots = np.asarray(pg.edge_gather)[valid]
        assert sorted(slots.tolist()) == list(range(g.num_edges))


def test_partition_halo_metadata():
    g = synth_graph("skew", 80, 400, feat=4, seed=3)
    pg = partition_graph(g, 4)
    node_ptr = np.asarray(pg.node_ptr)
    src = np.asarray(g.edge_index[0])
    dst = np.asarray(g.edge_index[1])
    shard_of = np.searchsorted(node_ptr, src, side="right") - 1
    want_cut = [int(np.sum((shard_of == s) &
                           ((dst < node_ptr[s]) | (dst >= node_ptr[s + 1]))))
                for s in range(4)]
    assert list(pg.halo.cut_edges) == want_cut
    assert pg.halo.total_cut == sum(want_cut)
    assert 0.0 <= pg.halo.cut_fraction <= 1.0
    # 1-shard partition has no halo by construction
    assert partition_graph(g, 1).halo.total_cut == 0


def test_partition_roundtrip_nodes_and_edges():
    rng = np.random.default_rng(7)
    for g in _graphs():
        for shards in (1, 3, 4):
            if shards > g.num_nodes:
                continue
            pg = partition_graph(g, shards)
            x = jnp.asarray(rng.standard_normal((g.num_nodes, 5))
                            .astype(np.float32))
            np.testing.assert_array_equal(
                np.asarray(unpartition_nodes(pg, pg.shard_nodes(x))),
                np.asarray(x))
            ev = jnp.asarray(rng.standard_normal((g.num_edges, 3))
                             .astype(np.float32))
            np.testing.assert_array_equal(
                np.asarray(unpartition_edges(pg, pg.shard_edges(ev))),
                np.asarray(ev))


def test_partition_roundtrip_property():
    """Hypothesis property: unpartition ∘ shard == identity on random
    skewed/gapped graphs for any shard count."""
    pytest.importorskip(
        "hypothesis",
        reason="hypothesis not installed — property test skipped")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 60), st.integers(0, 200), st.integers(1, 8),
           st.integers(0, 2 ** 16), st.integers(1, 6))
    def prop(v, e, stride, seed, shards):
        rng = np.random.default_rng(seed)
        lanes = np.arange(0, v, min(stride, v))
        dst = (np.sort(rng.choice(lanes, e)).astype(np.int32) if e
               else np.zeros(0, np.int32))
        src = rng.integers(0, v, e).astype(np.int32)
        from repro.data.graphs import Graph
        g = Graph(name="p", edge_index=np.stack([src, dst]), num_nodes=v,
                  x=rng.standard_normal((v, 2)).astype(np.float32),
                  labels=np.zeros(v, np.int32),
                  deg_inv_sqrt=np.ones(v, np.float32))
        pg = partition_graph(g, min(shards, v))
        x = jnp.asarray(g.x)
        np.testing.assert_array_equal(
            np.asarray(unpartition_nodes(pg, pg.shard_nodes(x))), g.x)
        ev = jnp.asarray(rng.standard_normal((e,)).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(unpartition_edges(pg, pg.shard_edges(ev))),
            np.asarray(ev))

    prop()


def test_partition_rejects_bad_shard_counts():
    g = synth_graph("g", 10, 40, feat=4, seed=0)
    with pytest.raises(ValueError):
        partition_graph(g, 0)
    with pytest.raises(ValueError):
        partition_graph(g, 11)


def test_partition_rejects_unsorted_destinations():
    """The single-device make_plan raises on unsorted idx; the sharded
    entry point must fail just as loudly (silent mis-aggregation bug)."""
    import dataclasses
    g = synth_graph("g", 10, 40, feat=4, seed=0)
    ei = g.edge_index[:, ::-1].copy()
    bad = dataclasses.replace(g, edge_index=ei)
    with pytest.raises(ValueError, match="sorted"):
        partition_graph(bad, 2)


def test_partitioned_plan_build_rejected_inside_jit():
    """Plan building is host-side (numpy over leaves); inside jit the
    leaves are tracers and the guard must raise a clear error instead of
    a TracerArrayConversionError from deep inside chunk_metadata."""
    g = synth_graph("g", 12, 30, feat=4, seed=0)
    pg = partition_graph(g, 2)

    @jax.jit
    def build(pg):
        return pg.make_plan(feat=4).chunk_first

    with pytest.raises(ValueError, match="outside jit"):
        build(pg)


def test_partitioned_plan_static_consistency():
    """Stacked leaves, one shared static program: common row count, global
    segment space, tight-but-uniform max_chunks, local_plan round-trip."""
    g = synth_graph("skew", 60, 300, feat=8, seed=0, alpha=1.2)
    pg = partition_graph(g, 4)
    pplan = make_partitioned_plan(pg, feat=8)
    assert pplan.chunk_first.shape == pplan.chunk_count.shape
    assert pplan.chunk_first.shape[0] == 4
    assert pplan.num_rows == pg.edges_per_shard
    assert pplan.num_segments == g.num_nodes
    assert pplan.max_chunks >= 1
    cc = np.asarray(pplan.chunk_count)
    assert int(cc.max()) <= pplan.max_chunks
    lp = pplan.local_plan(pplan.chunk_first[:1], pplan.chunk_count[:1])
    assert lp.num_rows == pplan.num_rows
    assert lp.max_chunks == pplan.max_chunks
    assert lp.config == pplan.config
    # global stats drive the cost model exactly like a single-device plan
    assert pplan.stats.num_rows == g.num_edges
    # pytree round-trip (rides jit/shard_map closures)
    leaves, treedef = jax.tree_util.tree_flatten(pplan)
    assert jax.tree_util.tree_unflatten(treedef, leaves) == pplan


def test_graph_make_plan_memoizes():
    """Repeated Graph.make_plan calls hit the per-(feat, config) memo;
    invalidation rebuilds."""
    g = synth_graph("g", 40, 200, feat=8, seed=0)
    p1 = g.make_plan(feat=16)
    p2 = g.make_plan(feat=16)
    assert p1 is p2                       # cache hit, no recompute
    p3 = g.make_plan(feat=32)
    assert p3 is not p1                   # different key
    assert g.make_plan(feat=32) is p3
    g.invalidate_plan_cache()
    assert g.make_plan(feat=16) is not p1


def test_empty_edge_graph_regression():
    """num_edges == 0: synth_graph, plans, partitions, mp, and every model
    must produce finite results (the NaN-probabilities bug)."""
    from repro.core.mp import mp, mp_transform
    from repro.models import gnn

    g = synth_graph("empty", 10, 0, feat=8, seed=0)
    assert g.num_edges == 0
    plan = g.make_plan(feat=8)
    assert plan.max_chunks == 1 and plan.stats.skew == 0.0
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    dis = jnp.asarray(g.deg_inv_sqrt)
    for impl, p in (("ref", None), ("pallas", plan)):
        for reduce in ("sum", "mean", "max"):
            y = mp(x, ei, g.num_nodes, reduce=reduce, impl=impl, plan=p)
            assert bool(jnp.isfinite(y).all()), (impl, reduce)
    w = jnp.asarray(np.ones((8, 16), np.float32))
    y = mp_transform(x, w, ei, g.num_nodes, reduce="sum", impl="pallas",
                     plan=plan)
    assert bool(jnp.isfinite(y).all())
    for model in gnn.MODELS:
        prm = gnn.init(jax.random.PRNGKey(0), model, 8, 16, 4)
        out = gnn.forward(prm, model, x, ei, g.num_nodes, dis,
                          impl="pallas", plan=plan)
        assert out.shape == (10, 4) and bool(jnp.isfinite(out).all()), model
    # partitioning an empty-edge graph also round-trips
    pg = partition_graph(g, 2)
    assert pg.edges_per_shard == 0 and pg.halo.total_cut == 0
    np.testing.assert_array_equal(
        np.asarray(unpartition_nodes(pg, pg.shard_nodes(x))), np.asarray(x))
