"""Decision tree (paper §III-C): fit quality, codegen exactness, pipeline."""
import numpy as np

from repro.core import codegen, perfdb
from repro.core.decision_tree import MultiOutputDecisionTree
from repro.core.features import InputFeatures


def test_tree_fits_separable_data():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, (400, 3))
    y = np.stack([np.where(x[:, 0] > 0, 10.0, 2.0),
                  np.where(x[:, 1] > 0.5, 7.0, 1.0)], axis=1)
    tree = MultiOutputDecisionTree(max_depth=4, min_samples_leaf=4).fit(x, y)
    pred = tree.predict(x)
    assert np.mean((pred - y) ** 2) < 0.5
    assert tree.depth() <= 4


def test_tree_multioutput_joint_selection():
    """Leaves carry the whole config vector jointly (paper's multi-output
    regressor vs per-parameter trees)."""
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, (300, 2))
    # outputs correlated through the same split
    y = np.where(x[:, :1] > 0.5, np.array([[64.0, 256.0]]),
                 np.array([[16.0, 32.0]]))
    tree = MultiOutputDecisionTree(max_depth=3, min_samples_leaf=4).fit(x, y)
    p = tree.predict(np.array([0.9, 0.5]))
    assert p[0] > 32 and p[1] > 64


def test_perfdb_pipeline_small():
    datasets = perfdb.base_datasets(12)
    records = perfdb.build_perfdb(perfdb.augment(datasets, factor=2),
                                  feature_sizes=(1, 16, 64))
    assert len(records) > 1000
    x, y = perfdb.top1_training_set(records, "SR")
    assert x.shape[0] == y.shape[0] > 0 and y.shape[1] == 4


def test_codegen_reproduces_tree_exactly():
    """The generated if/else rules return exactly the snapped tree leaves
    (paper Listing 3 analogue)."""
    records = perfdb.build_perfdb(perfdb.augment(perfdb.base_datasets(10),
                                                 factor=2),
                                  feature_sizes=(1, 8, 64))
    trees = {}
    for sched in ("SR", "PR"):
        x, y = perfdb.top1_training_set(records, sched)
        trees[sched] = MultiOutputDecisionTree(max_depth=4).fit(x, y)
    src = codegen.generate_rules_source(trees["SR"], trees["PR"],
                                        InputFeatures.names())
    ns: dict = {}
    exec(src, ns)  # noqa: S102 — our own codegen
    rng = np.random.default_rng(2)
    for _ in range(200):
        feats = rng.uniform([10, -4, 0], [25, 7, 9])
        for sched, fn in (("SR", ns["select_sr"]), ("PR", ns["select_pr"])):
            got = fn(*feats)
            want = perfdb.snap_config(sched, trees[sched].predict(feats))
            assert got.astuple() == want.astuple()


def test_snap_config_valid():
    cfg = perfdb.snap_config("PR", np.array([100.0, 999.0, 7.0, 100.0]))
    assert cfg.schedule == "PR"
    assert cfg.vmem_bytes() <= 16 * 1024 * 1024


def test_generated_rules_committed_and_loadable():
    """TPU adaptation finding (EXPERIMENTS.md §Bench-Fig8): unlike the
    paper's GPU rule (F > 4 ⇒ SR, a coalescing effect), on v5e the PR
    one-hot matmul rides under the roofline knee (~240 FLOP/byte) — the MXU
    performs the parallel reduction for free while the kernel stays
    memory-bound, so the fitted rule selects PR across the swept F range."""
    from repro.core import _generated_rules as gr
    for f in (0.0, 2.0, 5.0, 7.0):
        cfg = gr.select(20.0, 2.5, f)
        assert cfg.schedule == "PR"
        assert cfg.vmem_bytes() <= 16 * 1024 * 1024
    # SR remains selectable explicitly (and is forced for reduce='max')
    assert gr.select_sr(20.0, 2.5, 5.0).schedule == "SR"
