"""Multi-device checks, run in a subprocess with 8 host devices
(tests/test_distributed.py drives this)."""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import functools
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.distributed import collectives, pipeline, sharding as shd, step as steplib  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim import adamw  # noqa: E402


def check_ring_allreduce():
    mesh = jax.make_mesh((8,), ("r",))
    x = jnp.arange(8 * 16 * 4, dtype=jnp.float32).reshape(8, 16, 4)

    def ring(xl):
        return collectives.ring_allreduce(xl[0], "r")

    got = shard_map(ring, mesh=mesh, in_specs=PS("r"), out_specs=PS("r"))(x)
    want = jnp.tile(jnp.sum(x, 0, keepdims=True) / 1.0, (8, 1, 1))[:, : 16 // 8]
    # out_specs PS("r") splits the replicated result; compare against psum
    def psum_ref(xl):
        return jax.lax.psum(xl[0], "r")
    want2 = shard_map(psum_ref, mesh=mesh, in_specs=PS("r"),
                      out_specs=PS("r"))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want2),
                               rtol=1e-5)
    print("ring_allreduce OK")


def check_ring_matmul():
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 24)).astype(np.float32))
    fn = collectives.make_ring_matmul(mesh, "model")
    got = fn(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)
    print("ring_matmul OK")


def check_hierarchical_and_compressed_psum():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 4, 16, 8)).astype(np.float32))

    def h(xl):
        return collectives.hierarchical_psum(xl[0, 0], "pod", "data")

    got = shard_map(h, mesh=mesh, in_specs=PS("pod", "data"),
                    out_specs=PS("pod", "data"))(x)

    def p(xl):
        return jax.lax.psum(jax.lax.psum(xl[0, 0], "data"), "pod")

    want = shard_map(p, mesh=mesh, in_specs=PS("pod", "data"),
                     out_specs=PS("pod", "data"))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def c(xl):
        # error-feedback buffer lives at the reduce-scattered shape
        ef = jnp.zeros((xl.shape[2] // 4, xl.shape[3]), jnp.float32)
        out, new_ef = collectives.compressed_psum(xl[0, 0], ef, "pod", "data")
        return out

    got_c = shard_map(c, mesh=mesh, in_specs=PS("pod", "data"),
                      out_specs=PS("pod", "data"))(x)
    err = np.max(np.abs(np.asarray(got_c) - np.asarray(want)))
    scale = np.max(np.abs(np.asarray(want)))
    assert err < 0.05 * scale + 0.05, (err, scale)
    print("hierarchical/compressed psum OK (int8 err %.4f)" % err)


def check_pipeline():
    mesh = jax.make_mesh((4,), ("pipe",))
    rng = np.random.default_rng(2)
    n_stages, n_micro, dim = 4, 8, 16
    ws = jnp.asarray(rng.standard_normal((n_stages, dim, dim))
                     .astype(np.float32) * 0.3)

    def stage(w, x):
        return jnp.tanh(x @ w)

    xm = jnp.asarray(rng.standard_normal((n_micro, 4, dim)).astype(np.float32))
    got = pipeline.pipeline_forward(stage, ws, xm, mesh=mesh, axis="pipe")
    want = xm
    for i in range(n_stages):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("pipeline OK")


def check_pjit_train_step_matches_single_device():
    cfg = ModelConfig("t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=128, dtype="float32", max_seq=64)
    prm = lm.init(jax.random.PRNGKey(0), cfg)
    ts = steplib.TrainStepConfig(opt=adamw.AdamWConfig(lr=1e-3),
                                 remat_policy="none")
    opt = adamw.init(prm, ts.opt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 128)
    batch = {"tokens": toks, "labels": toks}

    # single device
    def loss(p):
        return lm.loss_fn(p, cfg, batch, remat_policy="none")
    (l0, _), g = jax.value_and_grad(loss, has_aux=True)(prm)
    p1, o1, m1 = adamw.update(g, opt, prm, ts.opt,
                              lr_scale=jnp.asarray(0.0, jnp.float32))

    # 2×2 mesh pjit
    mesh = make_host_mesh(2, 2)
    plan = shd.ParallelPlan.for_mesh(mesh)
    fn, shardings_for = steplib.build_train_step(cfg, mesh, plan, ts)
    in_sh, _ = shardings_for(prm, opt, {"tokens": (4, 16), "labels": (4, 16)})
    with mesh:
        p2, o2, m2 = jax.jit(fn, in_shardings=in_sh)(
            prm, opt, batch, jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(float(l0), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
    print("pjit train step == single device OK (loss %.4f)" % float(m2["loss"]))


def check_serve_step_sharded():
    cfg = ModelConfig("t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=128, dtype="float32", max_seq=64)
    prm = lm.init(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(2, 2)
    plan = shd.ParallelPlan.for_mesh(mesh)
    fn, shardings_for = steplib.build_serve_step(cfg, mesh, plan, 4, 16)
    psh, tok_sh, st_sh = shardings_for(prm)
    state = lm.init_decode_state(cfg, 4, 16, jnp.float32)
    tok = jax.random.randint(jax.random.PRNGKey(2), (4, 1), 0, 128)
    with mesh:
        lg, st = jax.jit(fn, in_shardings=(psh, tok_sh, st_sh))(prm, tok, state)
    lg1, st1 = lm.decode_step(prm, cfg, tok, state)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg1), rtol=2e-3,
                               atol=2e-3)
    print("sharded serve step == single device OK")


def check_moe_shard_map_parity():
    """EP shard_map MoE (§Perf #5) ≡ global capacity path, fwd and grads."""
    from repro.models import moe as moe_lib
    cfg = ModelConfig("t", family="moe", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=128, num_experts=8, top_k=2, moe_d_ff=16,
                      capacity_factor=8.0, dtype="float32", max_seq=64)
    prm = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y_ref, _ = moe_lib.moe_capacity(prm, x, cfg)
    mesh = make_host_mesh(2, 4)
    plan = shd.ParallelPlan.for_mesh(mesh)
    with mesh, shd.activation_sharding(mesh, plan):
        y_sm, _ = jax.jit(lambda p, x: moe_lib.moe_shard_map(p, x, cfg))(prm, x)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)

    def loss_sm(p, x):
        with shd.activation_sharding(mesh, plan):
            y, _ = moe_lib.moe_shard_map(p, x, cfg)
        return jnp.sum(y ** 2)

    def loss_ref(p, x):
        y, _ = moe_lib.moe_capacity(p, x, cfg)
        return jnp.sum(y ** 2)

    with mesh:
        g_sm = jax.jit(jax.grad(loss_sm, argnums=(0, 1)))(prm, x)
    g_ref = jax.grad(loss_ref, argnums=(0, 1))(prm, x)
    for a, b in zip(jax.tree_util.tree_leaves(g_sm),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
    print("moe shard_map parity OK")


def check_tp_out_project_parity():
    """Opt-in hand-scheduled TP projection ≡ plain matmul (kept for real-TPU
    bf16-wire all-reduces; §Perf log #6)."""
    from repro.models import layers as L
    from repro.models.params import P
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 16, 32)).astype(np.float32))
    w = P(jnp.asarray(rng.standard_normal((32, 24)).astype(np.float32)),
          ("heads", "embed"))
    mesh = make_host_mesh(2, 4)
    plan = shd.ParallelPlan.for_mesh(mesh)
    want = x @ w.value
    with mesh, shd.activation_sharding(mesh, plan):
        got = jax.jit(lambda x, wv: L.tp_out_project(x, P(wv, w.axes)))(
            x, w.value)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("tp_out_project parity OK")


def check_elastic_reshard():
    """Elastic scaling drill: checkpoint written under mesh A (2×4) restores
    onto mesh B (4×2) — the restart path after losing/gaining nodes."""
    import tempfile
    from repro.checkpoint import checkpoint as ckpt
    cfg = ModelConfig("t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=128, dtype="float32", max_seq=64)
    prm = lm.init(jax.random.PRNGKey(0), cfg)
    mesh_a = make_host_mesh(2, 4)
    plan_a = shd.ParallelPlan.for_mesh(mesh_a)
    sh_a = shd.param_shardings(prm, plan_a, mesh_a)
    prm_a = jax.device_put(prm, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(prm_a, d, 42)
        mesh_b = make_host_mesh(4, 2)
        plan_b = shd.ParallelPlan.for_mesh(mesh_b)
        sh_b = shd.param_shardings(prm, plan_b, mesh_b)
        prm_b = ckpt.restore(prm, d, shardings=sh_b)
    for a, b in zip(jax.tree_util.tree_leaves(prm),
                    jax.tree_util.tree_leaves(prm_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    print("elastic reshard (2×4 → 4×2) OK")


if __name__ == "__main__":
    check_ring_allreduce()
    check_ring_matmul()
    check_hierarchical_and_compressed_psum()
    check_pipeline()
    check_pjit_train_step_matches_single_device()
    check_serve_step_sharded()
    check_moe_shard_map_parity()
    check_tp_out_project_parity()
    check_elastic_reshard()
    print("ALL DISTRIBUTED CHECKS OK")
