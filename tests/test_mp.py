"""Unified message-passing subsystem (core/mp.py): mp parity across every
reduce × weighted × impl combo, the FLOP-based transform/aggregate
reordering, fused segment_softmax numerical stability, and grad checks for
the fused mean/max VJPs vs the ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ops
from repro.core.config_space import KernelConfig
from repro.core.mp import choose_order, mp, mp_transform
from repro.core.plan import make_graph_plan

RNG = np.random.default_rng(23)
CFG = KernelConfig("SR", 32, 128, 64, 1)


def _graph(v=50, e=260, f=8, seed=0, gapped=False):
    rng = np.random.default_rng(seed)
    if gapped:
        dst = np.sort(rng.choice(np.arange(0, v, 5), e)).astype(np.int32)
    else:
        dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    src = rng.integers(0, v, e).astype(np.int32)
    ei = np.stack([src, dst])
    x = jnp.asarray(rng.standard_normal((v, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(e), jnp.float32)
    plan = make_graph_plan(ei, v, feat=f, config=CFG)
    return jnp.asarray(ei), x, w, v, plan


# ---------------------------------------------------------------------------
# mp: one primitive, every aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
@pytest.mark.parametrize("weighted", [False, True])
def test_mp_pallas_matches_ref(reduce, weighted):
    ei, x, w, v, plan = _graph(seed=1)
    ew = w if weighted else None
    want = mp(x, ei, v, reduce=reduce, edge_weight=ew, impl="ref")
    got = mp(x, ei, v, reduce=reduce, edge_weight=ew, impl="pallas",
             plan=plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_mp_max_fills_empty_neighbourhoods_with_zero():
    """mp's max is model-facing: isolated nodes get 0, not the -inf
    segment_max identity."""
    ei, x, w, v, plan = _graph(gapped=True, seed=2)
    for impl, p in (("ref", None), ("pallas", plan)):
        y = mp(x, ei, v, reduce="max", impl=impl, plan=p)
        assert bool(jnp.isfinite(y).all())
        dst = np.asarray(ei[1])
        empty = np.setdiff1d(np.arange(v), dst)
        assert empty.size > 0
        np.testing.assert_array_equal(np.asarray(y)[empty], 0.0)


def test_mp_max_preserves_nan_and_posinf():
    """Only the -inf empty-neighbourhood identity is zero-filled — real NaN
    (upstream bug) and +inf (sentinel features) aggregates must surface."""
    ei, x, w, v, plan = _graph(gapped=True, seed=8)
    x = x.at[0, 0].set(jnp.nan).at[1, 1].set(jnp.inf)
    y = mp(x, ei, v, reduce="max", impl="ref")
    src, dst = np.asarray(ei[0]), np.asarray(ei[1])
    assert bool(jnp.isnan(y[dst[src == 0][0], 0])) or np.all(src != 0)
    assert not bool(jnp.isneginf(y).any())


def test_mp_rejects_unknown_reduce():
    ei, x, w, v, plan = _graph()
    with pytest.raises(ValueError):
        mp(x, ei, v, reduce="median")


@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
def test_mp_grads_pallas_match_ref(reduce):
    """Grad checks for the fused (single-launch) mean/max VJPs vs the ref
    oracle, weighted and unweighted, through the plan."""
    ei, x, w, v, plan = _graph(seed=3)

    def loss(x, w, impl, p, weighted):
        y = mp(x, ei, v, reduce=reduce,
               edge_weight=(w if weighted else None), impl=impl, plan=p)
        return jnp.sum(jnp.sin(y))

    for weighted in (False, True):
        g_ref = jax.grad(loss, (0, 1))(x, w, "ref", None, weighted)
        g_pal = jax.grad(loss, (0, 1))(x, w, "pallas", plan, weighted)
        for a, b in zip(g_pal, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# transform/aggregate reordering
# ---------------------------------------------------------------------------

def test_choose_order_follows_spmm_width():
    """Aggregate-first wins iff it narrows the SpMM (past lane padding)."""
    ei, x, w, v, plan = _graph(seed=4)
    e = int(ei.shape[1])
    assert choose_order(32, 256, plan=plan) == "aggregate_first"
    assert choose_order(256, 32, plan=plan) == "transform_first"
    # both below the 128-lane tile ⇒ modelled cost ties ⇒ conventional order
    assert choose_order(8, 16, plan=plan) == "transform_first"
    # plan-less path takes explicit sizes
    assert choose_order(32, 256, num_edges=e, num_nodes=v) == "aggregate_first"
    with pytest.raises(ValueError):
        choose_order(32, 256)


@pytest.mark.parametrize("order", ["aggregate_first", "transform_first",
                                   "auto"])
@pytest.mark.parametrize("reduce", ["sum", "mean"])
def test_mp_transform_orders_agree(order, reduce):
    """Linear reduces commute with W: both orders (and the auto pick)
    compute the same layer, ref and pallas."""
    ei, x, w, v, plan = _graph(seed=5, f=16)
    wmat = jnp.asarray(RNG.standard_normal((16, 160)) / 4.0, jnp.float32)
    want = mp(x, ei, v, reduce=reduce, impl="ref") @ wmat
    got = mp_transform(x, wmat, ei, v, reduce=reduce, impl="pallas",
                       plan=plan, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_mp_transform_max_pins_transform_first():
    """max does not commute with W — auto must not reorder."""
    ei, x, w, v, plan = _graph(seed=6, f=16)
    wmat = jnp.asarray(RNG.standard_normal((16, 160)) / 4.0, jnp.float32)
    got = mp_transform(x, wmat, ei, v, reduce="max", impl="ref", order="auto")
    want = mp(x @ wmat, ei, v, reduce="max", impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    with pytest.raises(ValueError):
        mp_transform(x, wmat, ei, v, order="backwards")
    with pytest.raises(ValueError):   # bogus order must raise for max too
        mp_transform(x, wmat, ei, v, reduce="max", order="backwards")
    with pytest.raises(ValueError):   # explicit non-commuting pin rejected
        mp_transform(x, wmat, ei, v, reduce="max", order="aggregate_first")


# ---------------------------------------------------------------------------
# segment_softmax: numerical stability + grads
# ---------------------------------------------------------------------------

def _softmax_case(m, s, gapped=False, scale=1.0, heads=None, seed=7):
    rng = np.random.default_rng(seed)
    if gapped:
        idx = np.sort(rng.choice(np.arange(0, s, 7), m)).astype(np.int32)
    else:
        idx = np.sort(rng.integers(0, s, m)).astype(np.int32)
    shape = (m,) if heads is None else (m, heads)
    x = jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)
    return x, jnp.asarray(idx)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("gapped", [False, True])
@pytest.mark.parametrize("scale", [1.0, 1e4])
def test_segment_softmax_stable_and_normalized(impl, gapped, scale):
    """Empty/gapped segments and large-magnitude logits: the segment-max
    subtraction (online on the pallas path) must keep every output finite
    and every live segment summing to 1."""
    m, s = 260, 300
    x, idx = _softmax_case(m, s, gapped=gapped, scale=scale)
    p = ops.segment_softmax(x, idx, s, impl)
    assert bool(jnp.isfinite(p).all())
    sums = jax.ops.segment_sum(p, idx, s, indices_are_sorted=True)
    live = np.unique(np.asarray(idx))
    np.testing.assert_allclose(np.asarray(sums)[live], 1.0, rtol=1e-5)


def test_segment_softmax_pallas_matches_ref_multihead():
    x, idx = _softmax_case(300, 40, heads=4, scale=30.0)
    got = ops.segment_softmax(x, idx, 40, "pallas")
    want = ops.segment_softmax(x, idx, 40, "ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_segment_softmax_singleton_segments_are_one():
    """A segment with a single huge logit softmaxes to exactly 1."""
    idx = jnp.asarray(np.arange(10, dtype=np.int32))
    x = jnp.asarray(np.linspace(-1e4, 1e4, 10), jnp.float32)
    for impl in ("ref", "pallas"):
        np.testing.assert_allclose(
            np.asarray(ops.segment_softmax(x, idx, 10, impl)), 1.0)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_segment_softmax_grad_matches_autodiff_oracle(impl):
    """The custom VJP (p·(g − Σ p·g)) vs autodiff through the three-pass
    formulation, 1-D and multi-head."""
    from repro.core.ops import _segment_softmax_ref
    for heads in (None, 3):
        x, idx = _softmax_case(200, 30, heads=heads, seed=11)

        def f(x, impl_):
            return jnp.sum(jnp.sin(ops.segment_softmax(x, idx, 30, impl_)))

        got = jax.grad(f)(x, impl)
        want = jax.grad(
            lambda x: jnp.sum(jnp.sin(_segment_softmax_ref(x, idx, 30))))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
