"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — property tests skipped (CI installs it)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ops
from repro.core.config_space import KernelConfig, all_configs
from repro.core.features import InputFeatures
from repro.core.heuristics import select_config

SET = settings(max_examples=25, deadline=None)


@st.composite
def segment_problem(draw):
    m = draw(st.integers(1, 400))
    s = draw(st.integers(1, 80))
    n = draw(st.integers(1, 40))
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.integers(0, s, m)).astype(np.int32)
    x = rng.standard_normal((m, n)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(idx), s


@SET
@given(segment_problem(), st.sampled_from(["SR", "PR"]),
       st.sampled_from([64, 128, 256]))
def test_blocked_equals_oracle(problem, sched, mb):
    x, idx, s = problem
    cfg = KernelConfig(sched, 128, 128, mb, 8)
    got = ops.segment_reduce(x, idx, s, "sum", "blocked", cfg)
    want = jax.ops.segment_sum(x, idx, s, indices_are_sorted=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@SET
@given(segment_problem(), st.floats(-2.0, 2.0), st.floats(-2.0, 2.0))
def test_linearity(problem, a, b):
    """segment_reduce(a·x + b·y) == a·SR(x) + b·SR(y)."""
    x, idx, s = problem
    y = x[::-1].copy() if x.shape[0] > 1 else x
    lhs = ops.segment_reduce(a * x + b * y, idx, s)
    rhs = a * ops.segment_reduce(x, idx, s) + b * ops.segment_reduce(y, idx, s)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@SET
@given(segment_problem(), st.integers(0, 2 ** 16))
def test_permutation_within_segments_invariance(problem, seed):
    """Shuffling rows *within* each segment leaves the sum unchanged."""
    x, idx, s = problem
    rng = np.random.default_rng(seed)
    idx_np = np.asarray(idx)
    perm = np.arange(idx_np.size)
    for seg in np.unique(idx_np):
        rows = np.where(idx_np == seg)[0]
        perm[rows] = rng.permutation(rows)
    got = ops.segment_reduce(jnp.asarray(np.asarray(x)[perm]),
                             jnp.asarray(idx_np[perm]), s)
    want = ops.segment_reduce(x, idx, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(segment_problem())
def test_mean_times_count_equals_sum(problem):
    x, idx, s = problem
    mean = ops.segment_reduce(x, idx, s, "mean")
    total = ops.segment_reduce(x, idx, s, "sum")
    cnt = jax.ops.segment_sum(jnp.ones((x.shape[0],)), idx, s,
                              indices_are_sorted=True)
    np.testing.assert_allclose(mean * jnp.maximum(cnt, 1.0)[:, None], total,
                               rtol=1e-4, atol=1e-4)


@SET
@given(segment_problem())
def test_sum_conservation(problem):
    """Σ_s Y[s] == Σ_i X[i] — reduction conserves mass."""
    x, idx, s = problem
    y = ops.segment_reduce(x, idx, s)
    np.testing.assert_allclose(jnp.sum(y, 0), jnp.sum(x, 0),
                               rtol=1e-3, atol=1e-3)


@SET
@given(segment_problem())
def test_gather_vjp_roundtrip(problem):
    """<gather(h), g> == <h, scatter(g)> — adjointness of the VJP pair."""
    x, idx, s = problem
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.standard_normal((s, x.shape[1])).astype(np.float32))
    g = x
    lhs = jnp.sum(ops.gather(h, idx) * g)
    dh = jax.grad(lambda h: jnp.sum(ops.gather(h, idx) * g))(h)
    rhs = jnp.sum(h * dh) / 1.0
    # adjointness: dh == scatter-add(g) so <h, dh> == <gather(h), g>
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


@SET
@given(st.integers(1, 10 ** 8), st.integers(1, 10 ** 6), st.integers(1, 512))
def test_selected_config_always_valid(m, s, f):
    """The generated rules always emit a VMEM-feasible pruned-space config."""
    cfg = select_config(m, s, f)
    assert cfg.schedule in ("SR", "PR")
    assert cfg.vmem_bytes() <= 16 * 1024 * 1024
    valid = {c.astuple() for c in all_configs()}
    assert cfg.astuple() in valid


@SET
@given(st.integers(1, 10 ** 8), st.integers(1, 10 ** 6), st.integers(1, 512))
def test_features_o1(m, s, f):
    feats = InputFeatures(m, s, f)
    v = feats.as_vector()
    assert v.shape == (3,) and np.all(np.isfinite(v))
