"""Sharded message-passing checks, run in a subprocess with 8 host devices
(tests/test_sharded_mp.py drives this; the CI distributed smoke step runs
it directly)."""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ops as geot                     # noqa: E402
from repro.core.dist_mp import (make_shard_mesh, mp_sharded,               # noqa: E402
                                mp_transform_sharded, segment_softmax_sharded)
from repro.core.mp import mp                           # noqa: E402
from repro.data.graphs import synth_graph              # noqa: E402
from repro.data.partition import partition_graph, unpartition_edges  # noqa: E402
from repro.kernels import ops as kops                  # noqa: E402
from repro.models import gnn                           # noqa: E402

REDUCES = ("sum", "mean", "max")


def _gapped_graph(v, e, f, seed, stride=5):
    """Every (stride)th node receives edges — empty segments inside and
    between shards."""
    rng = np.random.default_rng(seed)
    dst = np.sort(rng.choice(np.arange(0, v, stride), e)).astype(np.int32)
    src = rng.integers(0, v, e).astype(np.int32)
    from repro.data.graphs import Graph
    deg = np.bincount(dst, minlength=v).astype(np.float32)
    return Graph(name="gapped", edge_index=np.stack([src, dst]), num_nodes=v,
                 x=rng.standard_normal((v, f), dtype=np.float32),
                 labels=np.zeros(v, np.int32),
                 deg_inv_sqrt=(1.0 / np.sqrt(np.maximum(deg, 1.0)))
                 .astype(np.float32))


def _cases():
    yield synth_graph("skewed", 60, 300, feat=8, seed=3, alpha=1.2)
    yield _gapped_graph(70, 240, 8, seed=4)
    yield synth_graph("tiny", 9, 17, feat=8, seed=5)


def check_mp_sharded_parity():
    """partition_graph -> mp_sharded == single-device mp for every reduce,
    weighted and unweighted, on skewed and gapped graphs."""
    for g in _cases():
        x = jnp.asarray(g.x)
        ei = jnp.asarray(g.edge_index)
        rng = np.random.default_rng(11)
        w = jnp.asarray(rng.standard_normal(g.num_edges).astype(np.float32))
        for shards in (2, 4):
            if shards > g.num_nodes:
                continue
            pg = partition_graph(g, shards)
            pplan = pg.make_plan(feat=8)
            mesh = make_shard_mesh(shards)
            for reduce in REDUCES:
                for ew in (None, w):
                    want = mp(x, ei, g.num_nodes, reduce=reduce,
                              edge_weight=ew, impl="ref")
                    got = mp_sharded(x, pg, reduce=reduce, edge_weight=ew,
                                     pplan=pplan, mesh=mesh, impl="pallas")
                    np.testing.assert_allclose(
                        np.asarray(got), np.asarray(want), rtol=1e-5,
                        atol=1e-5,
                        err_msg=f"{g.name} shards={shards} {reduce} "
                                f"weighted={ew is not None}")
    print("mp_sharded parity OK")


def check_mp_sharded_property():
    """Property test: random skewed/gapped graphs, all four reduces
    (sum/mean/max/softmax) — hypothesis when installed (CI), seed sweep
    otherwise."""
    def one(v, e, stride, seed, shards, reduce):
        rng = np.random.default_rng(seed)
        lanes = np.arange(0, v, stride)
        dst = np.sort(rng.choice(lanes, e)).astype(np.int32)
        src = rng.integers(0, v, e).astype(np.int32)
        x = jnp.asarray(rng.standard_normal((v, 4)).astype(np.float32))
        from repro.data.graphs import Graph
        g = Graph(name="prop", edge_index=np.stack([src, dst]), num_nodes=v,
                  x=np.asarray(x), labels=np.zeros(v, np.int32),
                  deg_inv_sqrt=np.ones(v, np.float32))
        pg = partition_graph(g, shards)
        pplan = pg.make_plan(feat=4)
        mesh = make_shard_mesh(shards)
        tag = str((v, e, stride, seed, shards, reduce))
        if reduce == "softmax":
            logits = jnp.asarray(rng.standard_normal(e).astype(np.float32)
                                 * 8.0)
            got = unpartition_edges(pg, segment_softmax_sharded(
                logits, pg, pplan=pplan, mesh=mesh, impl="pallas"))
            want = geot.segment_softmax(logits, jnp.asarray(dst), v, "ref")
        else:
            got = mp_sharded(x, pg, reduce=reduce, pplan=pplan, mesh=mesh,
                             impl="pallas")
            want = mp(x, jnp.asarray(g.edge_index), v, reduce=reduce,
                      impl="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=tag)

    all_reduces = REDUCES + ("softmax",)
    try:
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=20, deadline=None)
        @given(st.integers(8, 90), st.integers(1, 150), st.integers(1, 7),
               st.integers(0, 2 ** 16), st.sampled_from([2, 3, 4, 8]),
               st.sampled_from(all_reduces))
        def prop(v, e, stride, seed, shards, reduce):
            one(v, e, stride, seed, min(shards, v), reduce)

        prop()
        tag = "hypothesis"
    except ImportError:
        for seed in range(8):
            rng = np.random.default_rng(seed + 100)
            one(int(rng.integers(8, 90)), int(rng.integers(1, 150)),
                int(rng.integers(1, 7)), seed, int(rng.choice([2, 3, 4])),
                all_reduces[seed % 4])
        tag = "seed sweep (hypothesis not installed)"
    print(f"mp_sharded property OK ({tag})")


def check_mp_sharded_grads():
    g = synth_graph("g", 60, 300, feat=8, seed=3)
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    w = jnp.asarray(
        np.random.default_rng(0).standard_normal(g.num_edges)
        .astype(np.float32))
    pg = partition_graph(g, 4)
    pplan = pg.make_plan(feat=8)
    mesh = make_shard_mesh(4)
    for reduce in REDUCES:
        for weighted in (False, True):
            def loss(x, w, sharded):
                ew = w if weighted else None
                if sharded:
                    y = mp_sharded(x, pg, reduce=reduce, edge_weight=ew,
                                   pplan=pplan, mesh=mesh, impl="pallas")
                else:
                    y = mp(x, ei, g.num_nodes, reduce=reduce, edge_weight=ew,
                           impl="ref")
                return jnp.sum(jnp.sin(y))

            gs = jax.grad(loss, (0, 1))(x, w, True)
            gr = jax.grad(loss, (0, 1))(x, w, False)
            for a, b in zip(gs, gr):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-5,
                                           err_msg=f"{reduce} {weighted}")
    # tied maxima spanning shards (constant features): the sharded max
    # subgradient may split ties differently than the single-device even
    # split (documented in core/dist_mp.py), but it must stay a *valid*
    # subgradient — the cotangent mass over each segment is conserved, so
    # the totals agree exactly
    ones = jnp.ones_like(x)

    def total(sharded):
        def loss(x):
            if sharded:
                y = mp_sharded(x, pg, reduce="max", pplan=pplan, mesh=mesh,
                               impl="pallas")
            else:
                y = mp(x, ei, g.num_nodes, reduce="max", impl="ref")
            return jnp.sum(y)
        return float(jnp.sum(jax.grad(loss)(ones)))

    np.testing.assert_allclose(total(True), total(False), rtol=1e-6)
    print("mp_sharded grads OK (incl. tie-mass conservation)")


def check_segment_softmax_sharded():
    """Two-stage online-softmax stat merge == single-device softmax,
    values and grads, 1-D and multi-head, large-magnitude logits."""
    g = _gapped_graph(60, 250, 4, seed=9, stride=3)
    ei = jnp.asarray(g.edge_index)
    pg = partition_graph(g, 4)
    pplan = pg.make_plan(feat=4)
    mesh = make_shard_mesh(4)
    rng = np.random.default_rng(2)
    for shape, scale in (((g.num_edges,), 1.0), ((g.num_edges, 3), 1e4)):
        e = jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)
        want = geot.segment_softmax(e, ei[1], g.num_nodes, "ref")
        got_st = segment_softmax_sharded(e, pg, pplan=pplan, mesh=mesh,
                                         impl="pallas")
        got = unpartition_edges(pg, got_st)
        assert bool(jnp.isfinite(got).all())
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-6)

        def l_sh(e):
            return jnp.sum(jnp.sin(segment_softmax_sharded(
                e, pg, pplan=pplan, mesh=mesh, impl="pallas")))

        def l_ref(e):
            return jnp.sum(jnp.sin(geot.segment_softmax(
                e, ei[1], g.num_nodes, "ref")))

        np.testing.assert_allclose(np.asarray(jax.grad(l_sh)(e)),
                                   np.asarray(jax.grad(l_ref)(e)),
                                   rtol=1e-4, atol=1e-6)
    print("segment_softmax_sharded OK")


def check_mp_transform_sharded():
    g = synth_graph("g", 50, 260, feat=16, seed=5)
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    pg = partition_graph(g, 4)
    pplan = pg.make_plan(feat=16)
    mesh = make_shard_mesh(4)
    wmat = jnp.asarray(
        np.random.default_rng(1).standard_normal((16, 160))
        .astype(np.float32) / 4.0)
    want = mp(x, ei, g.num_nodes, reduce="mean", impl="ref") @ wmat
    for order in ("auto", "aggregate_first", "transform_first"):
        got = mp_transform_sharded(x, wmat, pg, reduce="mean", pplan=pplan,
                                   mesh=mesh, impl="pallas", order=order)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3, err_msg=order)
    try:
        mp_transform_sharded(x, wmat, pg, reduce="max",
                             order="aggregate_first")
        raise AssertionError("max + aggregate_first must raise")
    except ValueError:
        pass
    print("mp_transform_sharded OK")


def check_ring_collective():
    """The ring_allreduce merge (dead distributed/ code now on the GNN hot
    path) matches the psum merge."""
    g = synth_graph("g", 64, 300, feat=8, seed=6)
    x = jnp.asarray(g.x)
    pg = partition_graph(g, 4)
    pplan = pg.make_plan(feat=8)
    mesh = make_shard_mesh(4)
    a = mp_sharded(x, pg, reduce="sum", pplan=pplan, mesh=mesh,
                   impl="pallas", collective="psum")
    b = mp_sharded(x, pg, reduce="sum", pplan=pplan, mesh=mesh,
                   impl="pallas", collective="ring")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
    print("ring-collective merge OK")


def check_models_sharded_parity():
    """gcn/gin/sage/gat forward + loss grads: 4-shard mesh == single device
    (the acceptance bar: 1e-5 on fp32 synth graphs)."""
    g = synth_graph("g", 50, 260, feat=8, seed=7)
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    dis = jnp.asarray(g.deg_inv_sqrt)
    labels = jnp.asarray((np.asarray(g.x[:, 0]) > 0).astype(np.int32))
    plan = g.make_plan(feat=16)
    pg = partition_graph(g, 4)
    pplan = pg.make_plan(feat=16)
    mesh = make_shard_mesh(4)
    for model in gnn.MODELS:
        heads = 3 if model == "gat" else 1
        prm = gnn.init(jax.random.PRNGKey(0), model, 8, 16, 2, heads=heads)
        want = gnn.forward(prm, model, x, ei, g.num_nodes, dis,
                           impl="pallas", plan=plan)
        got = gnn.forward(prm, model, x, ei, g.num_nodes, dis, impl="pallas",
                          plan=pplan, mesh=mesh, partition=pg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=model)

        g_ref = jax.grad(gnn.loss_fn)(prm, model, x, ei, labels, g.num_nodes,
                                      dis, "pallas", plan)
        g_sh = jax.grad(lambda p: gnn.loss_fn(
            p, model, x, ei, labels, g.num_nodes, dis, "pallas", pplan,
            mesh=mesh, partition=pg))(prm)
        for a, b in zip(jax.tree_util.tree_leaves(g_sh),
                        jax.tree_util.tree_leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5, err_msg=model)
    print("sharded model parity OK (fwd + grads, all four families)")


def check_fusion_accounting():
    """The sharded planned path launches only fused kernels — zero unfused
    segment-op fallbacks (trace-time accounting hooks)."""
    g = synth_graph("g", 50, 260, feat=8, seed=7)
    x = jnp.asarray(g.x)
    ei = jnp.asarray(g.edge_index)
    dis = jnp.asarray(g.deg_inv_sqrt)
    pg = partition_graph(g, 4)
    pplan = pg.make_plan(feat=16)
    mesh = make_shard_mesh(4)
    for model in gnn.MODELS:
        heads = 2 if model == "gat" else 1
        prm = gnn.init(jax.random.PRNGKey(0), model, 8, 16, 2, heads=heads)
        kops.reset_fusion_counts()
        jax.make_jaxpr(lambda x: gnn.forward(
            prm, model, x, ei, g.num_nodes, dis, impl="pallas", plan=pplan,
            mesh=mesh, partition=pg))(x)
        counts = kops.fusion_counts()
        fused = {k: v for k, v in counts.items() if k.startswith("fused:")}
        unfused = {k: v for k, v in counts.items()
                   if k.startswith("unfused:")}
        merge = {k: v for k, v in counts.items() if k.startswith("merge:")}
        assert fused and not unfused, (model, counts)
        if model == "gat":
            # the softmax stat merge must be *visible* in the accounting
            # (recorded as merge:, not silently un-instrumented)
            assert merge.get("merge:segment_softmax_stats"), (model, counts)
    kops.reset_fusion_counts()
    print("fusion accounting OK (sharded path: fused launches only; "
          "stat merges visible)")


def check_single_shard_degenerate():
    """num_shards=1 is the identity partition: no padding, no cut edges,
    and mp_sharded reduces to the plain planned path."""
    g = synth_graph("g", 40, 200, feat=8, seed=8)
    pg = partition_graph(g, 1)
    assert pg.halo.total_cut == 0 and pg.edges_per_shard == g.num_edges
    x = jnp.asarray(g.x)
    got = mp_sharded(x, pg, reduce="sum", pplan=pg.make_plan(feat=8),
                     mesh=make_shard_mesh(1), impl="pallas")
    want = mp(x, jnp.asarray(g.edge_index), g.num_nodes, reduce="sum",
              impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    print("single-shard degenerate OK")


def check_server_sharded_parity():
    """GNNServer with shards>1: the padded/bucketed serving loop routes
    through the partitioned mesh path and still matches a direct
    single-device planned forward per request."""
    from repro.serve import BucketPolicy, GNNServer
    rng = np.random.default_rng(9)
    graphs = [synth_graph(f"srv{i}", int(rng.integers(24, 90)),
                          int(rng.integers(40, 260)), feat=8, seed=i)
              for i in range(4)]
    for model in ("gcn", "gat"):
        heads = 2 if model == "gat" else 1
        prm = gnn.init(jax.random.PRNGKey(1), model, 8, 16, 2, heads=heads)
        srv = GNNServer(prm, model, impl="pallas", shards=2,
                        policy=BucketPolicy(min_nodes=32, min_edges=32),
                        max_batch_nodes=128, max_batch_graphs=2)
        for g in graphs:
            srv.submit(g)
        srv.run_until_drained()
        for uid, g in enumerate(graphs):
            want = gnn.forward(prm, model, jnp.asarray(g.x),
                               jnp.asarray(g.edge_index), g.num_nodes,
                               jnp.asarray(g.deg_inv_sqrt), impl="pallas",
                               plan=g.make_plan(feat=16))
            np.testing.assert_allclose(srv.results[uid].logits,
                                       np.asarray(want), rtol=1e-5,
                                       atol=1e-5)
    print("sharded serving parity OK (GNNServer shards=2, gcn + 2-head gat)")


if __name__ == "__main__":
    assert len(jax.devices()) >= 8, jax.devices()
    check_mp_sharded_parity()
    check_mp_sharded_property()
    check_mp_sharded_grads()
    check_segment_softmax_sharded()
    check_mp_transform_sharded()
    check_ring_collective()
    check_models_sharded_parity()
    check_fusion_accounting()
    check_single_shard_degenerate()
    check_server_sharded_parity()
    print("ALL SHARDED MP CHECKS OK")
