"""Continuous-batching serving (LM): requests with different prompt lengths
and budgets stream through a fixed-size decode batch; slots are reused the
tick after a request finishes (vLLM-style iteration-level scheduling on top
of the ragged decode_step).

The GNN twin of this demo is ``examples/gnn_serving.py``: variable-shape
*graphs* streaming through ``repro.serve.GNNServer`` — shape-bucketed
padding + a per-bucket plan/executable cache + block-diagonal micro-
batching replace the LM's fixed decode slots (see ``docs/serving.md``).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro import configs as cfglib
from repro.models import lm
from repro.serve.lm import ContinuousBatcher, Request

cfg = cfglib.get_config("qwen3-8b").reduced()
params = lm.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

batcher = ContinuousBatcher(params, cfg, batch_size=4, max_len=64)
for uid in range(10):
    batcher.submit(Request(
        uid=uid,
        prompt=rng.integers(0, cfg.vocab_size, rng.integers(3, 12)).astype(np.int32),
        max_new_tokens=int(rng.integers(4, 10)),
        on_token=lambda uid, tok: None,
    ))

t0 = time.perf_counter()
ticks = 0
while batcher.queue or any(not s.free for s in batcher.slots):
    n_active = batcher.tick()
    ticks += 1
dt = time.perf_counter() - t0

total_tokens = sum(len(v) for v in batcher.finished.values())
print(f"served {len(batcher.finished)} requests in {ticks} ticks "
      f"({dt:.2f}s, {total_tokens} tokens, batch=4 slots)")
for uid in sorted(batcher.finished)[:4]:
    print(f"  req {uid}: {batcher.finished[uid]}")
