"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps on the deterministic synthetic Markov language, with
checkpointing + fault-tolerant loop. Loss decreases by several nats.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.launch import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="qwen3-8b")
args = ap.parse_args()

losses = train.main([
    "--arch", args.arch, "--reduced",
    "--steps", str(args.steps), "--batch", "8", "--seq", "256",
    "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_100m",
    "--ckpt-every", "100", "--log-every", "20",
])
print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over {args.steps} steps")
