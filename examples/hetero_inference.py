"""Heterogeneous GNN inference on a relation-typed graph (FASTEN's
workload): 3-layer RGCN / relational-GAT node classification where every
layer's per-relation weight transforms run as **one** grouped
``segment_matmul`` launch (never a Python loop over types).

Everything goes through the public ``repro`` facade: a
:class:`~repro.data.graphs.TypedGraph` precomputes the (type, dst)
permutation triple once; ``make_plan`` / ``make_relation_plan`` build the
fused-reduce and grouped-matmul schedules once per graph; the typed models
consume both via the uniform layer signature. The grouped path is checked
against a per-type Python-loop reference, and on ``--impl pallas`` the
fusion counters verify exactly one ``segment_matmul`` launch per layer.

    PYTHONPATH=src python examples/hetero_inference.py [--relations 8]
                                                       [--impl ref|pallas]
                                                       [--nodes N --edges E]
"""
import argparse
import time

ap = argparse.ArgumentParser()
ap.add_argument("--nodes", type=int, default=2048)
ap.add_argument("--edges", type=int, default=16384)
ap.add_argument("--relations", type=int, default=8)
ap.add_argument("--hidden", type=int, default=64)
ap.add_argument("--heads", type=int, default=2,
                help="attention heads for the RGAT model")
ap.add_argument("--impl", default="ref", choices=["ref", "pallas"],
                help="aggregation backend (pallas runs interpreted on CPU)")
ap.add_argument("--tune", action="store_true",
                help="pick kernel configs from a measured autotuner sweep")
args = ap.parse_args()

import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
import numpy as np            # noqa: E402

import repro                  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402

g = repro.synth_typed_graph("hetero-demo", args.nodes, args.edges,
                            num_relations=args.relations, feat=32, seed=0)
counts = ", ".join(str(int(c)) for c in g.type_counts)
print(f"{g.name}: |V|={g.num_nodes:,} |E|={g.num_edges:,} "
      f"R={g.num_relations} (rows per relation: {counts})")

t0 = time.perf_counter()
plan = g.make_plan(feat=args.hidden, tune=args.tune or None)
rplan = g.make_relation_plan(feat=args.hidden, tune=args.tune or None)
print(f"  plans built in {(time.perf_counter() - t0) * 1e3:.1f} ms — "
      f"reduce grid {plan.max_chunks} (of {plan.worst_case_chunks}), "
      f"grouped grid {rplan.max_groups} (of {rplan.worst_case_groups})")

x = jnp.asarray(g.x)
ei = jnp.asarray(g.edge_index)
et = jnp.asarray(g.edge_type)
typed_kw = dict(edge_type=et, type_perm=jnp.asarray(g.type_perm),
                inv_type_perm=jnp.asarray(g.inv_type_perm),
                type_counts=jnp.asarray(g.type_counts), rplan=rplan)

# per-type loop reference for the first RGCN layer's typed aggregation —
# the thing the grouped launch replaces
def per_type_loop_messages(x, w_rel):
    src = g.edge_index[0]
    msg = jnp.zeros((g.num_edges, w_rel.shape[-1]), x.dtype)
    for r in range(g.num_relations):
        sel = np.where(g.edge_type == r)[0]
        msg = msg.at[sel].set(jnp.take(x, src[sel], axis=0) @ w_rel[r])
    return msg


for model in repro.TYPED_MODELS:
    heads = args.heads if model == "rgat" else 1
    params = repro.gnn_init(jax.random.PRNGKey(0), model, 32, args.hidden,
                            16, num_relations=g.num_relations, heads=heads)
    with kops.fusion_scope() as fused:
        fwd = jax.jit(lambda p, x: repro.gnn_forward(
            p, model, x, ei, g.num_nodes, impl=args.impl, plan=plan,
            **typed_kw))
        out = jax.block_until_ready(fwd(params, x))
    launches = fused.get("fused:segment_matmul", 0)
    if args.impl == "pallas":
        assert launches == len(params), (
            f"expected one grouped launch per layer, got {launches}")
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(fwd(params, x))
    dt = (time.perf_counter() - t0) / 3
    pred = jnp.argmax(out, -1)
    tag = f" heads={heads}" if model == "rgat" and heads > 1 else ""
    print(f"  {model:5s}: logits {out.shape}  {dt*1e3:7.1f} ms/inference "
          f"({args.impl}{tag})  grouped launches: {launches} "
          f"for {len(params)} layers  classes used: "
          f"{len(jnp.unique(pred))}")

# cross-check the grouped transform against the per-type loop
w_rel = params[0]["w_rel"].value
got = repro.grouped_segment_matmul(
    jnp.asarray(g.x)[jnp.asarray(g.typed_src)], jnp.asarray(g.type_counts),
    w_rel, args.impl, None, None)
want = per_type_loop_messages(jnp.asarray(g.x), w_rel)[g.type_perm]
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-4, f"grouped vs per-type loop diverged: {err}"
print(f"  grouped vs per-type-loop parity: max|Δ| = {err:.2e}")
