"""MoE training with GeoT dispatch/combine (DESIGN.md §4): a reduced
qwen3-moe-30b-a3b trains for a few dozen steps; the expert combine is the
paper's fused ``index_weight_segment_reduce`` and the dropless path runs
grouped GEMM over expert segments.

    PYTHONPATH=src python examples/moe_training.py [--steps 60]
"""
import argparse

from repro.launch import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--moe-impl", choices=["capacity", "ragged"],
                default="ragged")
args = ap.parse_args()

losses = train.main([
    "--arch", "qwen3-moe-30b-a3b", "--reduced",
    "--steps", str(args.steps), "--batch", "8", "--seq", "128",
    "--lr", "1e-3", "--moe-impl", args.moe_impl,
    "--ckpt-dir", "/tmp/repro_moe_example", "--log-every", "10",
])
print(f"MoE ({args.moe_impl} dispatch) loss: "
      f"{losses[0]:.3f} → {losses[-1]:.3f}")
