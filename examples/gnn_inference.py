"""End-to-end GNN inference (the paper's §V-F workload): 3-layer GCN /
GIN / GraphSAGE node classification on Table-II-scale graphs, aggregation
via GeoT fused ops.

A :class:`~repro.core.plan.SegmentPlan` is built once per graph and reused
by every layer of every model (the FASTEN-style amortization): the schedule
metadata and the tight kernel grid are paid for a single time, not per call.

    PYTHONPATH=src python examples/gnn_inference.py [--dataset ogbn-arxiv]
                                                    [--impl ref|blocked|pallas]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.graphs import all_dataset_names, dataset
from repro.models import gnn

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="flickr", choices=all_dataset_names())
ap.add_argument("--hidden", type=int, default=64)
ap.add_argument("--impl", default="ref", choices=["ref", "blocked", "pallas"],
                help="aggregation backend (pallas runs interpreted on CPU)")
ap.add_argument("--no-plan", action="store_true",
                help="skip the precomputed SegmentPlan (ablation)")
ap.add_argument("--tune", action="store_true",
                help="select the kernel config from a measured autotuner "
                     "sweep (cached in the persistent PerfDB) instead of "
                     "the generated decision-tree rules")
args = ap.parse_args()

g = dataset(args.dataset, feat=32)
print(f"{g.name}: |V|={g.num_nodes:,} |E|={g.num_edges:,}")
x = jnp.asarray(g.x)
ei = jnp.asarray(g.edge_index)
dis = jnp.asarray(g.deg_inv_sqrt)

plan = None
if not args.no_plan:
    t0 = time.perf_counter()
    plan = g.make_plan(feat=args.hidden, tune=args.tune or None)
    dt = time.perf_counter() - t0
    print(f"  plan: config={plan.config.astuple()}  "
          f"max_chunks={plan.max_chunks} (worst case "
          f"{plan.worst_case_chunks}, {plan.grid_savings:.1f}x tighter)  "
          f"skew={plan.stats.skew:.1f}  built in {dt*1e3:.1f} ms")

for model in ("gcn", "gin", "sage"):
    params = gnn.init(jax.random.PRNGKey(0), model, 32, args.hidden, 16)
    fwd = jax.jit(lambda p, x: gnn.forward(p, model, x, ei, g.num_nodes, dis,
                                           impl=args.impl, plan=plan))
    out = jax.block_until_ready(fwd(params, x))          # compile + run
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(fwd(params, x))
    dt = (time.perf_counter() - t0) / 3
    pred = jnp.argmax(out, -1)
    print(f"  {model:5s}: logits {out.shape}  {dt*1e3:7.1f} ms/inference "
          f"({args.impl})  classes used: {len(jnp.unique(pred))}")
