"""End-to-end GNN inference (the paper's §V-F workload): 3-layer GCN /
GIN / GraphSAGE / GAT node classification on Table-II-scale graphs, every
aggregation routed through the unified ``core/mp.py`` message-passing
primitive (fused sum/mean/max + segment_softmax kernels on
``--impl pallas``).

A :class:`~repro.core.plan.SegmentPlan` is built once per graph and reused
by every layer of every model (the FASTEN-style amortization): the schedule
metadata and the tight kernel grid are paid for a single time, not per call.

With ``--shards N`` the whole model runs sharded over an N-device mesh
(host devices faked via ``XLA_FLAGS=--xla_force_host_platform_device_count``
when the flag isn't already set): the graph is partitioned
(:mod:`repro.data.partition`), one stacked per-shard plan drives the same
fused kernels per shard, and halo contributions merge with psum/pmax/
softmax-stat collectives (:mod:`repro.core.dist_mp`). The sharded logits
are checked against the single-device run.

    PYTHONPATH=src python examples/gnn_inference.py [--dataset ogbn-arxiv]
                                                    [--impl ref|blocked|pallas]
                                                    [--heads 4] [--scale 0.25]
                                                    [--shards 4]
"""
import argparse
import os
import sys
import time

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="flickr")
ap.add_argument("--hidden", type=int, default=64)
ap.add_argument("--impl", default="ref", choices=["ref", "blocked", "pallas"],
                help="aggregation backend (pallas runs interpreted on CPU)")
ap.add_argument("--models", default=None,
                help="comma-separated subset of the model families "
                     "(default: all)")
ap.add_argument("--heads", type=int, default=1,
                help="attention heads for the GAT model (multi-head "
                     "segment_softmax is one fused launch)")
ap.add_argument("--scale", type=float, default=1.0,
                help="scale the dataset's |V|,|E| down (CI smoke runs)")
ap.add_argument("--no-plan", action="store_true",
                help="skip the precomputed SegmentPlan (ablation)")
ap.add_argument("--tune", action="store_true",
                help="select the kernel config from a measured autotuner "
                     "sweep (cached in the persistent PerfDB) instead of "
                     "the generated decision-tree rules")
ap.add_argument("--shards", type=int, default=0,
                help="run the models sharded over an N-device mesh "
                     "(partitioned graph + per-shard fused kernels + "
                     "collective halo merge); 0 = single device")
args = ap.parse_args()

# the host-platform device count must be pinned before jax initializes its
# backends — do it here, before the first jax import touches device state
if args.shards > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count="
                               f"{max(args.shards, 8)}")

import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
import numpy as np            # noqa: E402

from repro.data.graphs import all_dataset_names, dataset  # noqa: E402
from repro.models import gnn  # noqa: E402

if args.dataset not in all_dataset_names():
    sys.exit(f"unknown dataset {args.dataset!r}; "
             f"choose from {', '.join(all_dataset_names())}")

g = dataset(args.dataset, feat=32, scale=args.scale)
print(f"{g.name}: |V|={g.num_nodes:,} |E|={g.num_edges:,}")
x = jnp.asarray(g.x)
ei = jnp.asarray(g.edge_index)
dis = jnp.asarray(g.deg_inv_sqrt)

plan = None
if not args.no_plan:
    t0 = time.perf_counter()
    plan = g.make_plan(feat=args.hidden, tune=args.tune or None)
    dt = time.perf_counter() - t0
    print(f"  plan: config={plan.config.astuple()}  "
          f"max_chunks={plan.max_chunks} (worst case "
          f"{plan.worst_case_chunks}, {plan.grid_savings:.1f}x tighter)  "
          f"skew={plan.stats.skew:.1f}  built in {dt*1e3:.1f} ms")

partition = pplan = mesh = None
if args.shards > 1:
    from repro.core.dist_mp import make_shard_mesh
    t0 = time.perf_counter()
    partition = g.partition(args.shards)
    pplan = partition.make_plan(feat=args.hidden, tune=args.tune or None)
    mesh = make_shard_mesh(args.shards)
    dt = time.perf_counter() - t0
    counts = [int(c) for c in np.asarray(partition.edge_valid).sum(1)] \
        if partition.edges_per_shard else [0] * args.shards
    print(f"  partition: {args.shards} shards  edges/shard={counts}  "
          f"cut edges={partition.halo.total_cut} "
          f"({100 * partition.halo.cut_fraction:.1f}%)  "
          f"shard grid max_chunks={pplan.max_chunks}  "
          f"built in {dt*1e3:.1f} ms")

for model in (args.models or ",".join(gnn.MODELS)).split(","):
    heads = args.heads if model == "gat" else 1
    params = gnn.init(jax.random.PRNGKey(0), model, 32, args.hidden, 16,
                      heads=heads)
    fwd = jax.jit(lambda p, x: gnn.forward(p, model, x, ei, g.num_nodes, dis,
                                           impl=args.impl, plan=plan))
    out = jax.block_until_ready(fwd(params, x))          # compile + run
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(fwd(params, x))
    dt = (time.perf_counter() - t0) / 3
    pred = jnp.argmax(out, -1)
    tag = f" heads={heads}" if model == "gat" and heads > 1 else ""
    print(f"  {model:5s}: logits {out.shape}  {dt*1e3:7.1f} ms/inference "
          f"({args.impl}{tag})  classes used: {len(jnp.unique(pred))}")
    if partition is not None:
        fwd_sh = jax.jit(lambda p, x: gnn.forward(
            p, model, x, ei, g.num_nodes, dis, impl=args.impl, plan=pplan,
            mesh=mesh, partition=partition))
        out_sh = jax.block_until_ready(fwd_sh(params, x))
        t0 = time.perf_counter()
        for _ in range(3):
            out_sh = jax.block_until_ready(fwd_sh(params, x))
        dt_sh = (time.perf_counter() - t0) / 3
        err = float(jnp.max(jnp.abs(out_sh - out)))
        assert err < 1e-4, f"sharded {model} diverged: max err {err}"
        print(f"         sharded x{args.shards}: {dt_sh*1e3:7.1f} "
              f"ms/inference  max|Δ| vs single device = {err:.2e}")
