"""End-to-end GNN inference (the paper's §V-F workload): 3-layer GCN /
GIN / GraphSAGE / GAT node classification on Table-II-scale graphs, every
aggregation routed through the unified ``core/mp.py`` message-passing
primitive (fused sum/mean/max + segment_softmax kernels on
``--impl pallas``).

A :class:`~repro.core.plan.SegmentPlan` is built once per graph and reused
by every layer of every model (the FASTEN-style amortization): the schedule
metadata and the tight kernel grid are paid for a single time, not per call.

    PYTHONPATH=src python examples/gnn_inference.py [--dataset ogbn-arxiv]
                                                    [--impl ref|blocked|pallas]
                                                    [--heads 4] [--scale 0.25]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.graphs import all_dataset_names, dataset
from repro.models import gnn

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="flickr", choices=all_dataset_names())
ap.add_argument("--hidden", type=int, default=64)
ap.add_argument("--impl", default="ref", choices=["ref", "blocked", "pallas"],
                help="aggregation backend (pallas runs interpreted on CPU)")
ap.add_argument("--models", default=",".join(gnn.MODELS),
                help="comma-separated subset of " + ",".join(gnn.MODELS))
ap.add_argument("--heads", type=int, default=1,
                help="attention heads for the GAT model (multi-head "
                     "segment_softmax is one fused launch)")
ap.add_argument("--scale", type=float, default=1.0,
                help="scale the dataset's |V|,|E| down (CI smoke runs)")
ap.add_argument("--no-plan", action="store_true",
                help="skip the precomputed SegmentPlan (ablation)")
ap.add_argument("--tune", action="store_true",
                help="select the kernel config from a measured autotuner "
                     "sweep (cached in the persistent PerfDB) instead of "
                     "the generated decision-tree rules")
args = ap.parse_args()

g = dataset(args.dataset, feat=32, scale=args.scale)
print(f"{g.name}: |V|={g.num_nodes:,} |E|={g.num_edges:,}")
x = jnp.asarray(g.x)
ei = jnp.asarray(g.edge_index)
dis = jnp.asarray(g.deg_inv_sqrt)

plan = None
if not args.no_plan:
    t0 = time.perf_counter()
    plan = g.make_plan(feat=args.hidden, tune=args.tune or None)
    dt = time.perf_counter() - t0
    print(f"  plan: config={plan.config.astuple()}  "
          f"max_chunks={plan.max_chunks} (worst case "
          f"{plan.worst_case_chunks}, {plan.grid_savings:.1f}x tighter)  "
          f"skew={plan.stats.skew:.1f}  built in {dt*1e3:.1f} ms")

for model in args.models.split(","):
    heads = args.heads if model == "gat" else 1
    params = gnn.init(jax.random.PRNGKey(0), model, 32, args.hidden, 16,
                      heads=heads)
    fwd = jax.jit(lambda p, x: gnn.forward(p, model, x, ei, g.num_nodes, dis,
                                           impl=args.impl, plan=plan))
    out = jax.block_until_ready(fwd(params, x))          # compile + run
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(fwd(params, x))
    dt = (time.perf_counter() - t0) / 3
    pred = jnp.argmax(out, -1)
    tag = f" heads={heads}" if model == "gat" and heads > 1 else ""
    print(f"  {model:5s}: logits {out.shape}  {dt*1e3:7.1f} ms/inference "
          f"({args.impl}{tag})  classes used: {len(jnp.unique(pred))}")
