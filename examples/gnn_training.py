"""End-to-end GNN training through the ``repro.train`` orchestration API
(ISSUE 7): DatasetProvider → Task → Trainer on the planned-Pallas models.

The example *asserts the training contract itself*:

  * loss decreases for every trained family (gcn homogeneous + rgcn
    relational by default);
  * the jitted train step compiles **exactly once per graph shape
    bucket** — the provider's plan memo plus the task's per-bucket plan
    canonicalization mean steps never re-plan and never retrace
    (``FitResult.traces == len(FitResult.buckets)``);
  * a mid-run kill (``--kill-at``, exercised via a subprocess) followed
    by ``fit(resume=True)`` restores from the checkpoint to a loss
    trajectory identical (≤ 1e-6, in practice bitwise) to the
    uninterrupted run — providers are deterministic in the step index
    and the PRNG key rides the checkpointed TrainState.

Usage:
  python examples/gnn_training.py                  # full smoke (CI default)
  python examples/gnn_training.py --models gcn --steps 60
  python examples/gnn_training.py --resume --ckpt-dir /tmp/d   # resume leg
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile

from repro import fit  # the facade export — the acceptance criterion
from repro.optim import adamw
from repro.train import (GraphEpochProvider, NodeClassification, Trainer,
                         TrainerConfig)

SHAPES = ((96, 384), (128, 512))


def build(model: str, args, ckpt_dir=None):
    typed = model in ("rgcn", "rgat")
    data = GraphEpochProvider(
        shapes=SHAPES, graphs_per_shape=2, feat=args.feat,
        num_classes=args.classes, typed=typed, num_relations=4,
        seed=args.seed)
    task = NodeClassification.from_provider(data, model=model,
                                            hidden=args.hidden,
                                            impl=args.impl)
    cfg = TrainerConfig(
        steps=args.steps, warmup_steps=4,
        opt=adamw.AdamWConfig(lr=args.lr, weight_decay=0.0),
        seed=args.seed, ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every)
    return task, data, cfg


def train_full(model: str, args):
    task, data, cfg = build(model, args)
    trainer = Trainer(task, data, cfg)
    res = trainer.fit()
    n_buckets = len(SHAPES)
    assert res.losses[-1] < res.losses[0], (
        f"{model}: loss did not decrease "
        f"({res.losses[0]:.4f} -> {res.losses[-1]:.4f})")
    assert res.traces == len(res.buckets) == n_buckets, (
        f"{model}: expected exactly one trace per shape bucket "
        f"({n_buckets}), got traces={res.traces} buckets={res.buckets}")
    print(f"[{model}] loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}  "
          f"traces={res.traces} buckets={len(res.buckets)}  OK")
    return res


def kill_and_resume(args):
    """Child process trains gcn and dies mid-run; we resume from its
    checkpoint and require the combined trajectory to match the
    uninterrupted run's to <= 1e-6."""
    full = train_full("gcn", args)
    kill_at = args.steps // 2 - 1
    with tempfile.TemporaryDirectory(prefix="repro_train_ckpt_") as d:
        cmd = [sys.executable, os.path.abspath(__file__),
               "--models", "gcn", "--steps", str(args.steps),
               "--lr", str(args.lr), "--seed", str(args.seed),
               "--hidden", str(args.hidden), "--impl", args.impl,
               "--ckpt-dir", d, "--ckpt-every", str(args.ckpt_every),
               "--kill-at", str(kill_at)]
        proc = subprocess.run(cmd, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr
        task, data, cfg = build("gcn", args, ckpt_dir=d)
        res = Trainer(task, data, cfg).fit(resume=True)
        expect_start = (kill_at // args.ckpt_every) * args.ckpt_every
        assert res.start_step == expect_start > 0, (
            res.start_step, expect_start)
        tail = full.losses[res.start_step:]
        assert len(tail) == len(res.losses)
        worst = max(abs(a - b) for a, b in zip(tail, res.losses))
        assert worst <= 1e-6, (
            f"resumed trajectory diverged: max |Δloss| = {worst:.2e}")
        print(f"[resume] killed at step {kill_at}, restored step "
              f"{res.start_step}, max |Δloss| vs uninterrupted run "
              f"{worst:.2e}  OK")


def run_killed(model: str, args):
    """The subprocess leg: train with checkpoints, hard-exit mid-run."""
    task, data, cfg = build(model, args, ckpt_dir=args.ckpt_dir)

    def cb(step, metrics, verdict):
        if step >= args.kill_at:
            # simulate a hard crash: no cleanup, no final checkpoint
            os._exit(0)

    Trainer(task, data, cfg).fit(metrics_cb=cb)
    raise SystemExit(f"kill at step {args.kill_at} never happened")


def run_resume(args):
    """Explicit --resume leg: continue a run from --ckpt-dir."""
    model = args.models.split(",")[0]
    task, data, cfg = build(model, args, ckpt_dir=args.ckpt_dir)
    res = Trainer(task, data, cfg).fit(resume=True)
    assert res.start_step > 0, "nothing to resume from"
    # the epoch cycles through several distinct graphs, so compare
    # epoch-mean losses, not raw endpoints (different graphs)
    n = len(data)
    assert len(res.losses) >= 2 * n, "resumed run too short to judge"
    first = sum(res.losses[:n]) / n
    last = sum(res.losses[-n:]) / n
    assert last < first, (first, last)
    print(f"[{model}] resumed from step {res.start_step}, "
          f"epoch-mean loss {first:.4f} -> {last:.4f}  OK")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="gcn,rgcn",
                    help="comma-separated: gcn gin sage gat rgcn rgat")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="pallas", choices=["ref", "pallas"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=8)
    ap.add_argument("--kill-at", type=int, default=None,
                    help="(internal) hard-exit at this step")
    ap.add_argument("--resume", action="store_true",
                    help="resume the first of --models from --ckpt-dir")
    ap.add_argument("--skip-kill-test", action="store_true")
    args = ap.parse_args(argv)

    if args.kill_at is not None:
        run_killed(args.models.split(",")[0], args)
        return
    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume needs --ckpt-dir")
        run_resume(args)
        return

    models = [m for m in args.models.split(",") if m]
    for model in models:
        if model != "gcn" or args.skip_kill_test:
            train_full(model, args)
    if not args.skip_kill_test and "gcn" in models:
        kill_and_resume(args)
    print("all training checks passed")


if __name__ == "__main__":
    main()
