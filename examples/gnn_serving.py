"""GNN serving: a stream of random-shape graphs through ``GNNServer`` —
shape-bucketed padding + plan/executable cache + block-diagonal
continuous batching over the planned Pallas path (the GNN twin of
``examples/continuous_batching.py``'s LM demo).

The demo asserts the engine's serving contract end to end:

  * **bounded compiles** — the whole stream triggers at most one compile
    per shape bucket (executables are cached per bucket; per-request work
    is a chunk-metadata stamp, never a retrace);
  * **hot cache** — after the bucket-ladder warmup, the plan-cache hit
    rate over the stream is >= 80% (default: 100%);
  * **exactness** — every served result matches a direct planned-pallas
    ``models/gnn.forward`` on the request's own (unpadded, individually
    planned) graph at 1e-5.

    PYTHONPATH=src python examples/gnn_serving.py [--requests 200]
        [--min-nodes 64] [--max-nodes 4096] [--model gcn] [--heads 1]
        [--impl pallas] [--check all|sample|none] [--no-warmup]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import synth_graph
from repro.models import gnn
from repro.serve import BucketPolicy, GNNServer, bucket_for, bucket_rungs

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=200)
ap.add_argument("--min-nodes", type=int, default=64)
ap.add_argument("--max-nodes", type=int, default=4096)
ap.add_argument("--edge-factor", type=float, default=3.0,
                help="mean edges per node of the synthetic request graphs")
ap.add_argument("--feat", type=int, default=32)
ap.add_argument("--hidden", type=int, default=32)
ap.add_argument("--model", default="gcn", choices=list(gnn.MODELS))
ap.add_argument("--heads", type=int, default=1)
ap.add_argument("--impl", default="pallas",
                choices=["ref", "blocked", "pallas"])
ap.add_argument("--max-batch-nodes", type=int, default=4096,
                help="continuous-batching node budget per micro-batch")
ap.add_argument("--max-batch-graphs", type=int, default=8)
ap.add_argument("--check", default="all", choices=["all", "sample", "none"],
                help="verify served logits against a direct per-request "
                     "forward (sample: every 8th request)")
ap.add_argument("--no-warmup", action="store_true",
                help="skip the bucket-ladder warmup (first-touch batches "
                     "then pay the compile inline and count as misses)")
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

rng = np.random.default_rng(args.seed)

# -- the request stream: log-uniform |V|, power-law degree graphs ----------
graphs = []
for i in range(args.requests):
    v = int(np.exp(rng.uniform(np.log(args.min_nodes),
                               np.log(args.max_nodes))))
    e = int(v * rng.uniform(args.edge_factor / 2, args.edge_factor * 2))
    graphs.append(synth_graph(f"req{i}", v, e, feat=args.feat, seed=i))

params = gnn.init(jax.random.PRNGKey(0), args.model, args.feat, args.hidden,
                  16, heads=args.heads)
policy = BucketPolicy(min_nodes=64, min_edges=64)

# -- warmup: the bucket ladder the stream + batcher can touch --------------
# Every micro-batch has V <= max(max_batch_nodes, largest single graph) and
# edge density E/V in [edge_factor/2, 2*edge_factor] (each member's
# generator bound carries to sums); pow-2 rounding widens the bucket ratio
# by at most 2x each way, so E_b/V_b lands in [edge_factor/4, 4*edge_factor]
# — except where a floor dominates. Warming each reachable (V, E) rung
# compiles ahead of traffic, so serving runs 100% hot and the compile
# count equals len(ladder) exactly.
max_v = max(args.max_batch_nodes, max(g.num_nodes for g in graphs))
max_e = int(2 * args.edge_factor * max_v)


def _reachable(v, e):
    hi = max(policy.min_edges, 4 * args.edge_factor * v)
    lo = args.edge_factor * v / 4
    return e <= hi and (e >= lo or v == policy.min_nodes
                        or e == policy.min_edges)


ladder = sorted(
    bucket_for(v, e, policy)
    for v in bucket_rungs(max_v, policy.min_nodes, policy.growth)
    for e in bucket_rungs(max_e, policy.min_edges, policy.growth)
    if _reachable(v, e))

# the executable cache must hold the whole ladder: an evicted bucket would
# recompile on its next touch — exactly the churn the compile bound forbids
server = GNNServer(params, args.model, impl=args.impl, policy=policy,
                   max_batch_nodes=args.max_batch_nodes,
                   max_batch_graphs=args.max_batch_graphs,
                   cache_capacity=len(ladder) + 8)
if not args.no_warmup:
    t0 = time.perf_counter()
    n = server.warmup(ladder)
    print(f"warmup: compiled {n} bucket executables "
          f"({time.perf_counter() - t0:.1f}s)")

# -- serve the stream ------------------------------------------------------
t0 = time.perf_counter()
for g in graphs:
    server.submit(g)
server.run_until_drained()
serve_wall = time.perf_counter() - t0
s = server.stats()

print(f"served {s['requests']} requests in {s['batches']} micro-batches "
      f"({serve_wall:.1f}s, {s['requests'] / serve_wall:.1f} req/s)")
print(f"  buckets={s['buckets']}  compiles={s['compiles']}  "
      f"cache hit rate={s['cache']['hit_rate']:.1%}  "
      f"(hits={s['cache']['hits']} misses={s['cache']['misses']} "
      f"prefills={s['cache']['prefills']})")
print(f"  latency mean={s['latency_mean_s'] * 1e3:.1f}ms "
      f"p95={s['latency_p95_s'] * 1e3:.1f}ms  "
      f"pad overhead: nodes x{s['pad_node_overhead']:.2f} "
      f"edges x{s['pad_edge_overhead']:.2f}")

# -- the serving contract --------------------------------------------------
assert len(server.results) == args.requests, "requests dropped"
n_buckets = len(ladder) if not args.no_warmup else s["buckets"]
assert s["compiles"] <= n_buckets, \
    f"{s['compiles']} compiles > {n_buckets} buckets"
if not args.no_warmup:
    assert s["cache"]["hit_rate"] >= 0.8, \
        f"hit rate {s['cache']['hit_rate']:.1%} < 80%"

if args.check != "none":
    idxs = (range(args.requests) if args.check == "all"
            else range(0, args.requests, 8))
    t0 = time.perf_counter()
    worst = 0.0
    for i in idxs:
        g = graphs[i]
        plan = g.make_plan(feat=args.hidden)
        direct = gnn.forward(params, args.model, jnp.asarray(g.x),
                             jnp.asarray(g.edge_index), g.num_nodes,
                             jnp.asarray(g.deg_inv_sqrt), impl=args.impl,
                             plan=plan)
        direct = np.asarray(jax.block_until_ready(direct))
        served = server.results[i].logits
        np.testing.assert_allclose(served, direct, rtol=1e-5, atol=1e-5,
                                   err_msg=f"request {i} ({g.name}) diverged")
        worst = max(worst, float(np.max(np.abs(served - direct))))
    print(f"  parity: {len(list(idxs))} requests vs direct planned-{args.impl}"
          f" forward, max|Δ|={worst:.2e} "
          f"({time.perf_counter() - t0:.1f}s)")
print("serving contract holds: compiles <= buckets, cache hot, "
      "served == direct")
