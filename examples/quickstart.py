"""Quickstart: the GeoT tensor-centric API in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (index_weight_segment_reduce, segment_reduce,
                        select_config)
from repro.kernels import ops as kops

rng = np.random.default_rng(0)

# --- segment reduction (paper Fig. 2): sorted Idx, dense X — no sparse
# formats anywhere (format-agnostic, §IV) -----------------------------------
M, S, F = 10_000, 1_000, 32
idx = jnp.asarray(np.sort(rng.integers(0, S, M)).astype(np.int32))
x = jnp.asarray(rng.standard_normal((M, F), np.float32))

y = segment_reduce(x, idx, S)                       # sum per segment
print("segment_reduce:", y.shape)

# --- data-aware config selection (paper §III-C): O(1) features → codegen'd
# decision-tree rules pick ⟨schedule, S_b, N_b, M_b, K_c⟩ -------------------
cfg = select_config(M, S, F)
print("selected config:", cfg)

# --- the Pallas TPU kernel (interpret=True on CPU) -------------------------
y_kernel = kops.segment_reduce(x, idx, S, config=cfg, interpret=True)
print("pallas == oracle:", bool(jnp.allclose(y_kernel, y, atol=1e-3)))

# --- fused message+aggregate ≡ SpMM (paper Listing 2, §IV) -----------------
V = 2_000
h = jnp.asarray(rng.standard_normal((V, F), np.float32))
src = jnp.asarray(rng.integers(0, V, M).astype(np.int32))
w = jnp.asarray(rng.standard_normal(M).astype(np.float32))
out = index_weight_segment_reduce(h, src, w, idx, S)
print("fused SpMM:", out.shape)

# --- it is all differentiable (beyond-paper: autograd, §VI) ----------------
grad = jax.grad(lambda h: jnp.sum(
    index_weight_segment_reduce(h, src, w, idx, S) ** 2))(h)
print("d(SpMM)/dH:", grad.shape, "— VJP is itself a segment reduction")
