"""End-to-end out-of-core sampled GNN training (ISSUE 9): neighbor
sampling into bucketed subgraphs + double-buffered async host→device
prefetch, over a graph the device never sees whole.

The example *asserts the pipeline contract itself*:

  * **zero retraces**: across a long sampled stream (200 batches by
    default) the jitted train step compiles exactly once per shape
    bucket — and the bucket set is known *in advance* by probing the
    deterministic sampler, so ``traces == probed buckets`` is checked
    too, not just ``traces == buckets seen``;
  * **measured overlap**: with prefetch depth >= 2 the steady-state
    consumer wait is a small fraction of the host production cost the
    pipeline is hiding (the blocking depth-0 loader pays all of it);
  * **exact parity**: an exact-neighborhood sampler reproduces the
    full-graph forward's logits on the seed nodes to 1e-5;
  * **out-of-core**: the same stream sampled from an on-disk sharded
    store (bounded shard LRU) is bitwise the in-memory stream;
  * **serving ingest**: ``GNNServer.serve_sampled`` serves the stream
    from the same shared plan/executable cache, one compile per bucket.

Usage:
  python examples/gnn_sampled_training.py                # CI smoke
  python examples/gnn_sampled_training.py --steps 500 --depth 3
"""
from __future__ import annotations

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.data.graphs import synth_graph
from repro.data.pipeline import SampledBatchProducer
from repro.data.sampling import (NeighborSampler, ShardedGraphStore,
                                 save_graph_shards)
from repro.models import gnn
from repro.optim import adamw
from repro.serve import GNNServer
from repro.train import SampledNodeProvider


def probe_buckets(graph, args):
    """The bucket set the stream will touch — sampling is deterministic,
    so probing the sampler host-side IS the schedule."""
    sampler = NeighborSampler(graph, fanouts=tuple(args.fanouts),
                              batch_size=args.batch_size, seed=args.seed)
    producer = SampledBatchProducer(sampler, feat=args.hidden)
    return producer.buckets_for_warmup(probe_steps=args.steps)


def train_sampled(graph, args):
    data = SampledNodeProvider(
        graph, fanouts=tuple(args.fanouts), batch_size=args.batch_size,
        plan_feat=max(args.hidden, graph.x.shape[1]), depth=args.depth,
        seed=args.seed)
    task = repro.NodeClassification.from_provider(
        data, model="gcn", hidden=args.hidden, impl=args.impl)
    cfg = repro.TrainerConfig(
        steps=args.steps, warmup_steps=4,
        opt=adamw.AdamWConfig(lr=args.lr, weight_decay=0.0), seed=args.seed)
    with data:
        res = repro.fit(task, data, cfg)
        stats = data.stats()

    expected = probe_buckets(graph, args)
    assert res.traces == len(res.buckets) == len(expected), (
        f"retrace leak: traces={res.traces} buckets={len(res.buckets)} "
        f"probed={len(expected)} over {args.steps} batches")
    assert all(s.sampled for s in res.buckets)

    wait_med = stats["wait_s_median_steady"]
    prod_med = stats["produce_s_median_steady"]
    assert wait_med < 0.5 * prod_med, (
        f"prefetch depth={args.depth} hid too little: steady median wait "
        f"{wait_med * 1e3:.2f} ms vs produce {prod_med * 1e3:.2f} ms")

    # epoch-scale loss check: batches differ per step, so compare windowed
    # means across the stream's halves — and only on long streams (short
    # legs exercise the pipeline contract, not convergence; synthetic
    # labels are random, so learning is memorization-slow by design)
    assert np.all(np.isfinite(res.losses))
    half = len(res.losses) // 2
    first, last = np.mean(res.losses[:half]), np.mean(res.losses[half:])
    if args.steps >= 150:
        assert last < first, (
            f"loss did not decrease ({first:.4f} -> {last:.4f})")

    print(f"[train] {args.steps} batches, traces={res.traces} == "
          f"buckets={len(res.buckets)} (probed {len(expected)}), "
          f"loss {first:.4f} -> {last:.4f}")
    print(f"[prefetch] depth={args.depth} overlap={stats['overlap']:.2f}  "
          f"steady wait {wait_med * 1e3:.3f} ms vs produce "
          f"{prod_med * 1e3:.3f} ms  OK")


def check_exact_parity(graph, args):
    params = gnn.init(jax.random.PRNGKey(args.seed), "gcn",
                      graph.x.shape[1], args.hidden, 8, num_layers=2)
    full = np.asarray(gnn.forward(
        params, "gcn", jnp.asarray(graph.x), jnp.asarray(graph.edge_index),
        graph.num_nodes, jnp.asarray(graph.deg_inv_sqrt), impl="ref"))
    sampler = NeighborSampler(graph, fanouts=(None, None), exact=True,
                              batch_size=8, seed=args.seed)
    worst = 0.0
    for step in range(4):
        sub = sampler.sample_batch(step)
        out = np.asarray(gnn.forward(
            params, "gcn", jnp.asarray(sub.x), jnp.asarray(sub.edge_index),
            sub.num_nodes, jnp.asarray(sub.deg_inv_sqrt), impl="ref"))
        worst = max(worst, float(np.abs(out[:sub.num_seeds]
                                        - full[sub.seed_nodes]).max()))
    assert worst < 1e-5, f"exact-neighborhood parity broke: {worst:.2e}"
    print(f"[parity] exact 2-hop sampled forward == full-graph forward on "
          f"seeds, max |Δ| = {worst:.2e}  OK")


def check_out_of_core(graph, args):
    mem = NeighborSampler(graph, fanouts=tuple(args.fanouts),
                          batch_size=args.batch_size, seed=args.seed)
    with tempfile.TemporaryDirectory(prefix="repro_shards_") as d:
        save_graph_shards(graph, d, num_shards=8)
        store = ShardedGraphStore(d, cache_shards=2)
        ooc = NeighborSampler(store, fanouts=tuple(args.fanouts),
                              batch_size=args.batch_size, seed=args.seed)
        for step in range(6):
            a, b = mem.sample_batch(step), ooc.sample_batch(step)
            assert np.array_equal(a.node_ids, b.node_ids)
            assert np.array_equal(a.edge_index, b.edge_index)
            assert np.array_equal(a.x, b.x)
        assert len(store._lru) <= 2, "shard LRU exceeded its bound"
    print(f"[out-of-core] 8-shard store stream == in-memory stream "
          f"(shard loads: {store.loads}, resident <= 2)  OK")


def check_serving(graph, args):
    params = gnn.init(jax.random.PRNGKey(args.seed), "gcn",
                      graph.x.shape[1], args.hidden, 8, num_layers=2)
    server = GNNServer(params, "gcn", impl=args.impl, feat=args.hidden)
    sampler = NeighborSampler(graph, fanouts=tuple(args.fanouts),
                              batch_size=args.batch_size, seed=args.seed)
    worst = 0.0
    with server.sampled_pipeline(sampler, depth=args.depth) as pipe:
        for step in range(12):
            b = pipe.batch(step)
            logits = server.serve_sampled(b)
            ref = np.asarray(gnn.forward(
                params, "gcn", jnp.asarray(b.graph.x),
                jnp.asarray(b.graph.edge_index), b.graph.num_nodes,
                jnp.asarray(b.graph.deg_inv_sqrt), impl="ref"))
            worst = max(worst, float(np.abs(logits
                                            - ref[:b.num_seeds]).max()))
    assert server.compiles == len(server.cache), (
        f"sampled serving retraced: {server.compiles} compiles for "
        f"{len(server.cache)} buckets")
    assert worst < 1e-4, f"served logits diverged: {worst:.2e}"
    print(f"[serve] 12 sampled batches, compiles={server.compiles} == "
          f"buckets={len(server.cache)}, max |Δ| vs ref = {worst:.2e}  OK")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--edges", type=int, default=16384)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--fanouts", type=int, nargs="+", default=[8, 4])
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="pallas", choices=["ref", "pallas"])
    args = ap.parse_args(argv)
    assert args.depth >= 2, "the overlap check needs prefetch depth >= 2"

    # host-resident only: nothing below ever device_puts the full graph
    graph = synth_graph("ooc-demo", args.nodes, args.edges, feat=args.feat,
                        num_classes=8, seed=args.seed)
    print(f"[graph] |V|={graph.num_nodes} |E|={graph.num_edges} "
          f"(host-only; device sees {args.batch_size}-seed subgraphs)")

    check_exact_parity(graph, args)
    check_out_of_core(graph, args)
    train_sampled(graph, args)
    check_serving(graph, args)
    print("all sampled-pipeline checks passed")


if __name__ == "__main__":
    main()
