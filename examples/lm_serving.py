"""Batched LM serving: prefill a batch of prompts into the KV cache, then
decode greedily — the serve_step that the decode_32k / long_500k dry-run
cells lower at production scale.

    PYTHONPATH=src python examples/lm_serving.py [--arch rwkv6-3b]
"""
import argparse

from repro.launch import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-8b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

serve.main(["--arch", args.arch, "--reduced", "--batch", str(args.batch),
            "--prompt-len", "16", "--gen", str(args.gen)])
