"""Parameter pytree with logical sharding axes (MaxText-style).

Every parameter is created as a :class:`P` leaf carrying logical axis names
("embed", "mlp", "heads", "vocab", "expert", "layers", …).
``split`` separates values from axes; :mod:`repro.distributed.sharding` maps
logical axes onto mesh axes per parallelism plan (DP/FSDP/TP/EP).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class P:
    """A parameter leaf: value + logical axes (one name or None per dim).

    Registered as a pytree node (value = child, axes = aux data) so P-trees
    flow through jit/grad/optimizers; ``axes`` ride along as metadata."""
    value: jax.Array
    axes: Tuple[Optional[str], ...]

    # NOTE: no ndim==len(axes) assert — transforms (lax.scan over stacked
    # layers) legitimately slice the leading "layers" dim off the value while
    # the aux axes ride along unchanged. Axes are only interpreted on the
    # outer (unsliced) tree by the sharding rules.


jax.tree_util.register_pytree_node(
    P,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: P(children[0], axes),
)


def is_param(x: Any) -> bool:
    return isinstance(x, P)


def split(tree):
    """(values, logical_axes) pytrees with identical structure."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def count_params(values) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(values))


def param_bytes(values) -> int:
    return sum(int(x.size * x.dtype.itemsize)
               for x in jax.tree_util.tree_leaves(values))


# -- initializers ----------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, axes, dtype, scale: float = 1.0):
    std = scale / jnp.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.asarray(std, dtype)
    return P(w, axes)


def embed_init(key, vocab: int, dim: int, dtype):
    w = jax.random.normal(key, (vocab, dim), dtype) * jnp.asarray(0.02, dtype)
    return P(w, ("vocab", "embed"))


def zeros_init(shape, axes, dtype):
    return P(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype):
    return P(jnp.ones(shape, dtype), axes)


def stack_layers(key, n: int, init_fn):
    """Initialize `n` structurally-identical layers stacked on a leading
    "layers" axis (enables lax.scan over layers — keeps HLO size O(1) in
    depth, essential for 61-layer dry-run compiles)."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(
        lambda *leaves: P(jnp.stack([l.value for l in leaves]),
                          ("layers",) + leaves[0].axes),
        *trees, is_leaf=is_param)
