"""Unified LM assembly for every assigned architecture.

A stack is a (possibly heterogeneous) sequence of blocks; block *kind* is
(mixer, ffn) with mixer ∈ {attn, mamba, rwkv} and ffn ∈ {mlp, moe}.  The
kind sequence is periodic (Jamba: period 8 — one attention layer per 8,
MoE every other layer; dense/MoE/RWKV archs: period 1), so the stack scans
over periods with per-slot stacked params — HLO size stays O(period), not
O(depth), keeping 61-layer dry-run compiles tractable.

Encoder-decoder (Whisper) and prefix-embedding (VLM/audio stubs) variants
reuse the same machinery.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, rwkv as rwkv_lib, ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.params import P, dense_init, stack_layers

# ---------------------------------------------------------------------------
# kinds & periodicity
# ---------------------------------------------------------------------------

def layer_kind(cfg: ModelConfig, i: int):
    if cfg.rwkv:
        mixer = "rwkv"
    elif cfg.is_attn_layer(i):
        mixer = "attn"
    else:
        mixer = "mamba"
    return (mixer, "moe" if cfg.is_moe_layer(i) else "mlp")


def stack_plan(cfg: ModelConfig, num_layers: Optional[int] = None):
    """(lead_kinds, period_kinds, num_periods)."""
    n = num_layers if num_layers is not None else cfg.num_layers
    kinds = [layer_kind(cfg, i) for i in range(n)]
    if cfg.unroll_layers:
        return kinds, [], 0
    lead = cfg.first_dense
    body = kinds[lead:]
    if not body:
        return kinds, [], 0
    for p in range(1, len(body) + 1):
        if len(body) % p == 0 and all(
                body[i] == body[i % p] for i in range(len(body))):
            return kinds[:lead], body[:p], len(body) // p
    return kinds, [], 0  # unreachable


# ---------------------------------------------------------------------------
# block init / forward
# ---------------------------------------------------------------------------

def _mixer_init(key, cfg, kind, dtype):
    if kind == "attn":
        return layers.attention_init(key, cfg, dtype)
    if kind == "mamba":
        return ssm_lib.ssm_init(key, cfg, dtype)
    return rwkv_lib.rwkv_init(key, cfg, dtype)


def _ffn_init(key, cfg, kind, dtype):
    if kind == "moe":
        return moe_lib.moe_init(key, cfg, dtype)
    if cfg.rwkv:
        return rwkv_lib.channel_mix_init(key, cfg, dtype)
    return layers.mlp_init(key, cfg, dtype)


def block_init(key, cfg: ModelConfig, kind, dtype, cross: bool = False):
    ks = jax.random.split(key, 4)
    prm = {
        "norm1": layers.norm_init(cfg),
        "mixer": _mixer_init(ks[0], cfg, kind[0], dtype),
        "norm2": layers.norm_init(cfg),
        "ffn": _ffn_init(ks[1], cfg, kind[1], dtype),
    }
    if cross:
        prm["norm_x"] = layers.norm_init(cfg)
        prm["cross"] = layers.attention_init(ks[2], cfg, dtype)
    return prm


class BlockAux(NamedTuple):
    moe_aux: jax.Array


def block_forward(prm, x, cfg: ModelConfig, kind, positions=None,
                  causal: bool = True, enc_kv=None, moe_impl: str = "capacity"):
    """Full-sequence block (train / prefill). Returns (x, aux)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(prm["norm1"], x, cfg)
    if mixer == "attn":
        mix = layers.attention(prm["mixer"], h, cfg, positions, causal)
    elif mixer == "mamba":
        st = ssm_lib.SSMState(
            jnp.zeros((x.shape[0], cfg.d_conv - 1,
                       cfg.expand * cfg.d_model), jnp.float32),
            jnp.zeros((x.shape[0], cfg.expand * cfg.d_model, cfg.d_state),
                      jnp.float32))
        mix, _ = ssm_lib.ssm_forward(prm["mixer"], h, cfg, st)
    else:  # rwkv
        st = rwkv_lib.RWKVState(
            jnp.zeros((x.shape[0], cfg.num_heads, cfg.head_dim,
                       cfg.head_dim), jnp.float32),
            jnp.zeros((x.shape[0], cfg.d_model), jnp.float32),
            jnp.zeros((x.shape[0], cfg.d_model), jnp.float32))
        mix, _ = rwkv_lib.rwkv_time_mix(prm["mixer"], h, cfg, st)

    if cfg.parallel_block:
        # cohere-style: attn and ffn read the same normed input
        f = layers.mlp(prm["ffn"], h, cfg)
        return x + mix + f, BlockAux(aux)

    x = x + mix
    if "cross" in prm and enc_kv is not None:
        hx = layers.apply_norm(prm["norm_x"], x, cfg)
        x = x + layers.attention(prm["cross"], hx, cfg, positions, kv=enc_kv)
    h2 = layers.apply_norm(prm["norm2"], x, cfg)
    if ffn == "moe":
        f, aux = moe_lib.moe(prm["ffn"], h2, cfg, impl=moe_impl)
    elif cfg.rwkv:
        f, _ = rwkv_lib.rwkv_channel_mix(
            prm["ffn"], h2, cfg,
            rwkv_lib.RWKVState(jnp.zeros((1,), jnp.float32),
                               jnp.zeros((1,), jnp.float32),
                               jnp.zeros((x.shape[0], cfg.d_model),
                                         jnp.float32)))
    else:
        f = layers.mlp(prm["ffn"], h2, cfg)
    return x + f, BlockAux(aux)


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    lead_kinds, period_kinds, n_periods = stack_plan(cfg)
    prm: dict = {"embed": layers.embedding_init(ks[0], cfg, dtype),
                 "final_norm": layers.norm_init(cfg)}
    if not cfg.tie_embeddings:
        prm["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.padded_vocab,
                                    ("embed", "vocab"), dtype)
    if cfg.pos == "learned":
        prm["pos_embed"] = P(
            jax.random.normal(ks[2], (cfg.max_seq, cfg.d_model), dtype) * 0.02,
            (None, "embed"))

    cross = cfg.cross_attention
    prm["lead"] = [block_init(k, cfg, kind, dtype, cross=cross)
                   for k, kind in zip(jax.random.split(ks[3],
                                                       max(len(lead_kinds), 1)),
                                      lead_kinds)]
    prm["period"] = [
        stack_layers(jax.random.split(ks[4], len(period_kinds))[s], n_periods,
                     functools.partial(block_init, cfg=cfg,
                                       kind=period_kinds[s], dtype=dtype,
                                       cross=cross))
        for s in range(len(period_kinds))
    ]
    if cfg.encoder_layers:
        enc_cfg = cfg
        prm["enc_blocks"] = [
            block_init(k, enc_cfg, ("attn", "mlp"), dtype)
            for k in jax.random.split(ks[5], cfg.encoder_layers)]
        prm["enc_norm"] = layers.norm_init(cfg)
        prm["enc_pos"] = P(
            jax.random.normal(ks[6], (cfg.max_seq, cfg.d_model), dtype) * 0.02,
            (None, "embed"))
    return prm


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)          # "full"


def encode(prm, cfg: ModelConfig, enc_embeds):
    """Whisper encoder over stubbed frame embeddings (B, S_enc, D)."""
    x = enc_embeds + prm["enc_pos"].value[: enc_embeds.shape[1]]
    for bp in prm["enc_blocks"]:
        x, _ = block_forward(bp, x, cfg, ("attn", "mlp"), causal=False)
    return layers.apply_norm(prm["enc_norm"], x, cfg)


def forward(prm, cfg: ModelConfig, tokens, prefix_embeds=None,
            enc_embeds=None, remat_policy: str = "full",
            moe_impl: str = "capacity"):
    """tokens: (B, S) int32 → logits (B, S_total, vocab_pad) fp32.

    prefix_embeds: (B, P, D) stubbed modality frontend output (VLM/audio),
    prepended to the token embeddings (DESIGN.md §5).
    enc_embeds: (B, S_enc, D) encoder-side stub (Whisper)."""
    from repro.distributed.sharding import ashard
    x = layers.embed(prm["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = ashard(x, "batch", "seq", None)
    b, s, _ = x.shape
    if cfg.pos == "learned":
        x = x + prm["pos_embed"].value[:s]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    enc_kv = None
    if enc_embeds is not None and cfg.encoder_layers:
        enc_out = encode(prm, cfg, enc_embeds)
        enc_kv = enc_out

    lead_kinds, period_kinds, _ = stack_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def run_block(bp, x, kind):
        kv = None
        if enc_kv is not None:
            # project encoder output through this block's cross-attn K/V
            ck = layers._project_qkv(bp["cross"], enc_kv, cfg, positions=None,
                                     apply_rope=False)
            kv = (ck[1], ck[2])
        return block_forward(bp, x, cfg, kind, positions=positions,
                             causal=True, enc_kv=kv, moe_impl=moe_impl)

    for bp, kind in zip(prm["lead"], lead_kinds):
        x, aux = run_block(bp, x, kind)
        aux_total = aux_total + aux.moe_aux

    if period_kinds:
        def period_fn(x, period_params):
            x = ashard(x, "batch", "seq", None)   # re-pin inside the scan
            aux_p = jnp.zeros((), jnp.float32)
            for s, kind in enumerate(period_kinds):
                x, aux = run_block(period_params[s], x, kind)
                aux_p = aux_p + aux.moe_aux
            return x, aux_p

        body = _remat(period_fn, remat_policy)
        x, aux_seq = jax.lax.scan(
            lambda c, pp: body(c, pp), x, tuple(prm["period"]))
        aux_total = aux_total + jnp.sum(aux_seq)

    x = layers.apply_norm(prm["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = layers.unembed(prm["embed"], x, cfg)
    else:
        logits = (x @ prm["lm_head"].value).astype(jnp.float32) * cfg.logit_scale
    logits = ashard(logits, "batch", "seq", "act_vocab")
    return logits, aux_total


def loss_fn(prm, cfg: ModelConfig, batch, remat_policy: str = "full",
            moe_impl: str = "capacity", aux_weight: float = 0.01):
    """Next-token cross entropy (+ MoE load-balance aux)."""
    tokens = batch["tokens"]
    logits, aux = forward(prm, cfg, tokens,
                          prefix_embeds=batch.get("prefix_embeds"),
                          enc_embeds=batch.get("enc_embeds"),
                          remat_policy=remat_policy, moe_impl=moe_impl)
    # align: prefix positions (if any) produce no loss
    p = logits.shape[1] - tokens.shape[1]
    logits = logits[:, p:]
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux_weight * aux, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode (single-token serve step with cache/state)
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Per-slot caches, each stacked over periods (lead slots separate)."""
    lead: tuple
    period: tuple
    length: jax.Array


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> DecodeState:
    lead_kinds, period_kinds, n_periods = stack_plan(cfg)

    def mk(kind, n):
        mixer = kind[0]
        if mixer == "attn":
            # raw (k, v) tuple — no scalar length inside scanned pytrees
            shape = (n, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
        if mixer == "mamba":
            return ssm_lib.init_ssm_state(cfg, batch, n)
        return rwkv_lib.init_rwkv_state(cfg, batch, n)

    lead = tuple(jax.tree_util.tree_map(lambda a: a[0], mk(kind, 1))
                 for kind in lead_kinds)
    period = tuple(mk(kind, n_periods) for kind in period_kinds)
    return DecodeState(lead, period, jnp.zeros((), jnp.int32))


def _block_decode(bp, x, cfg, kind, cache, length, enc_kv=None,
                  moe_impl: str = "capacity", lengths=None):
    mixer, ffn = kind
    h = layers.apply_norm(bp["norm1"], x, cfg)
    if mixer == "attn":
        kvc = layers.KVCache(cache[0], cache[1], length)
        mix, new_kv = layers.attention_decode(bp["mixer"], h, cfg, kvc,
                                              lengths=lengths)
        new_cache = (new_kv.k, new_kv.v)
    elif mixer == "mamba":
        mix, new_cache = ssm_lib.ssm_forward(bp["mixer"], h, cfg, cache)
    else:
        mix, st = rwkv_lib.rwkv_time_mix(bp["mixer"], h, cfg, cache)
        new_cache = st

    if cfg.parallel_block:
        f = layers.mlp(bp["ffn"], h, cfg)
        return x + mix + f, new_cache

    x = x + mix
    if "cross" in bp and enc_kv is not None:
        hx = layers.apply_norm(bp["norm_x"], x, cfg)
        x = x + layers.attention(bp["cross"], hx, cfg, kv=enc_kv)
    h2 = layers.apply_norm(bp["norm2"], x, cfg)
    if ffn == "moe":
        f, _ = moe_lib.moe(bp["ffn"], h2, cfg, impl=moe_impl)
    elif cfg.rwkv:
        f, st2 = rwkv_lib.rwkv_channel_mix(bp["ffn"], h2, cfg, new_cache)
        new_cache = st2
    else:
        f = layers.mlp(bp["ffn"], h2, cfg)
    return x + f, new_cache


def decode_step(prm, cfg: ModelConfig, tokens, state: DecodeState,
                enc_out=None, moe_impl: str = "capacity", lengths=None):
    """tokens: (B, 1) int32 → (logits (B, 1, V), new DecodeState).

    lengths: optional (B,) per-slot cache lengths (continuous batching —
    repro.serve.lm); default: the shared state.length counter."""
    from repro.distributed.sharding import ashard
    x = layers.embed(prm["embed"], tokens)
    x = ashard(x, "batch", None, None)
    if cfg.pos == "learned":
        if lengths is None:
            x = x + jax.lax.dynamic_slice_in_dim(prm["pos_embed"].value,
                                                 state.length, 1, axis=0)
        else:
            x = x + jnp.take(prm["pos_embed"].value, lengths, axis=0)[:, None]
    lead_kinds, period_kinds, _ = stack_plan(cfg)

    new_lead = []
    for bp, kind, cache in zip(prm["lead"], lead_kinds, state.lead):
        kv = None
        if enc_out is not None and "cross" in bp:
            ck = layers._project_qkv(bp["cross"], enc_out, cfg, positions=None,
                                     apply_rope=False)
            kv = (ck[1], ck[2])
        x, nc = _block_decode(bp, x, cfg, kind, cache, state.length, enc_kv=kv,
                              moe_impl=moe_impl, lengths=lengths)
        new_lead.append(nc)

    new_period = []
    if period_kinds:
        def period_fn(carry, inp):
            x = carry
            pp, caches = inp
            new_caches = []
            for s, kind in enumerate(period_kinds):
                kv = None
                if enc_out is not None and "cross" in pp[s]:
                    ck = layers._project_qkv(pp[s]["cross"], enc_out, cfg,
                                             positions=None, apply_rope=False)
                    kv = (ck[1], ck[2])
                x, nc = _block_decode(pp[s], x, cfg, kind, caches[s],
                                      state.length, enc_kv=kv,
                                      moe_impl=moe_impl, lengths=lengths)
                new_caches.append(nc)
            return x, tuple(new_caches)

        x, stacked_new = jax.lax.scan(period_fn, x,
                                      (tuple(prm["period"]), state.period))
        new_period = list(stacked_new)

    x = layers.apply_norm(prm["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = layers.unembed(prm["embed"], x, cfg)
    else:
        logits = (x @ prm["lm_head"].value).astype(jnp.float32) * cfg.logit_scale
    new_state = DecodeState(tuple(new_lead), tuple(new_period),
                            state.length + 1)
    return logits, new_state
