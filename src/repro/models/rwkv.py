"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
*data-dependent decay* (the defining Finch feature, kept faithful via the
LoRA-parameterised per-token decay), plus squared-ReLU channel-mix.

Simplifications recorded in DESIGN.md: token-shift interpolation uses static
per-channel µ (Finch's ddlerp LoRA on µ is dropped); output normalisation is
per-head RMS instead of GroupNorm. The recurrence and state semantics match
the paper, so decode is O(1) per token (runs the long_500k cell).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import P, dense_init, ones_init, zeros_init

_DECAY_LORA = 64


class RWKVState(NamedTuple):
    wkv: jax.Array       # (B, H, Dk, Dv) per-head linear-attention state
    tm_prev: jax.Array   # (B, D) previous token (time-mix shift)
    cm_prev: jax.Array   # (B, D) previous token (channel-mix shift)


def rwkv_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    mu = lambda k: P(jax.random.uniform(k, (d,), jnp.float32), ("embed",))
    prm = {
        "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
        "mu_w": mu(ks[3]), "mu_g": mu(ks[4]),
        "wr": dense_init(ks[5], d, d, ("embed", "heads"), dtype),
        "wk": dense_init(ks[6], d, d, ("embed", "heads"), dtype),
        "wv": dense_init(ks[7], d, d, ("embed", "heads"), dtype),
        "wg": dense_init(ks[8], d, d, ("embed", "heads"), dtype),
        "wo": dense_init(ks[9], d, d, ("heads", "embed"), dtype),
        # data-dependent decay LoRA:  w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": P(jnp.full((d,), -6.0, jnp.float32), ("embed",)),
        "a_w": dense_init(ks[10], d, _DECAY_LORA, ("embed", None), jnp.float32),
        "b_w": dense_init(ks[11], _DECAY_LORA, d, (None, "embed"), jnp.float32),
        "u": P(jnp.zeros((d,), jnp.float32), ("embed",)),     # per-channel bonus
        "ln_out": ones_init((d,), ("embed",), jnp.float32),
    }
    return prm


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def _decay(prm, xw):
    lora = jnp.tanh(xw.astype(jnp.float32) @ prm["a_w"].value) @ prm["b_w"].value
    return jnp.exp(-jnp.exp(prm["w0"].value + lora))            # (…, D) ∈ (0,1)


def _wkv_step(state, r, k, v, w, u, h, dk):
    """One recurrence step on (B, H, Dk, Dv) state."""
    b = r.shape[0]
    rh = r.reshape(b, h, dk)
    kh = k.reshape(b, h, dk)
    vh = v.reshape(b, h, dk)
    wh = w.reshape(b, h, dk)
    uh = u.reshape(h, dk)
    kv = kh[..., :, None] * vh[..., None, :]                     # (B,H,Dk,Dv)
    y = jnp.einsum("bhk,bhkv->bhv", rh, state + uh[None, :, :, None] * kv)
    state = wh[..., :, None] * state + kv
    return state, y.reshape(b, h * dk)


def rwkv_time_mix(prm, x, cfg: ModelConfig, state: RWKVState):
    """x: (B, S, D). Returns (out, new_state). Sequential scan over S."""
    b, s, d = x.shape
    h, dk = cfg.num_heads, cfg.head_dim
    x_prev = jnp.concatenate(
        [state.tm_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    r = _lerp(x, x_prev, prm["mu_r"].value) @ prm["wr"].value
    k = _lerp(x, x_prev, prm["mu_k"].value) @ prm["wk"].value
    v = _lerp(x, x_prev, prm["mu_v"].value) @ prm["wv"].value
    g = jax.nn.silu(_lerp(x, x_prev, prm["mu_g"].value) @ prm["wg"].value)
    w = _decay(prm, _lerp(x, x_prev, prm["mu_w"].value))         # (B,S,D) f32

    def step(st, inp):
        rt, kt, vt, wt = inp
        return _wkv_step(st, rt.astype(jnp.float32), kt.astype(jnp.float32),
                         vt.astype(jnp.float32), wt, prm["u"].value, h, dk)

    xs = (r.transpose(1, 0, 2), k.transpose(1, 0, 2),
          v.transpose(1, 0, 2), w.transpose(1, 0, 2))
    new_wkv, ys = jax.lax.scan(step, state.wkv, xs)
    y = ys.transpose(1, 0, 2)                                    # (B,S,D)
    # per-head RMS (GroupNorm stand-in), then gate + output proj
    yh = y.reshape(b, s, h, dk)
    yh = yh * jax.lax.rsqrt(jnp.mean(jnp.square(yh), -1, keepdims=True) + 1e-5)
    y = (yh.reshape(b, s, d) * prm["ln_out"].value).astype(x.dtype) * g
    out = y @ prm["wo"].value
    new_state = RWKVState(new_wkv, x[:, -1].astype(jnp.float32),
                          state.cm_prev)
    return out, new_state


def channel_mix_init(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": P(jax.random.uniform(ks[0], (d,), jnp.float32), ("embed",)),
        "wk": dense_init(ks[1], d, f, ("embed", "mlp"), dtype),
        "wv": dense_init(ks[2], f, d, ("mlp", "embed"), dtype),
        "wr": dense_init(ks[0], d, d, ("embed", "embed2"), dtype),
    }


def rwkv_channel_mix(prm, x, cfg: ModelConfig, state: RWKVState):
    x_prev = jnp.concatenate(
        [state.cm_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    xk = _lerp(x, x_prev, prm["mu_k"].value)
    k = jnp.square(jax.nn.relu(xk @ prm["wk"].value))
    out = jax.nn.sigmoid(x @ prm["wr"].value) * (k @ prm["wv"].value)
    return out, RWKVState(state.wkv, state.tm_prev,
                          x[:, -1].astype(jnp.float32))


def init_rwkv_state(cfg: ModelConfig, batch: int, num_layers: int):
    h, dk = cfg.num_heads, cfg.head_dim
    return RWKVState(
        jnp.zeros((num_layers, batch, h, dk, dk), jnp.float32),
        jnp.zeros((num_layers, batch, cfg.d_model), jnp.float32),
        jnp.zeros((num_layers, batch, cfg.d_model), jnp.float32))
