"""Model configuration for every supported architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention / block flavour
    qk_norm: bool = False
    partial_rotary: float = 1.0    # fraction of head_dim that rotates
    use_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"
    mlp_gated: bool = True
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    pos: str = "rope"              # rope | learned | none
    parallel_block: bool = False   # cohere-style attn ∥ mlp
    logit_scale: float = 1.0
    rope_theta: float = 10000.0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    moe_every: int = 1             # MoE on layers with (i % moe_every == moe_offset)
    moe_offset: int = 0
    first_dense: int = 0           # leading dense layers (DeepSeek/Kimi style)
    norm_topk: bool = False
    capacity_factor: float = 1.25

    # SSM / hybrid (Jamba)
    attn_every: int = 0            # 1 attention layer per `attn_every` (0 = all attn)
    attn_offset: int = 0
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    # RWKV6
    rwkv: bool = False

    # encoder-decoder (Whisper)
    encoder_layers: int = 0
    cross_attention: bool = False

    # modality frontend stub (audio frames / vision patches)
    num_prefix_embeds: int = 0

    max_seq: int = 532_480
    dtype: str = "bfloat16"

    # roofline instrumentation: lay all layers out explicitly instead of
    # scanning periods (HLO cost analysis counts while bodies once, so the
    # roofline differencing lowers small unrolled stacks — benchmarks/roofline.py)
    unroll_layers: bool = False

    # which shape cells apply (full-attention archs skip long_500k)
    supports_long_context: bool = False

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        return (self.vocab_size + 127) // 128 * 128

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0 or i < self.first_dense:
            return False
        return (i % self.moe_every) == self.moe_offset

    def is_attn_layer(self, i: int) -> bool:
        """hybrid (Jamba): one attention layer per `attn_every` block."""
        if self.rwkv:
            return False
        if self.attn_every == 0:
            return True
        return (i % self.attn_every) == self.attn_offset

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family (per spec: small
        layers/width, few experts, tiny vocab)."""
        small = dict(
            num_layers=max(2, self.attn_every or 2) if self.family == "hybrid" else 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            max_seq=512,
            dtype="float32",
        )
        if self.num_experts:
            small.update(num_experts=4, top_k=min(self.top_k, 2), moe_d_ff=64)
        if self.encoder_layers:
            small.update(encoder_layers=2)
        if self.num_prefix_embeds:
            small.update(num_prefix_embeds=4)
        small.update(overrides)
        return dataclasses.replace(self, **small)
