"""Message-passing GNNs on GeoT ops (paper §V: GCN, GIN, GraphSAGE; +GAT).

Graphs are tensors (format-agnostic, §IV): ``edge_index`` (2, E) with
``edge_index[1]`` (destinations) sorted non-decreasing — the PyG convention
the paper relies on.  Aggregation is ``index_segment_reduce`` /
``index_weight_segment_reduce`` (fused message+aggregate) throughout; no
sparse formats anywhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ops as geot
from repro.models.params import P, dense_init, zeros_init


def make_model_plan(edge_index, num_nodes: int, feat: int,
                    tune: Optional[bool] = None, config=None):
    """One :class:`~repro.core.plan.SegmentPlan` for every layer (and, via
    the custom VJPs, every backward pass) of a model on this graph.

    ``feat`` should be the widest layer width. ``tune=True`` selects the
    kernel config from a measured autotuner sweep instead of the generated
    rules — the one-off sweep cost is paid here, once per graph, and cached
    in the persistent PerfDB per (device, shape class)."""
    from repro.core.plan import make_graph_plan
    return make_graph_plan(edge_index, num_nodes, feat=feat, config=config,
                           tune=tune)


# ---------------------------------------------------------------------------
# layers (paper Listing 2 style)
# ---------------------------------------------------------------------------

def gcn_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return {"w": dense_init(key, d_in, d_out, ("embed", "mlp"), dtype),
            "b": zeros_init((d_out,), ("mlp",), dtype)}


def gcn_layer(prm, x, edge_index, deg_inv_sqrt, num_nodes: int,
              impl: str = "ref", plan=None):
    """GCN: Y = D^{-1/2} A D^{-1/2} X W — SpMM with weights = normalized
    coefficients, i.e. index_weight_segment_reduce (paper §IV / Fig. 10)."""
    src, dst = edge_index[0], edge_index[1]
    h = x @ prm["w"].value
    w = deg_inv_sqrt[src] * deg_inv_sqrt[dst]
    out = geot.index_weight_segment_reduce(h, src, w, dst, num_nodes,
                                           impl=impl, plan=plan)
    return out + prm["b"].value


def gin_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "mlp1": dense_init(k1, d_in, d_out, ("embed", "mlp"), dtype),
        "mlp2": dense_init(k2, d_out, d_out, ("mlp", "embed"), dtype),
        "b1": zeros_init((d_out,), ("mlp",), dtype),
        "b2": zeros_init((d_out,), ("embed",), dtype),
        "eps": P(jnp.zeros((), jnp.float32), ()),
    }


def gin_layer(prm, x, edge_index, num_nodes: int, impl: str = "ref",
              plan=None):
    """GIN: h' = MLP((1+ε)·h + Σ_neighbors h) — unweighted fused aggregate."""
    src, dst = edge_index[0], edge_index[1]
    agg = geot.index_segment_reduce(x, src, dst, num_nodes, impl=impl,
                                    plan=plan)
    h = (1.0 + prm["eps"].value) * x + agg
    h = jax.nn.relu(h @ prm["mlp1"].value + prm["b1"].value)
    return h @ prm["mlp2"].value + prm["b2"].value


def sage_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"w_self": dense_init(k1, d_in, d_out, ("embed", "mlp"), dtype),
            "w_neigh": dense_init(k2, d_in, d_out, ("embed", "mlp"), dtype),
            "b": zeros_init((d_out,), ("mlp",), dtype)}


def sage_layer(prm, x, edge_index, num_nodes: int, impl: str = "ref",
               plan=None):
    """GraphSAGE (mean aggregator)."""
    src, dst = edge_index[0], edge_index[1]
    agg = geot.index_segment_reduce(x, src, dst, num_nodes, reduce="mean",
                                    impl=impl, plan=plan)
    return (x @ prm["w_self"].value + agg @ prm["w_neigh"].value
            + prm["b"].value)


def gat_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": dense_init(k1, d_in, d_out, ("embed", "mlp"), dtype),
            "a_src": dense_init(k2, d_out, 1, ("mlp", None), dtype),
            "a_dst": dense_init(k3, d_out, 1, ("mlp", None), dtype)}


def gat_layer(prm, x, edge_index, num_nodes: int, impl: str = "ref",
              plan=None):
    """Single-head GAT: attention coefficients via segment_softmax over the
    sorted destination segments."""
    src, dst = edge_index[0], edge_index[1]
    h = x @ prm["w"].value
    alpha = (h @ prm["a_src"].value)[src, 0] + (h @ prm["a_dst"].value)[dst, 0]
    alpha = geot.segment_softmax(jax.nn.leaky_relu(alpha, 0.2), dst, num_nodes)
    return geot.index_weight_segment_reduce(h, src, alpha, dst, num_nodes,
                                            impl=impl, plan=plan)


# ---------------------------------------------------------------------------
# 3-layer models (paper §V-F: node classification, 3 layers, hidden 32/64)
# ---------------------------------------------------------------------------

_LAYER = {"gcn": (gcn_layer_init, gcn_layer),
          "gin": (gin_layer_init, gin_layer),
          "sage": (sage_layer_init, sage_layer),
          "gat": (gat_layer_init, gat_layer)}


def init(key, model: str, d_in: int, hidden: int, num_classes: int,
         num_layers: int = 3, dtype=jnp.float32):
    init_fn, _ = _LAYER[model]
    dims = [d_in] + [hidden] * (num_layers - 1) + [num_classes]
    ks = jax.random.split(key, num_layers)
    return [init_fn(k, dims[i], dims[i + 1], dtype)
            for i, k in enumerate(ks)]


def forward(params, model: str, x, edge_index, num_nodes: int,
            deg_inv_sqrt: Optional[jax.Array] = None, impl: str = "ref",
            plan=None):
    """``plan``: one :class:`~repro.core.plan.SegmentPlan` built on this
    graph's destinations — reused by every layer (and, via the custom VJPs,
    by the backward pass)."""
    _, layer_fn = _LAYER[model]
    h = x
    for i, prm in enumerate(params):
        if model == "gcn":
            h = layer_fn(prm, h, edge_index, deg_inv_sqrt, num_nodes, impl,
                         plan)
        else:
            h = layer_fn(prm, h, edge_index, num_nodes, impl, plan)
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params, model: str, x, edge_index, labels, num_nodes: int,
            deg_inv_sqrt=None, impl: str = "ref", plan=None):
    logits = forward(params, model, x, edge_index, num_nodes,
                     deg_inv_sqrt, impl, plan)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
