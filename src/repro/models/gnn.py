"""Message-passing GNNs on the unified :mod:`repro.core.mp` primitive
(paper §V: GCN, GIN, GraphSAGE; + multi-head GAT).

Graphs are tensors (format-agnostic, §IV): ``edge_index`` (2, E) with
``edge_index[1]`` (destinations) sorted non-decreasing — the PyG convention
the paper relies on.

Every layer shares one signature

    layer(prm, x, edge_index, num_nodes, deg_inv_sqrt=None, *,
          impl="ref", plan=None, mesh=None, partition=None)

and routes its aggregation through ``mp`` / ``mp_transform``: on the
``pallas`` path every reduce (sum / mean / max, weighted or not) and the
GAT ``segment_softmax`` is a single fused plan-aware kernel, and layers
whose aggregation commutes with their dense transform (GCN, SAGE's
neighbour branch) let ``mp_transform`` pick the layer schedule by the
cost model — aggregate-first, transform-first, or (pallas, single
device, VMEM permitting) the **fully-fused** one-launch SpMM+GEMM that
never materializes the (S, d_in) aggregate.

Passing ``partition=`` (a :class:`~repro.data.partition.PartitionedGraph`,
with ``plan`` a matching :class:`~repro.core.plan.PartitionedPlan` and
``mesh`` a 1-D device mesh) reroutes every aggregation through
:mod:`repro.core.dist_mp`: the same fused kernels run per shard and halo
contributions merge with the reduce's collective algebra — the model code
itself is unchanged up to that dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ops as geot
from repro.core.mp import mp as mp_agg
from repro.core.mp import mp_transform, mp_typed
from repro.models.params import P, dense_init, zeros_init


def _mp(x, edge_index, num_nodes, *, reduce, edge_weight=None, plan=None,
        impl="ref", mesh=None, partition=None):
    """Dispatch plain vs sharded message passing (one switch for every
    layer; ``plan`` is a SegmentPlan or, sharded, a PartitionedPlan)."""
    if partition is None:
        return mp_agg(x, edge_index, num_nodes, reduce=reduce,
                      edge_weight=edge_weight, plan=plan, impl=impl)
    from repro.core.dist_mp import mp_sharded
    return mp_sharded(x, partition, reduce=reduce, edge_weight=edge_weight,
                      pplan=plan, mesh=mesh, impl=impl)


def _mp_transform(x, w, edge_index, num_nodes, *, reduce, edge_weight=None,
                  plan=None, impl="ref", mesh=None, partition=None):
    if partition is None:
        return mp_transform(x, w, edge_index, num_nodes, reduce=reduce,
                            edge_weight=edge_weight, plan=plan, impl=impl)
    from repro.core.dist_mp import mp_transform_sharded
    return mp_transform_sharded(x, w, partition, reduce=reduce,
                                edge_weight=edge_weight, pplan=plan,
                                mesh=mesh, impl=impl)


def make_model_plan(edge_index, num_nodes: int, feat: int,
                    tune: Optional[bool] = None, config=None):
    """One :class:`~repro.core.plan.SegmentPlan` for every layer (and, via
    the custom VJPs, every backward pass) of a model on this graph.

    ``feat`` should be the widest layer width. ``tune=True`` selects the
    kernel config from a measured autotuner sweep instead of the generated
    rules — the one-off sweep cost is paid here, once per graph, and cached
    in the persistent PerfDB per (device, shape class)."""
    from repro.core.plan import make_graph_plan
    return make_graph_plan(edge_index, num_nodes, feat=feat, config=config,
                           tune=tune)


# ---------------------------------------------------------------------------
# layers (paper Listing 2 style) — one uniform signature, all on core.mp
# ---------------------------------------------------------------------------

def gcn_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32, **_):
    return {"w": dense_init(key, d_in, d_out, ("embed", "mlp"), dtype),
            "b": zeros_init((d_out,), ("mlp",), dtype)}


def gcn_layer(prm, x, edge_index, num_nodes: int, deg_inv_sqrt=None, *,
              impl: str = "ref", plan=None, mesh=None, partition=None):
    """GCN: Y = D^{-1/2} A D^{-1/2} X W — weighted-sum message passing with
    the transform/aggregate order picked by the cost model (paper §IV /
    Fig. 10; aggregate-first when the layer widens)."""
    if deg_inv_sqrt is None:
        raise ValueError("gcn_layer needs deg_inv_sqrt")
    src, dst = edge_index[0], edge_index[1]
    w_e = deg_inv_sqrt[src] * deg_inv_sqrt[dst]
    out = _mp_transform(x, prm["w"].value, edge_index, num_nodes,
                        reduce="sum", edge_weight=w_e, plan=plan, impl=impl,
                        mesh=mesh, partition=partition)
    return out + prm["b"].value


def gin_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32, **_):
    k1, k2 = jax.random.split(key)
    return {
        "mlp1": dense_init(k1, d_in, d_out, ("embed", "mlp"), dtype),
        "mlp2": dense_init(k2, d_out, d_out, ("mlp", "embed"), dtype),
        "b1": zeros_init((d_out,), ("mlp",), dtype),
        "b2": zeros_init((d_out,), ("embed",), dtype),
        "eps": P(jnp.zeros((), jnp.float32), ()),
    }


def gin_layer(prm, x, edge_index, num_nodes: int, deg_inv_sqrt=None, *,
              impl: str = "ref", plan=None, mesh=None, partition=None):
    """GIN: h' = MLP((1+ε)·h + Σ_neighbors h) — unweighted fused sum.
    The MLP is non-linear, so there is no reordering opportunity."""
    agg = _mp(x, edge_index, num_nodes, reduce="sum", plan=plan, impl=impl,
              mesh=mesh, partition=partition)
    h = (1.0 + prm["eps"].value) * x + agg
    h = jax.nn.relu(h @ prm["mlp1"].value + prm["b1"].value)
    return h @ prm["mlp2"].value + prm["b2"].value


def sage_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32, **_):
    k1, k2 = jax.random.split(key)
    return {"w_self": dense_init(k1, d_in, d_out, ("embed", "mlp"), dtype),
            "w_neigh": dense_init(k2, d_in, d_out, ("embed", "mlp"), dtype),
            "b": zeros_init((d_out,), ("mlp",), dtype)}


def sage_layer(prm, x, edge_index, num_nodes: int, deg_inv_sqrt=None, *,
               impl: str = "ref", plan=None, mesh=None, partition=None):
    """GraphSAGE (mean aggregator): one fused mean kernel on the pallas
    path (no sum+count pair), with the neighbour transform reorderable
    (mean commutes with W)."""
    neigh = _mp_transform(x, prm["w_neigh"].value, edge_index, num_nodes,
                          reduce="mean", plan=plan, impl=impl, mesh=mesh,
                          partition=partition)
    return x @ prm["w_self"].value + neigh + prm["b"].value


def gat_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32,
                   heads: int = 1):
    """Multi-head GAT parameters: W projects to ``heads`` blocks of d_out;
    per-head attention vectors a_src/a_dst of shape (heads, d_out)."""
    k1, k2, k3 = jax.random.split(key, 3)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_out, jnp.float32))
    return {
        "w": dense_init(k1, d_in, heads * d_out, ("embed", "mlp"), dtype),
        "a_src": P(jax.random.normal(k2, (heads, d_out), dtype)
                   * scale.astype(dtype), ("heads", "mlp")),
        "a_dst": P(jax.random.normal(k3, (heads, d_out), dtype)
                   * scale.astype(dtype), ("heads", "mlp")),
    }


def gat_layer(prm, x, edge_index, num_nodes: int, deg_inv_sqrt=None, *,
              impl: str = "ref", plan=None, mesh=None, partition=None):
    """Multi-head GAT: per-head attention via one fused multi-head
    ``segment_softmax`` launch (heads ride the lane dimension), then one
    α-weighted fused sum per head (heads block the feature dim). Head
    outputs are averaged, so the layer's output width is d_out for any
    ``heads`` — heads=1 reproduces the single-head layer exactly.

    Sharded, the softmax runs per shard with the two-stage stat merge and
    its stacked per-shard α feeds the weighted sums without ever being
    gathered back to global edge order."""
    src, dst = edge_index[0], edge_index[1]
    heads, d_out = prm["a_src"].value.shape
    h = x @ prm["w"].value                                  # (V, heads*d_out)
    hh = h.reshape(h.shape[0], heads, d_out)
    logit_src = jnp.einsum("vhd,hd->vh", hh, prm["a_src"].value)
    logit_dst = jnp.einsum("vhd,hd->vh", hh, prm["a_dst"].value)
    e = jax.nn.leaky_relu(logit_src[src] + logit_dst[dst], 0.2)  # (E, heads)
    if partition is None:
        alpha = geot.segment_softmax(e, dst, num_nodes, impl, None, plan)
    else:
        from repro.core.dist_mp import segment_softmax_sharded
        alpha = segment_softmax_sharded(e, partition, pplan=plan, mesh=mesh,
                                        impl=impl)      # stacked (S, E_pad, H)
    out = 0.0
    for i in range(heads):
        out = out + _mp(hh[:, i, :], edge_index, num_nodes,
                        reduce="sum", edge_weight=alpha[..., i],
                        plan=plan, impl=impl, mesh=mesh, partition=partition)
    return out / heads


# ---------------------------------------------------------------------------
# relation-typed layers (FASTEN direction): per-relation transforms as ONE
# grouped segment_matmul launch per layer — never a Python loop over types
# ---------------------------------------------------------------------------

def _require_typed(name, edge_type):
    if edge_type is None:
        raise ValueError(f"{name} needs edge_type (a relation-typed graph; "
                         "see repro.data.graphs.TypedGraph)")


def rgcn_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32,
                    num_relations: int = 4, **_):
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return {
        "w_rel": P(jax.random.normal(k1, (num_relations, d_in, d_out), dtype)
                   * scale.astype(dtype), ("relation", "embed", "mlp")),
        "w_self": dense_init(k2, d_in, d_out, ("embed", "mlp"), dtype),
        "b": zeros_init((d_out,), ("mlp",), dtype),
    }


def rgcn_layer(prm, x, edge_index, num_nodes: int, deg_inv_sqrt=None, *,
               impl: str = "ref", plan=None, mesh=None, partition=None,
               edge_type=None, type_perm=None, inv_type_perm=None,
               type_counts=None, rplan=None):
    """RGCN: h' = W_self·h + mean_{(s,d,r)} W_r·h_s  (mean over *all*
    incoming typed messages — the single-normalizer simplification of
    Schlichtkrull's per-relation 1/c_{i,r}; one grouped matmul + one fused
    mean reduce per layer instead of R separate SpMMs)."""
    _require_typed("rgcn_layer", edge_type)
    if partition is not None:
        raise NotImplementedError("typed layers are single-shard for now")
    agg = mp_typed(x, prm["w_rel"].value, edge_index, edge_type, num_nodes,
                   type_perm=type_perm, inv_type_perm=inv_type_perm,
                   type_counts=type_counts, reduce="mean", plan=plan,
                   rplan=rplan, impl=impl)
    return x @ prm["w_self"].value + agg + prm["b"].value


def rgat_layer_init(key, d_in: int, d_out: int, dtype=jnp.float32,
                    heads: int = 1, num_relations: int = 4, **_):
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    scale_out = 1.0 / jnp.sqrt(jnp.asarray(d_out, jnp.float32))
    return {
        "w_rel": P(jax.random.normal(
            k1, (num_relations, d_in, heads * d_out), dtype)
            * scale_in.astype(dtype), ("relation", "embed", "mlp")),
        "a_src": P(jax.random.normal(k2, (num_relations, heads, d_out), dtype)
                   * scale_out.astype(dtype), ("relation", "heads", "mlp")),
        "a_dst": P(jax.random.normal(k3, (num_relations, heads, d_in), dtype)
                   * scale_in.astype(dtype), ("relation", "heads", "embed")),
    }


def rgat_layer(prm, x, edge_index, num_nodes: int, deg_inv_sqrt=None, *,
               impl: str = "ref", plan=None, mesh=None, partition=None,
               edge_type=None, type_perm=None, inv_type_perm=None,
               type_counts=None, rplan=None):
    """Relational multi-head GAT (our one-launch variant): attention logits

        e = LeakyReLU( a_src[r]·(W_r h_s)  +  a_dst[r]·h_d )

    score the *transformed* source against the relation's view of the
    **raw** destination (a_dst acts on h_d directly), so only sources
    need the per-relation transform — exactly one grouped
    ``segment_matmul`` launch per layer, like RGCN. Softmax normalizes
    over each destination's incoming edges (all relations jointly) via
    the fused multi-head kernel; the α-weighted sums gather the
    type-ordered messages through the inverse permutation, so no
    un-permute launch either. Head outputs are averaged."""
    _require_typed("rgat_layer", edge_type)
    if partition is not None:
        raise NotImplementedError("typed layers are single-shard for now")
    src, dst = edge_index[0], edge_index[1]
    num_relations, heads, d_out = prm["a_src"].value.shape
    if type_perm is None:
        type_perm = jnp.argsort(edge_type, stable=True)
    if type_counts is None:
        type_counts = jnp.bincount(edge_type, length=num_relations)
    if inv_type_perm is None:
        inv_type_perm = (jnp.zeros_like(type_perm)
                         .at[type_perm]
                         .set(jnp.arange(type_perm.shape[0],
                                         dtype=type_perm.dtype)))
    et_t = jnp.take(edge_type, type_perm)            # relation per typed row
    # transformed source messages in (type, dst) order — the ONE grouped
    # launch of the layer
    msg = geot.grouped_segment_matmul(
        geot.gather(x, jnp.take(src, type_perm)), type_counts,
        prm["w_rel"].value, impl, None, rplan)
    msg_h = msg.reshape(msg.shape[0], heads, d_out)
    logit_src = jnp.einsum("ehd,ehd->eh", msg_h,
                           jnp.take(prm["a_src"].value, et_t, axis=0))
    logit_dst = jnp.einsum("ek,ehk->eh",
                           geot.gather(x, jnp.take(dst, type_perm)),
                           jnp.take(prm["a_dst"].value, et_t, axis=0))
    e_t = jax.nn.leaky_relu(logit_src + logit_dst, 0.2)     # typed order
    e = jnp.take(e_t, inv_type_perm, axis=0)                # dst order
    alpha = geot.segment_softmax(e, dst, num_nodes, impl, None, plan)
    out = 0.0
    for i in range(heads):
        out = out + geot.index_weight_segment_reduce(
            msg_h[:, i, :], inv_type_perm, alpha[..., i], dst, num_nodes,
            "sum", impl, None, plan)
    return out / heads


# ---------------------------------------------------------------------------
# 3-layer models (paper §V-F: node classification, 3 layers, hidden 32/64)
# ---------------------------------------------------------------------------

_LAYER = {"gcn": (gcn_layer_init, gcn_layer),
          "gin": (gin_layer_init, gin_layer),
          "sage": (sage_layer_init, sage_layer),
          "gat": (gat_layer_init, gat_layer),
          "rgcn": (rgcn_layer_init, rgcn_layer),
          "rgat": (rgat_layer_init, rgat_layer)}

# the homogeneous families every graph supports (the serve engine's model
# space); relation-typed families need a TypedGraph and are listed apart
MODELS = ("gcn", "gin", "sage", "gat")
TYPED_MODELS = ("rgcn", "rgat")


def init(key, model: str, d_in: int, hidden: int, num_classes: int,
         num_layers: int = 3, dtype=jnp.float32, heads: int = 1,
         num_relations: int = 4):
    """``heads`` > 1 builds multi-head attention layers (GAT/RGAT only);
    ``num_relations`` sizes the per-relation transforms of the typed
    families (ignored elsewhere)."""
    init_fn, _ = _LAYER[model]
    dims = [d_in] + [hidden] * (num_layers - 1) + [num_classes]
    ks = jax.random.split(key, num_layers)
    kwargs = {}
    if model in ("gat", "rgat"):
        kwargs["heads"] = heads
    if model in TYPED_MODELS:
        kwargs["num_relations"] = num_relations
    return [init_fn(k, dims[i], dims[i + 1], dtype, **kwargs)
            for i, k in enumerate(ks)]


def forward(params, model: str, x, edge_index, num_nodes: int,
            deg_inv_sqrt: Optional[jax.Array] = None, impl: str = "ref",
            plan=None, *, mesh=None, partition=None, edge_type=None,
            type_perm=None, inv_type_perm=None, type_counts=None,
            rplan=None):
    """``plan``: one :class:`~repro.core.plan.SegmentPlan` built on this
    graph's destinations — reused by every layer (and, via the custom VJPs,
    by the backward pass). One uniform layer call for every family — no
    per-model special-casing.

    ``partition``/``mesh``: run every aggregation sharded over a device
    mesh (``plan`` then being the matching
    :class:`~repro.core.plan.PartitionedPlan`; both are built on demand
    when omitted). The result stays the replicated global (V, C) logits —
    sharding is an execution detail, not an API change.

    Typed families (``rgcn``/``rgat``) additionally take ``edge_type``
    (+ the optional precomputed permutation triple of a
    :class:`~repro.data.graphs.TypedGraph` and a ``rplan``
    :class:`~repro.core.plan.RelationPlan`)."""
    if partition is not None and plan is None:
        plan = partition.make_plan(feat=int(x.shape[-1]))
    _, layer_fn = _LAYER[model]
    typed_kw = {}
    if model in TYPED_MODELS:
        typed_kw = dict(edge_type=edge_type, type_perm=type_perm,
                        inv_type_perm=inv_type_perm,
                        type_counts=type_counts, rplan=rplan)
    h = x
    for i, prm in enumerate(params):
        h = layer_fn(prm, h, edge_index, num_nodes, deg_inv_sqrt,
                     impl=impl, plan=plan, mesh=mesh, partition=partition,
                     **typed_kw)
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params, model: str, x, edge_index, labels, num_nodes: int,
            deg_inv_sqrt=None, impl: str = "ref", plan=None, *, mesh=None,
            partition=None, edge_type=None, type_perm=None,
            inv_type_perm=None, type_counts=None, rplan=None):
    """Node-classification cross entropy — same keyword surface as
    :func:`forward`, typed families included."""
    logits = forward(params, model, x, edge_index, num_nodes,
                     deg_inv_sqrt, impl, plan, mesh=mesh,
                     partition=partition, edge_type=edge_type,
                     type_perm=type_perm, inv_type_perm=inv_type_perm,
                     type_counts=type_counts, rplan=rplan)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
