"""Common building blocks: norms, RoPE, GeoT-backed embedding, attention
(blocked online-softmax for long sequences + KV-cache decode), MLP.

Everything is a pure function over a params pytree of :class:`~repro.models.params.P`
leaves; layer stacks are scanned (see transformer.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import P, dense_init, embed_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    prm = {"scale": ones_init((dim,), ("embed",), jnp.float32)}
    if cfg.norm == "layernorm":
        prm["bias"] = zeros_init((dim,), ("embed",), jnp.float32)
    return prm


def apply_norm(prm, x, cfg: ModelConfig, eps: float = 1e-5):
    """Statistics in fp32, elementwise math in the input dtype.

    Deliberate: upcasting the whole tensor makes XLA hoist a bf16→f32
    convert of the *stacked* scan residuals out of the backward loop —
    +2× activation memory (§Perf log #3). The fp32 convert below fuses
    into the reductions, so no f32 copy of x is ever materialized."""
    dt = x.dtype
    if cfg.norm == "layernorm":
        # E[x²]−E[x]² form: jnp.var materializes the full (x−µ)² tensor in
        # fp32; two fused reductions leave no full-size f32 intermediate
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, -1, keepdims=True)
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        var = jnp.maximum(ms - jnp.square(mu), 0.0)
        inv = jax.lax.rsqrt(var + eps)
        out = (x - mu.astype(dt)) * inv.astype(dt) \
            * prm["scale"].value.astype(dt) + prm["bias"].value.astype(dt)
    else:
        ms = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
        inv = jax.lax.rsqrt(ms + eps)
        out = x * inv.astype(dt) * prm["scale"].value.astype(dt)
    return out


def simple_rms(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (out * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (with partial-rotary support, e.g. StableLM's 25%)
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float, partial: float = 1.0):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    rot = int(d * partial) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out, xp], -1)


# ---------------------------------------------------------------------------
# GeoT-backed embedding: the backward scatter-add is sort + segment_reduce
# (the paper's op applied to every LM's training step — DESIGN.md §4)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def _embed_fwd(table, ids):
    return jnp.take(table, ids, axis=0), (ids, table.shape[0])


def _embed_bwd(res, g):
    from repro.distributed.sharding import ashard, sharding_active
    ids, vocab = res
    flat_ids = ids.reshape(-1)
    flat_g = g.reshape(-1, g.shape[-1])
    if sharding_active():
        # Under SPMD a *global* argsort of the token stream forces GSPMD to
        # replicate the (B·S, D) cotangent on every device (§Perf log #4 —
        # hypothesis refuted: the GeoT sort pays off per-shard, not
        # globally). Plain scatter-add partitions cleanly instead.
        flat_g = ashard(flat_g, "batch", None)
        dtab = jax.ops.segment_sum(flat_g.astype(jnp.float32), flat_ids,
                                   vocab, indices_are_sorted=False)
        return ashard(dtab, "vocab", "embed").astype(g.dtype), None
    order = jnp.argsort(flat_ids)
    # sorted scatter-add == GeoT segment_reduce (paper §II-B); the output
    # cotangent dtype equals the table dtype (take preserves dtype)
    dtab = jax.ops.segment_sum(
        jnp.take(flat_g, order, axis=0).astype(jnp.float32),
        jnp.take(flat_ids, order), vocab, indices_are_sorted=True)
    return dtab.astype(g.dtype), None


_embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def embedding_init(key, cfg: ModelConfig, dtype):
    return {"table": embed_init(key, cfg.padded_vocab, cfg.d_model, dtype)}


def embed(prm, ids):
    return _embed_lookup(prm["table"].value, ids)


def unembed(prm, x, cfg: ModelConfig):
    logits = jnp.einsum("...d,vd->...v", x, prm["table"].value)
    return (logits * cfg.logit_scale).astype(jnp.float32)


# ---------------------------------------------------------------------------
# TP output projection (hand-scheduled collective)
# ---------------------------------------------------------------------------

def tp_out_project(x, w_param):
    """x @ W with the contraction dim sharded over "model".

    Hand-scheduled TP projection: matmul per-shard via shard_map, psum of
    the bf16 output, FSDP all-gather of W's output dim inside.

    §Perf log #6 (hypothesis REFUTED on this artifact): intended to halve
    the TP all-reduce bytes by reducing in bf16 instead of GSPMD's hoisted
    f32, but XLA:CPU re-hoists the convert past the psum (and past an
    optimization_barrier), so the wire stays f32 and the extra reshards
    cost +11%% collectives — call sites reverted to plain matmuls. Kept as
    opt-in infrastructure: on TPU hardware XLA emits native bf16
    all-reduces, where this is the expected 2× wire win."""
    from repro.distributed.sharding import (current_context, effective_axes,
                                            spec_for_axes)
    w = w_param.value
    ctx = current_context()
    if ctx is None:
        return x @ w
    mesh, plan = ctx
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m_ax = plan.model_axes[0]
    w_spec = spec_for_axes(effective_axes(w_param), w.shape, plan, mesh)
    if w_spec[0] != m_ax or x.shape[-1] % sizes[m_ax] != 0:
        return x @ w                      # contraction not model-sharded
    dspec = tuple(plan.batch_axes) if len(plan.batch_axes) > 1 \
        else plan.batch_axes[0]
    dsize = 1
    for a in plan.batch_axes:
        dsize *= sizes[a]
    if x.shape[0] % dsize != 0:
        dspec = None

    def body(x_l, w_l):
        if w_spec[1] is not None:         # FSDP: regather W's output dim
            w_l = jax.lax.all_gather(w_l, w_spec[1], axis=1, tiled=True)
        return jax.lax.psum(x_l @ w_l, m_ax)      # psum in x.dtype (bf16)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(PS(dspec, *([None] * (x.ndim - 2)), m_ax),
                             PS(*w_spec)),
                   out_specs=PS(dspec, *([None] * (x.ndim - 1))),
                   check_rep=False)
    return fn(x, w)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KH, D)
    v: jax.Array
    length: jax.Array     # () int32 — tokens already cached


def attention_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    prm = {
        "wq": dense_init(ks[0], d, cfg.q_dim, ("embed", "heads"), dtype),
        "wk": dense_init(ks[1], d, cfg.kv_dim, ("embed", "kv"), dtype),
        "wv": dense_init(ks[2], d, cfg.kv_dim, ("embed", "kv"), dtype),
        "wo": dense_init(ks[3], cfg.q_dim, d, ("heads", "embed"), dtype),
    }
    if cfg.use_bias:
        prm["bq"] = zeros_init((cfg.q_dim,), ("heads",), dtype)
        prm["bk"] = zeros_init((cfg.kv_dim,), ("kv",), dtype)
        prm["bv"] = zeros_init((cfg.kv_dim,), ("kv",), dtype)
        prm["bo"] = zeros_init((d,), ("embed",), dtype)
    if cfg.qk_norm:
        prm["q_norm"] = ones_init((cfg.head_dim,), (None,), jnp.float32)
        prm["k_norm"] = ones_init((cfg.head_dim,), (None,), jnp.float32)
    return prm


def _project_qkv(prm, x, cfg: ModelConfig, positions, apply_rope: bool = True):
    b, s, _ = x.shape
    q = x @ prm["wq"].value
    k = x @ prm["wk"].value
    v = x @ prm["wv"].value
    if cfg.use_bias:
        q, k, v = q + prm["bq"].value, k + prm["bk"].value, v + prm["bv"].value
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = simple_rms(q, prm["q_norm"].value)
        k = simple_rms(k, prm["k_norm"].value)
    if apply_rope and cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
        k = rope(k, positions, cfg.rope_theta, cfg.partial_rotary)
    return q, k, v


def _blocked_attention(q, k, v, causal: bool, block: int = 1024):
    """Online-softmax attention, scanned over KV blocks — O(S·block) memory
    instead of O(S²) (required for the 32k-train/prefill cells to fit HBM).

    The scan body is rematerialized (jax.checkpoint): without it the scan's
    backward saves every block's (B, H, S, block) score tensor — the full
    S×S matrix in fp32 — defeating the blocked formulation (§Perf log #2)."""
    from repro.distributed.sharding import ashard
    b, sq, h, d = q.shape
    skv = k.shape[1]
    g = h // k.shape[2]                             # GQA group size
    scale = 1.0 / jnp.sqrt(d)
    qf = (q * scale).astype(jnp.float32)
    qf = ashard(qf, "batch", None, "act_heads", None)
    nblk = -(-skv // block)
    pad = nblk * block - skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nblk, block, -1, d).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nblk, block, -1, d).transpose(1, 0, 2, 3, 4)
    kb = ashard(kb, None, "batch", None, None, None)
    vb = ashard(vb, None, "batch", None, None, None)
    q_pos = jnp.arange(sq)

    @jax.checkpoint
    def body(carry, inp):
        acc, m, l = carry
        kcb, vcb, blk = inp
        kcb = jnp.repeat(kcb, g, axis=2)
        vcb = jnp.repeat(vcb, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kcb.astype(jnp.float32))
        kv_pos = blk * block + jnp.arange(block)
        mask = kv_pos[None, :] < skv                   # padding mask
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vcb.astype(jnp.float32))
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)    # (B, S, H, D)


def attention(prm, x, cfg: ModelConfig, positions=None, causal: bool = True,
              kv: Optional[tuple] = None, block: int = 1024):
    """Full-sequence attention (training / prefill). kv overrides K/V source
    (cross-attention)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(prm, x, cfg, positions)
    if kv is not None:
        k, v = kv
        causal = False
    out = _blocked_attention(q, k, v, causal, block=block)
    out = out.reshape(b, s, cfg.q_dim) @ prm["wo"].value
    if cfg.use_bias:
        out = out + prm["bo"].value
    return out


def attention_decode(prm, x, cfg: ModelConfig, cache: KVCache,
                     lengths=None):
    """Single-token decode against a KV cache (B, 1, D) → (B, 1, D).

    lengths: optional (B,) int32 per-slot cache lengths — the ragged path
    used by continuous batching (each slot at its own position, with its own
    validity mask); default uses the shared scalar cache.length."""
    b = x.shape[0]
    if lengths is None:
        pos = jnp.broadcast_to(cache.length[None, None], (b, 1))
    else:
        pos = lengths[:, None]
    q, k_new, v_new = _project_qkv(prm, x, cfg, pos)
    if lengths is None:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), cache.length, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), cache.length, axis=1)
        valid = (jnp.arange(k_cache.shape[1]) <= cache.length)[None]
    else:
        rows = jnp.arange(b)
        k_cache = cache.k.at[rows, lengths].set(
            k_new[:, 0].astype(cache.k.dtype))
        v_cache = cache.v.at[rows, lengths].set(
            v_new[:, 0].astype(cache.v.dtype))
        valid = jnp.arange(k_cache.shape[1])[None, :] <= lengths[:, None]
    g = cfg.num_heads // cfg.num_kv_heads
    scale = 1.0 / jnp.sqrt(cfg.head_dim)
    qh = q.reshape(b, 1, cfg.num_kv_heads, g, cfg.head_dim).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qh * scale,
                   k_cache.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.q_dim).astype(x.dtype) @ prm["wo"].value
    if cfg.use_bias:
        out = out + prm["bo"].value
    return out, KVCache(k_cache, v_cache, cache.length + 1)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  num_layers: Optional[int] = None):
    n = num_layers if num_layers is not None else cfg.num_layers
    shape = (n, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_init(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    prm = {
        "w_up": dense_init(ks[0], cfg.d_model, d_ff, ("embed", "mlp"), dtype),
        "w_down": dense_init(ks[1], d_ff, cfg.d_model, ("mlp", "embed"), dtype),
    }
    if cfg.mlp_gated:
        prm["w_gate"] = dense_init(ks[2], cfg.d_model, d_ff,
                                   ("embed", "mlp"), dtype)
    if cfg.use_bias:
        prm["b_up"] = zeros_init((d_ff,), ("mlp",), dtype)
        prm["b_down"] = zeros_init((cfg.d_model,), ("embed",), dtype)
    return prm


def mlp(prm, x, cfg: ModelConfig):
    act = _ACTS[cfg.act]
    h = x @ prm["w_up"].value
    if cfg.use_bias:
        h = h + prm["b_up"].value
    if cfg.mlp_gated:
        h = act(x @ prm["w_gate"].value) * h
    else:
        h = act(h)
    out = h @ prm["w_down"].value
    if cfg.use_bias:
        out = out + prm["b_down"].value
    return out
