"""Mamba selective-SSM block (for Jamba's hybrid stack, arXiv:2403.19887).

Standard Mamba-1 formulation: in-proj → causal conv1d → data-dependent
(Δ, B, C) → diagonal state-space scan → gated out-proj. The scan is a
`lax.scan` over time (O(1)-state decode ⇒ Jamba runs the long_500k cell).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import P, dense_init, zeros_init


class SSMState(NamedTuple):
    conv: jax.Array      # (B, d_conv-1, d_inner) rolling conv window
    h: jax.Array         # (B, d_inner, d_state) SSM state


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def ssm_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.expand * d
    ds = cfg.d_state
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, ("embed", "mlp"), dtype),
        "conv_w": P(jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                    * (1.0 / math.sqrt(cfg.d_conv)), (None, "mlp")),
        "conv_b": zeros_init((di,), ("mlp",), jnp.float32),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, ("mlp", None), dtype),
        "dt_proj": dense_init(ks[3], dtr, di, (None, "mlp"), jnp.float32),
        "dt_bias": P(jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.099 + 0.001,
                     1e-4, None))), ("mlp",)),
        "a_log": P(jnp.log(a), ("mlp", None)),
        "d_skip": P(jnp.ones((di,), jnp.float32), ("mlp",)),
        "out_proj": dense_init(ks[5], di, d, ("mlp", "embed"), dtype),
    }


def _selective_scan(prm, xc, cfg: ModelConfig, h0):
    """xc: (B, S, di) post-conv. Returns (y (B,S,di), h_final)."""
    dtr, ds = _dt_rank(cfg), cfg.d_state
    dbl = xc @ prm["x_proj"].value
    dt = jax.nn.softplus(
        dbl[..., :dtr].astype(jnp.float32) @ prm["dt_proj"].value
        + prm["dt_bias"].value)                                  # (B,S,di)
    bmat = dbl[..., dtr:dtr + ds].astype(jnp.float32)            # (B,S,ds)
    cmat = dbl[..., dtr + ds:].astype(jnp.float32)               # (B,S,ds)
    a = -jnp.exp(prm["a_log"].value)                             # (di,ds)

    def step(h, inp):
        xt, dtt, bt, ct = inp                                    # (B,di),(B,di),(B,ds),(B,ds)
        da = jnp.exp(dtt[..., None] * a)                         # (B,di,ds)
        dbx = (dtt * xt.astype(jnp.float32))[..., None] * bt[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + xc.astype(jnp.float32) * prm["d_skip"].value
    return y, h_final


def ssm_forward(prm, x, cfg: ModelConfig, state: SSMState):
    """x: (B, S, D) → (out, new_state)."""
    b, s, _ = x.shape
    di = cfg.expand * cfg.d_model
    xz = x @ prm["in_proj"].value
    xin, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv1d with carried window
    window = jnp.concatenate([state.conv.astype(xin.dtype), xin], axis=1)
    segs = [window[:, i: i + s] * prm["conv_w"].value[i].astype(xin.dtype)
            for i in range(cfg.d_conv)]
    xc = jax.nn.silu(sum(segs) + prm["conv_b"].value.astype(xin.dtype))
    y, h_final = _selective_scan(prm, xc, cfg, state.h)
    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype) \
        @ prm["out_proj"].value
    new_conv = window[:, s:]                                     # last d_conv-1
    return out, SSMState(new_conv.astype(jnp.float32), h_final)


def init_ssm_state(cfg: ModelConfig, batch: int, num_layers: int):
    di = cfg.expand * cfg.d_model
    return SSMState(
        jnp.zeros((num_layers, batch, cfg.d_conv - 1, di), jnp.float32),
        jnp.zeros((num_layers, batch, di, cfg.d_state), jnp.float32))
