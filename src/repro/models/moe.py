"""Mixture-of-Experts layer built on GeoT segment ops (DESIGN.md §4).

Token→expert routing *is* a sorted segment-reduction problem:

  dispatch — assignments sorted by expert id (the sortedness contract of
             paper §II-B), positions-within-expert from the segment offsets;
  experts  — grouped GEMM over expert segments (``segment_matmul``) in the
             dropless path, or a dense (E, C, D) einsum in the capacity path
             (EP-shardable: `expert` axis → mesh "model");
  combine  — ``index_weight_segment_reduce`` keyed by token id (already
             sorted) with the router probabilities as weights — *exactly*
             the paper's fused SpMM op (§IV).

Two implementations:
  * ``capacity`` — static-shape GShard-style buffers; the pjit/dry-run path.
  * ``ragged``   — dropless sort + segment_matmul; single-host path that
                   exercises the Pallas grouped-GEMM kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ops as geot
from repro.models.config import ModelConfig
from repro.models.params import P, dense_init
from repro.models import layers


def moe_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    std = 1.0 / jnp.sqrt(d)
    prm = {
        "router": dense_init(ks[0], d, e, ("embed", "expert"), jnp.float32),
        "w_up": P(jax.random.normal(ks[1], (e, d, f), dtype) * std,
                  ("expert", "embed", "mlp")),
        "w_gate": P(jax.random.normal(ks[2], (e, d, f), dtype) * std,
                    ("expert", "embed", "mlp")),
        "w_down": P(jax.random.normal(ks[3], (e, f, d), dtype) * (std / 4),
                    ("expert", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        prm["shared"] = layers.mlp_init(
            ks[4], cfg, dtype, d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return prm


def _route(prm, x2d, cfg: ModelConfig):
    """Router: top-k expert ids + combine weights per token."""
    logits = (x2d.astype(jnp.float32) @ prm["router"].value)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    e = cfg.num_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return top_e.astype(jnp.int32), top_p.astype(x2d.dtype), aux


def _experts_dense(prm, xd, cfg: ModelConfig):
    """(E, C, D) → (E, C, D), sharded over the expert axis under pjit."""
    act = layers._ACTS[cfg.act]
    hu = jnp.einsum("ecd,edf->ecf", xd, prm["w_up"].value)
    hg = jnp.einsum("ecd,edf->ecf", xd, prm["w_gate"].value)
    return jnp.einsum("ecf,efd->ecd", act(hg) * hu, prm["w_down"].value)


def moe_capacity(prm, x, cfg: ModelConfig, capacity: Optional[int] = None):
    """Static-shape MoE (pjit path). x: (B, S, D) → (B, S, D), aux loss."""
    from repro.distributed.sharding import ashard
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    top_e, top_p, aux = _route(prm, x2d, cfg)
    k = cfg.top_k
    e = cfg.num_experts
    if capacity is None:
        capacity = max(1, int(t * k * cfg.capacity_factor / e))
        capacity = min(capacity, t)
    capacity = -(-capacity // 32) * 32        # shardable over the data axes
    a = t * k

    e_flat = top_e.reshape(a)
    w_flat = top_p.reshape(a)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)   # sorted ✓

    # --- dispatch: sort assignments by expert (GeoT sortedness contract) ---
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = jnp.take(e_flat, order)
    pos_sorted = jnp.arange(a, dtype=jnp.int32) - jnp.take(
        jnp.searchsorted(e_sorted, jnp.arange(e, dtype=jnp.int32),
                         side="left").astype(jnp.int32), e_sorted)
    inv = jnp.zeros((a,), jnp.int32).at[order].set(
        jnp.arange(a, dtype=jnp.int32))
    pos = jnp.take(pos_sorted, inv)                 # position in expert, orig order
    keep = pos < capacity
    slot = jnp.where(keep, e_flat * capacity + pos, e * capacity)

    # the (T·k, D) gathered message tensor is batch-aligned (tok_flat is
    # token-sorted) — pin it to the data axes or GSPMD replicates the gather
    msg = ashard(jnp.take(x2d, tok_flat, axis=0), "batch", None)
    xd = jnp.zeros((e * capacity, d), x.dtype).at[slot].set(msg, mode="drop")
    # EP: experts on "model", capacity slots on the data axes (GShard layout)
    xd3 = ashard(xd.reshape(e, capacity, d), "expert", "capacity", None)
    yd = _experts_dense(prm, xd3, cfg)
    yd = ashard(yd, "expert", "capacity", None).reshape(e * capacity, d)

    # --- combine: the paper's fused op — gather rows by slot, weight by
    # router prob, segment-reduce over (sorted) token ids (§IV) ---
    slot_safe = jnp.minimum(slot, e * capacity - 1)
    out2d = geot.index_weight_segment_reduce(
        yd, slot_safe, jnp.where(keep, w_flat, 0.0), tok_flat, t)

    if cfg.num_shared_experts:
        out2d = out2d + layers.mlp(prm["shared"], x2d, cfg)
    return out2d.reshape(b, s, d).astype(x.dtype), aux


def moe_ragged(prm, x, cfg: ModelConfig, impl: str = "ref"):
    """Dropless MoE via sort + grouped GEMM (single-host / kernel path)."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    top_e, top_p, aux = _route(prm, x2d, cfg)
    k = cfg.top_k
    a = t * k
    e_flat = top_e.reshape(a)
    w_flat = top_p.reshape(a)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(e_flat, stable=True)
    tok_sorted = jnp.take(tok_flat, order)
    group_sizes = jnp.bincount(e_flat, length=cfg.num_experts).astype(jnp.int32)

    xs = jnp.take(x2d, tok_sorted, axis=0)
    act = layers._ACTS[cfg.act]
    hu = geot.segment_matmul(xs, group_sizes, prm["w_up"].value, impl=impl)
    hg = geot.segment_matmul(xs, group_sizes, prm["w_gate"].value, impl=impl)
    ys = geot.segment_matmul(act(hg) * hu, group_sizes, prm["w_down"].value,
                             impl=impl)

    # combine in original (token-sorted) assignment order — fused SpMM (§IV)
    inv = jnp.zeros((a,), jnp.int32).at[order].set(
        jnp.arange(a, dtype=jnp.int32))
    out2d = geot.index_weight_segment_reduce(ys, inv, w_flat, tok_flat, t)

    if cfg.num_shared_experts:
        out2d = out2d + layers.mlp(prm["shared"], x2d, cfg)
    return out2d.reshape(b, s, d).astype(x.dtype), aux


def moe_shard_map(prm, x, cfg: ModelConfig):
    """Expert-parallel MoE via shard_map (§Perf iteration #5).

    GSPMD partitions the global dispatch scatter by materialising a
    (T·k, D) u32 index grid and all-gathering it (~69 GB/chip/layer on the
    qwen3-moe train cell — measured). But the MoE input is already
    *replicated over the model axis* (it feeds TP attention), so dispatch
    can be entirely LOCAL: each device selects the assignments that target
    its own E/|model| experts, builds its capacity buffer with the GeoT
    sort + fused combine (the paper's ops, applied per shard), and the only
    cross-device traffic is the same (T_local, D) psum a dense TP MLP pays.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    from repro.distributed.sharding import current_context, spec_for_axes

    mesh, plan = current_context()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m_ax = plan.model_axes[0]
    msize = sizes[m_ax]
    d_axes = plan.batch_axes
    dsize = 1
    for a in d_axes:
        dsize *= sizes[a]
    e = cfg.num_experts
    b, s, d = x.shape
    t = b * s
    if e % msize != 0 or (b % dsize != 0 and t % dsize != 0):
        return moe_capacity(prm, x, cfg)         # unshardable → global path
    e_m = e // msize
    t_loc = t // dsize
    k = cfg.top_k
    cap = max(1, int(t_loc * k * cfg.capacity_factor / e))
    cap = -(-cap // 8) * 8

    x2d = x.reshape(t, d)
    top_e, top_p, aux = _route(prm, x2d, cfg)
    dspec = tuple(d_axes) if len(d_axes) > 1 else d_axes[0]

    from repro.distributed.sharding import effective_axes
    wu, wg, wd = prm["w_up"].value, prm["w_gate"].value, prm["w_down"].value
    wu_spec = spec_for_axes(effective_axes(prm["w_up"]), wu.shape, plan, mesh)
    wg_spec = spec_for_axes(effective_axes(prm["w_gate"]), wg.shape, plan, mesh)
    wd_spec = spec_for_axes(effective_axes(prm["w_down"]), wd.shape, plan, mesh)

    def gather_dim(w, spec, dim):
        if spec[dim] is not None:
            names = spec[dim]
            return jax.lax.all_gather(w, names, axis=dim, tiled=True)
        return w

    def body(x_loc, te_loc, tp_loc, wu_l, wg_l, wd_l):
        m_rank = jax.lax.axis_index(m_ax)
        # FSDP: rebuild the full hidden dim of the local experts' weights
        wu_f = gather_dim(wu_l, wu_spec, 1)
        wg_f = gather_dim(wg_l, wg_spec, 1)
        wd_f = gather_dim(wd_l, wd_spec, 2)

        a = t_loc * k
        e_flat = te_loc.reshape(a)
        w_flat = tp_loc.reshape(a)
        tok_flat = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)

        # GeoT dispatch (paper §II-B): sort assignments by expert id —
        # local to this shard, no collective
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = jnp.take(e_flat, order)
        pos_sorted = jnp.arange(a, dtype=jnp.int32) - jnp.take(
            jnp.searchsorted(e_sorted, jnp.arange(e, dtype=jnp.int32),
                             side="left").astype(jnp.int32), e_sorted)
        inv = jnp.zeros((a,), jnp.int32).at[order].set(
            jnp.arange(a, dtype=jnp.int32))
        pos = jnp.take(pos_sorted, inv)
        mine = (e_flat // e_m) == m_rank
        keep = jnp.logical_and(pos < cap, mine)
        slot = jnp.where(keep, (e_flat - m_rank * e_m) * cap + pos, e_m * cap)

        xd = jnp.zeros((e_m * cap, d), x.dtype).at[slot].set(
            jnp.take(x_loc, tok_flat, axis=0), mode="drop")
        xd3 = xd.reshape(e_m, cap, d)
        act = layers._ACTS[cfg.act]
        hu = jnp.einsum("ecd,edf->ecf", xd3, wu_f)
        hg = jnp.einsum("ecd,edf->ecf", xd3, wg_f)
        yd = jnp.einsum("ecf,efd->ecd", act(hg) * hu, wd_f)
        yd = yd.reshape(e_m * cap, d)

        # GeoT combine (paper §IV): fused gather+weight+segment-reduce over
        # the (sorted) token ids — local; then one TP-style psum
        slot_safe = jnp.minimum(slot, e_m * cap - 1)
        out_part = geot.index_weight_segment_reduce(
            yd, slot_safe, jnp.where(keep, w_flat, 0.0), tok_flat, t_loc)
        # combine psum rides the wire in bf16 — inside shard_map the wire
        # dtype is ours to pick (§Perf log #7): halves combine bytes
        return jax.lax.psum(out_part.astype(x.dtype), m_ax)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(PS(dspec, None), PS(dspec, None), PS(dspec, None),
                  wu_spec, wg_spec, wd_spec),
        out_specs=PS(dspec, None),
        check_rep=False)
    out2d = fn(x2d, top_e, top_p, wu, wg, wd).astype(x.dtype)

    if cfg.num_shared_experts:
        out2d = out2d + layers.mlp(prm["shared"], x2d, cfg)
    return out2d.reshape(b, s, d), aux


def moe(prm, x, cfg: ModelConfig, impl: str = "capacity"):
    if impl == "capacity":
        from repro.distributed.sharding import sharding_active
        if sharding_active():
            return moe_shard_map(prm, x, cfg)
        return moe_capacity(prm, x, cfg)
    return moe_ragged(prm, x, cfg, impl="ref" if impl == "ragged" else impl)
