"""internvl2-2b [vlm] — InternViT frontend STUB + InternLM2-1.8B backbone:
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]. input_specs provides precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553,
    norm="rmsnorm", act="silu", mlp_gated=True, use_bias=False,
    pos="rope", rope_theta=1000000.0,
    num_prefix_embeds=256,
)
