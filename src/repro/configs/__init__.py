"""Architecture registry: ``--arch <id>`` → ModelConfig."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "stablelm-1.6b": "stablelm_1_6b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen3-8b": "qwen3_8b",
    "starcoder2-15b": "starcoder2_15b",
    "whisper-tiny": "whisper_tiny",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "rwkv6-3b": "rwkv6_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "internvl2-2b": "internvl2_2b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_module(name: str):
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
