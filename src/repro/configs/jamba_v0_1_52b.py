"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba+attn 1:7 interleave (attention at layer i%8==4), MoE
16e top-2 every other layer (i%2==1) [arXiv:2403.19887; hf].
Hybrid ⇒ runs the long_500k cell (only 4 of 32 layers carry KV cache)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    norm="rmsnorm", act="silu", mlp_gated=True, use_bias=False, pos="none",
    num_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4, d_state=16, d_conv=4, expand=2,
    capacity_factor=1.25, supports_long_context=True,
)
