"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert
d_ff=2048 vocab=163840, MoE 384e top-8 + 1 shared expert, first layer
dense (DeepSeek-V3 lineage)  [arXiv:2501.kimi2; unverified, paper-table].
NOTE: the assignment specifies GQA kv=8 (not MLA); we follow the
assignment."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    norm="rmsnorm", act="silu", mlp_gated=True, use_bias=False,
    pos="rope", rope_theta=50000.0,
    num_experts=384, top_k=8, moe_d_ff=2048, num_shared_experts=1,
    first_dense=1, norm_topk=True, capacity_factor=1.25,
)
