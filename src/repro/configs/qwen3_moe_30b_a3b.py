"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) expert
d_ff=768 vocab=151936, MoE 128e top-8, norm_topk, qk_norm
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    norm="rmsnorm", act="silu", mlp_gated=True, use_bias=False,
    qk_norm=True, pos="rope", rope_theta=1000000.0,
    num_experts=128, top_k=8, moe_d_ff=768, norm_topk=True,
    capacity_factor=1.25,
)
