"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, RoPE [arXiv:2402.19173; hf]. StarCoder2 flavour: LayerNorm,
non-gated GeLU MLP, biases."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    norm="layernorm", act="gelu", mlp_gated=False, use_bias=True,
    pos="rope", rope_theta=100000.0, tie_embeddings=True,
)
