"""whisper-tiny [audio] — 4L d_model=384 6H d_ff=1536 vocab=51865,
enc-dec, conv frontend STUB (input_specs provides precomputed frame
embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    norm="layernorm", act="gelu", mlp_gated=False, use_bias=True,
    pos="learned", encoder_layers=4, cross_attention=True,
    num_prefix_embeds=0, max_seq=65536,
)
# encoder frame count used by input_specs (30 s of audio at 50 Hz)
NUM_FRAMES = 1500
