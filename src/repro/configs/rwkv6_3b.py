"""rwkv6-3b [ssm] — Finch, 32L d_model=2560 (attn-free, 40 heads × 64)
d_ff=8960 vocab=65536, data-dependent decay [arXiv:2404.05892; hf].
O(1)-state decode ⇒ runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    rwkv=True, pos="none", norm="layernorm",
    supports_long_context=True,
)
