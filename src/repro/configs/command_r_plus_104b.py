"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no-bias [hf:CohereForAI/c4ai-command-r-v01;
unverified]. Cohere flavour: parallel attn∥mlp block, LayerNorm,
logit scaling, full rotary."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    norm="layernorm", act="silu", mlp_gated=True, use_bias=False,
    parallel_block=True, logit_scale=0.0625, pos="rope", rope_theta=75000.0,
    tie_embeddings=True,
)
