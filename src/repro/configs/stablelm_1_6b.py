"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32, i.e. MHA)
d_ff=5632 vocab=100352  [hf:stabilityai/stablelm-2-1_6b; unverified].
StableLM-2 flavour: LayerNorm, partial rotary 25%, SwiGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352,
    norm="layernorm", partial_rotary=0.25, act="silu", mlp_gated=True,
    use_bias=False, pos="rope", rope_theta=10000.0,
)
