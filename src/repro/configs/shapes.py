"""Assigned input-shape cells and ShapeDtypeStruct stand-ins (dry-run).

  train_4k     seq=4,096   global_batch=256   → train_step
  prefill_32k  seq=32,768  global_batch=32    → forward (prefill)
  decode_32k   seq=32,768  global_batch=128   → serve_step (1 new token,
                                                KV/state cache of seq_len)
  long_500k    seq=524,288 global_batch=1     → serve_step; needs
               sub-quadratic attention ⇒ runs only for SSM/hybrid archs
               (rwkv6-3b, jamba-v0.1-52b); skip documented for the 8 pure
               full-attention archs (DESIGN.md §5).

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input — no device allocation (the full configs are exercised
only through lower/compile).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

SHAPE_NAMES: List[str] = list(SHAPES)


def cell_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if runnable, else the skip reason (recorded in EXPERIMENTS.md)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: 500k decode needs sub-quadratic "
                "attention (run only for SSM/hybrid archs)")
    return None


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for a shape cell (token batch for training,
    request batch for serving; stubbed frontend embeddings where the arch
    needs them)."""
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    if cell.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_prefix_embeds, cfg.d_model), act)
        if cfg.family == "audio":
            from repro.configs.whisper_tiny import NUM_FRAMES
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, NUM_FRAMES, cfg.d_model), act)
        return specs
    # decode: one new token against a cache of seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "audio":
        from repro.configs.whisper_tiny import NUM_FRAMES
        specs["enc_out"] = jax.ShapeDtypeStruct(
            (b, NUM_FRAMES, cfg.d_model), act)
    return specs
