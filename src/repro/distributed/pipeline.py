"""Pipeline parallelism (GPipe-style) over a mesh "pipe" axis.

Implemented with shard_map + collective_permute: each device holds one
stage's params; microbatches stream through the ring with a `lax.scan` over
(num_micro + num_stages - 1) ticks.  Bubble fraction = (S-1)/(M+S-1).

This is the optional PP dimension (DESIGN.md §6) — the default production
mesh is (data, model); PP composes for >2-axis deployments and is validated
by tests/test_pipeline.py on a host-device mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn, stage_params, x_micro, *, mesh: Mesh,
                     axis: str = "pipe"):
    """Run microbatches through a ring of pipeline stages.

    stage_fn(params, x) -> x        — one stage's computation
    stage_params: pytree whose leaves have leading dim = num_stages
    x_micro: (num_micro, micro_batch, ...) input microbatches
    Returns (num_micro, micro_batch, ...) outputs (after the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def body(params_local, xm):
        # shard_map leaves a leading stage dim of 1 on the params — strip it
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        rank = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any) — others take the ring input
            inject = jnp.where(t < n_micro, jnp.minimum(t, n_micro - 1), 0)
            x_in = jnp.where(rank == 0, xm[inject], buf)
            y = stage_fn(params_local, x_in)
            # pass activation to the next stage
            buf = jax.lax.ppermute(
                y, axis,
                perm=[(j, (j + 1) % n_stages) for j in range(n_stages)])
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            emit = jnp.logical_and(rank == n_stages - 1, out_idx >= 0)
            safe_idx = jnp.clip(out_idx, 0, n_micro - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, safe_idx, 0),
                lambda o: o, outs)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (zero, outs), jnp.arange(ticks))
        # broadcast the last stage's outputs to every stage (replicated out)
        outs = jax.lax.psum(
            jnp.where(rank == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(PS(axis), PS()),       # params sharded by stage, x replicated
        out_specs=PS(),
        check_rep=False)
    return fn(stage_params, x_micro)
