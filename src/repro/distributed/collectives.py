"""Hand-scheduled collectives (shard_map layer).

Explicit counterparts of what GSPMD inserts automatically — used where the
automatic schedule is the bottleneck (§Perf) or where we want compression on
the thin cross-pod link:

  ring_allreduce     — chunked ring reduce-scatter + all-gather via
                       ppermute. One chunk in flight per hop ⇒ each hop's
                       DMA overlaps the next chunk's add (the classic
                       latency-hiding schedule; XLA emits async permutes).
  ring_psum_matmul   — local partial matmul + ring_allreduce of the result.
  hierarchical_psum  — reduce-scatter on the fat intra-pod ICI axis, psum on
                       the thin cross-pod axis, all-gather back.
  compressed_psum    — hierarchical_psum with int8 error-feedback compression
                       on the pod hop (8× fewer DCI bytes).

All functions assume they run inside shard_map with the named axes present;
``make_ring_matmul`` builds the wrapped version.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

from repro.optim import compression


def _axis_size(axis_name: str) -> int:
    """Static size of a named axis. ``jax.lax.axis_size`` does not exist in
    the pinned JAX; ``psum`` of a literal 1 is evaluated at trace time from
    the axis env, yielding a concrete int usable in Python control flow."""
    return int(jax.lax.psum(1, axis_name))


def _shift_up(x, axis_name: str):
    n = _axis_size(axis_name)
    return jax.lax.ppermute(x, axis_name,
                            perm=[(j, (j + 1) % n) for j in range(n)])


def ring_allreduce(y, axis_name: str):
    """Chunked ring all-reduce of `y` (equivalent to psum(y, axis_name)).

    Falls back to psum when the leading dim doesn't split evenly."""
    n = _axis_size(axis_name)
    if n == 1:
        return y
    m = y.shape[0]
    if m % n != 0:
        return jax.lax.psum(y, axis_name)
    rank = jax.lax.axis_index(axis_name)
    bufs = y.reshape(n, m // n, *y.shape[1:])

    def rs_hop(bufs, step):
        send_idx = jnp.mod(rank - step, n)
        sent = jnp.take(bufs, send_idx, axis=0)
        recv = _shift_up(sent, axis_name)
        recv_idx = jnp.mod(rank - step - 1, n)
        upd = recv + jnp.take(bufs, recv_idx, axis=0)
        return jax.lax.dynamic_update_index_in_dim(bufs, upd, recv_idx, 0), None

    bufs, _ = jax.lax.scan(rs_hop, bufs, jnp.arange(n - 1))
    # device r now holds the fully-reduced chunk (r + 1) mod n

    def ag_hop(bufs, step):
        send_idx = jnp.mod(rank + 1 - step, n)
        sent = jnp.take(bufs, send_idx, axis=0)
        recv = _shift_up(sent, axis_name)
        recv_idx = jnp.mod(rank - step, n)
        return jax.lax.dynamic_update_index_in_dim(bufs, recv, recv_idx, 0), None

    bufs, _ = jax.lax.scan(ag_hop, bufs, jnp.arange(n - 1))
    return bufs.reshape(y.shape)


def ring_psum_matmul(x_local, w_local, axis_name: str):
    """psum_p(x_p @ w_p) with the reduction ring-scheduled.

    x_local: (m, k_local); w_local: (k_local, n)."""
    return ring_allreduce(x_local @ w_local, axis_name)


def hierarchical_psum(x, pod_axis: str, data_axis: str):
    """reduce-scatter intra-pod → cross-pod psum → all-gather intra-pod.

    Equivalent to psum over (pod, data) but the cross-pod (DCI) hop moves
    1/|data| of the bytes."""
    n = _axis_size(data_axis)
    if x.shape[0] % n == 0:
        scat = jax.lax.psum_scatter(x, data_axis, scatter_dimension=0,
                                    tiled=True)
        scat = jax.lax.psum(scat, pod_axis)
        return jax.lax.all_gather(scat, data_axis, axis=0, tiled=True)
    return jax.lax.psum(jax.lax.psum(x, data_axis), pod_axis)


def compressed_psum(x, ef, pod_axis: str, data_axis: str):
    """hierarchical_psum with int8 EF-compression on the cross-pod hop.
    Returns (reduced, new_error_feedback)."""
    n = _axis_size(data_axis)
    if x.shape[0] % n != 0:
        return jax.lax.psum(jax.lax.psum(x, data_axis), pod_axis), ef
    scat = jax.lax.psum_scatter(x, data_axis, scatter_dimension=0, tiled=True)
    v = scat.astype(jnp.float32) + ef
    # shared scale across pods (one scalar pmax) so int8 payloads sum exactly
    absmax = jax.lax.pmax(jnp.max(jnp.abs(v)), pod_axis)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    new_ef = v - q.astype(jnp.float32) * scale
    qsum = jax.lax.psum(q.astype(jnp.int32), pod_axis)
    scat = qsum.astype(jnp.float32) * scale
    return jax.lax.all_gather(scat, data_axis, axis=0, tiled=True), new_ef


def make_ring_matmul(mesh: Mesh, axis: str = "model"):
    """shard_map-wrapped ring matmul: x (m, K) k-sharded, w (K, n) k-sharded,
    result replicated over `axis`."""
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(PS(None, axis), PS(axis, None)),
        out_specs=PS(None, None),
        check_rep=False)   # replication via ppermute isn't statically inferable
    def fn(x_local, w_local):
        return ring_psum_matmul(x_local, w_local, axis)
    return fn
