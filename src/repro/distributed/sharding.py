"""Logical-axis → mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

Parameters carry logical axes (repro.models.params.P); these rules translate
them to ``PartitionSpec``s on the production mesh:

  mesh axes: ("data", "model")              — single pod (16 × 16)
             ("pod", "data", "model")       — multi-pod (2 × 16 × 16)

  TP   : "mlp"/"heads"/"kv"/"vocab"/"expert" → "model"
  FSDP : "embed" (param hidden dim)          → ("pod","data")  [ZeRO-3]
  DP   : activation "batch"                  → ("pod","data")
  SP   : activation "seq" (long-context)     → "model" or "data" per plan
  EP   : "expert"                            → "model"

Any rule whose dimension is not divisible by the assigned mesh axes falls
back to replication (guarded in ``spec_for_axes``) — e.g. whisper-tiny's
6 q-heads on a 16-way model axis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.params import P, is_param


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    fsdp: bool = True                  # shard "embed" over data (ZeRO-3)
    seq_shard_axis: Optional[str] = None   # SP: shard activation "seq"
    batch_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)

    @staticmethod
    def for_mesh(mesh: Mesh, fsdp: bool = True,
                 seq_shard_axis: Optional[str] = None) -> "ParallelPlan":
        batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return ParallelPlan(fsdp=fsdp, seq_shard_axis=seq_shard_axis,
                            batch_axes=batch, model_axes=("model",))


def _rules(plan: ParallelPlan):
    data = plan.batch_axes
    return {
        # parameter logical axes
        "embed": data if plan.fsdp else None,
        "mlp": plan.model_axes,
        "heads": plan.model_axes,
        "kv": plan.model_axes,
        "vocab": plan.model_axes,
        "expert": plan.model_axes,
        "layers": None,
        "embed2": None,
        # activation logical axes
        "batch": data,
        "seq": (plan.seq_shard_axis,) if plan.seq_shard_axis else None,
        "capacity": data,
        "act_vocab": plan.model_axes,
        "act_heads": plan.model_axes,
        None: None,
    }


def spec_for_axes(axes, shape, plan: ParallelPlan, mesh: Mesh) -> PartitionSpec:
    """PartitionSpec for one tensor.

    Guards: (a) divisibility — dims not divisible by their mesh-axis product
    fall back to replication (e.g. whisper's 6 heads on a 16-way model axis);
    (b) uniqueness — a mesh axis maps to at most one dim, first axis in the
    logical order wins (e.g. MoE expert weights (expert, embed, mlp): the
    expert dim takes "model", so mlp stays unsharded)."""
    rules = _rules(plan)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        assign = rules.get(name)
        if assign is None:
            entries.append(None)
            continue
        assign = tuple(a for a in (assign if isinstance(assign, tuple)
                                   else (assign,))
                       if a is not None and a not in used)
        total = int(np.prod([sizes[a] for a in assign])) if assign else 1
        if assign and dim % total == 0:
            entries.append(assign if len(assign) > 1 else assign[0])
            used.update(assign)
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def effective_axes(p: P):
    """Axes aligned to the *current* value rank: a lax.scan over stacked
    layers slices the leading "layers" dim off the value while the aux axes
    ride along unchanged — drop it when interpreting a sliced leaf."""
    ax = p.axes
    nd = getattr(p.value, "ndim", len(ax))
    if len(ax) == nd + 1 and ax[0] == "layers":
        return ax[1:]
    return ax


def param_specs(params, plan: ParallelPlan, mesh: Mesh):
    """PartitionSpec pytree (prefix tree: one spec per P leaf)."""
    return jax.tree_util.tree_map(
        lambda p: spec_for_axes(p.axes, p.value.shape, plan, mesh),
        params, is_leaf=is_param)


def param_shardings(params, plan: ParallelPlan, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, spec_for_axes(p.axes, p.value.shape,
                                                    plan, mesh)),
        params, is_leaf=is_param)


# ---------------------------------------------------------------------------
# activation constraints — a process-global context so model code can
# annotate without threading mesh/plan through every call
# ---------------------------------------------------------------------------

_CTX: list = []


class activation_sharding:
    """with activation_sharding(mesh, plan): ... enables ashard()."""

    def __init__(self, mesh: Mesh, plan: ParallelPlan):
        self.mesh, self.plan = mesh, plan

    def __enter__(self):
        _CTX.append((self.mesh, self.plan))
        return self

    def __exit__(self, *exc):
        _CTX.pop()


def ashard(x, *axes):
    """Constrain activation x to logical axes (no-op outside a context)."""
    if not _CTX:
        return x
    mesh, plan = _CTX[-1]
    spec = spec_for_axes(axes, x.shape, plan, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_active() -> bool:
    return bool(_CTX)


def current_context():
    """(mesh, plan) of the innermost activation_sharding context, or None."""
    return _CTX[-1] if _CTX else None


def batch_spec(plan: ParallelPlan, mesh: Mesh, *, seq_sharded: bool = False):
    """Sharding for a (B, S) token batch."""
    b = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
    s = plan.seq_shard_axis if seq_sharded else None
    return PartitionSpec(b, s)
