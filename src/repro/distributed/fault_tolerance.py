"""Fault tolerance for the training loop (DESIGN.md §6).

Pieces (all host-side, framework-agnostic, unit-tested):
  StragglerMonitor   — rolling step-time stats; flags steps > factor × p50
                       and recommends action after repeated offences.
  StepWatchdog       — hard wall-clock deadline per step (a hung collective
                       on a dead node looks like an infinite step).
  ResilientLoop      — runs steps, checkpoints every K, and on failure
                       restores the latest complete checkpoint and replays.
                       Deterministic data (seeded per step) makes replay
                       exact. `max_restarts` bounds crash loops.

On a real multi-host deployment the restore path re-enters through
``jax.distributed.initialize`` with the surviving hosts (elastic mesh —
checkpoint restore accepts a different mesh, see checkpoint.py); here the
logic is exercised with injected failures (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional

from repro.checkpoint import checkpoint as ckpt


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 50,
                 tolerance: int = 3):
        self.factor = factor
        self.window = window
        self.tolerance = tolerance
        self.times: list[float] = []
        self.offences = 0

    def record(self, duration_s: float) -> dict:
        self.times.append(duration_s)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = sorted(self.times)[len(self.times) // 2]
        is_straggler = (len(self.times) >= 5
                        and duration_s > self.factor * med)
        self.offences = self.offences + 1 if is_straggler else 0
        return {
            "median_s": med,
            "is_straggler": is_straggler,
            # repeated stragglers ⇒ a sick node: re-shard / evict, don't wait
            "action": ("evict" if self.offences >= self.tolerance
                       else "warn" if is_straggler else "ok"),
        }


class StepTimeout(RuntimeError):
    pass


class StepWatchdog:
    """Hard deadline around a blocking step call.

    A timed-out step's thread cannot be killed (Python offers no such
    primitive) — it keeps running until the blocking call returns. The
    watchdog *tracks* every such thread instead of dropping it on the
    floor: :meth:`reap` joins the ones that have since finished and
    reports how many are still alive, and each :meth:`run` reaps first,
    so a long-lived loop cannot accumulate unobserved zombie threads.
    """

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._timed_out: list[threading.Thread] = []

    def reap(self) -> int:
        """Join finished timed-out threads; return the count still alive."""
        still = []
        for th in self._timed_out:
            th.join(0)
            if th.is_alive():
                still.append(th)
        self._timed_out = still
        return len(still)

    def run(self, fn: Callable[[], Any]) -> Any:
        self.reap()
        result: list = []
        error: list = []

        def target():
            try:
                result.append(fn())
            except BaseException as e:  # noqa: BLE001 — propagated below
                error.append(e)

        th = threading.Thread(target=target, daemon=True)
        th.start()
        th.join(self.timeout_s)
        if th.is_alive():
            self._timed_out.append(th)
            raise StepTimeout(f"step exceeded {self.timeout_s}s deadline")
        if error:
            raise error[0]
        return result[0]


@dataclasses.dataclass
class ResilientLoopConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    step_timeout_s: Optional[float] = None
    straggler_factor: float = 3.0


class ResilientLoop:
    """Checkpoint/restart training loop with failure replay.

    step_fn(state, step:int) -> (state, metrics); state is any pytree
    (params, opt, …).  Data must be derivable from the step index
    (repro.data.tokens is), so replay after restore is exact."""

    def __init__(self, cfg: ResilientLoopConfig, step_fn, init_state):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = init_state
        self.monitor = StragglerMonitor(cfg.straggler_factor)
        self.restarts = 0
        self.events: list[tuple] = []

    def _restore(self, failed_step: int, entry_state, entry_step: int):
        """Roll back to the newest checkpoint **at or before** the failed
        step. A newer checkpoint (stale steps from an earlier run sharing
        the directory) would jump the loop past its failure point with
        foreign state. With no eligible checkpoint, fall back to the
        state the run entered with."""
        latest = (ckpt.latest_step(self.cfg.ckpt_dir,
                                   at_or_before=failed_step)
                  if self.cfg.ckpt_dir else None)
        if latest is None or latest < entry_step:
            self.state = entry_state
            self.events.append(("restored_entry", entry_step))
            return entry_step
        self.state = ckpt.restore(self.state, self.cfg.ckpt_dir, step=latest)
        self.events.append(("restored", latest))
        return latest

    def run(self, num_steps: int, start_step: int = 0,
            metrics_cb: Optional[Callable] = None):
        step = start_step
        entry_state = self.state        # _restore's no-checkpoint fallback
        watchdog = (StepWatchdog(self.cfg.step_timeout_s)
                    if self.cfg.step_timeout_s else None)
        while step < num_steps:
            try:
                t0 = time.monotonic()
                if watchdog:
                    self.state, metrics = watchdog.run(
                        lambda: self.step_fn(self.state, step))
                else:
                    self.state, metrics = self.step_fn(self.state, step)
                dt = time.monotonic() - t0
                verdict = self.monitor.record(dt)
                if verdict["action"] == "evict":
                    self.events.append(("straggler_evict", step))
                    self.monitor.offences = 0
                if metrics_cb:
                    metrics_cb(step, metrics, verdict)
                step += 1
                if self.cfg.ckpt_dir and step % self.cfg.ckpt_every == 0:
                    ckpt.save(self.state, self.cfg.ckpt_dir, step,
                              keep=self.cfg.keep)
                    self.events.append(("saved", step))
            except (StepTimeout, RuntimeError, ValueError) as e:
                self.restarts += 1
                self.events.append(("failure", step, repr(e)))
                if self.restarts > self.cfg.max_restarts:
                    raise
                step = self._restore(step, entry_state, start_step)
        if watchdog:
            watchdog.reap()
        return self.state
