"""Jit'd distributed train/serve step builders (pjit path).

``build_train_step`` / ``build_serve_step`` return (fn, in_shardings,
out_shardings) ready for ``jax.jit(..., in_shardings=...)`` — the dry-run
lowers exactly these.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed import sharding as shd
from repro.models import lm, rwkv as rwkv_lib, ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.params import is_param
from repro.optim import adamw, schedule
from repro.optim.adamw import QTensor


class TrainStepConfig(NamedTuple):
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000
    remat_policy: str = "full"
    moe_impl: str = "capacity"


# ---------------------------------------------------------------------------
# shardings for optimizer state (mirrors params; QTensor scale replicated)
# ---------------------------------------------------------------------------

def opt_shardings(params, plan, mesh: Mesh, opt_state):
    """Moment shardings mirror the param shardings. int8 (QTensor) moments
    shard the payload like the param and replicate the scalar scale; the
    prefix tree must keep the P-node structure so QTensor fields match."""
    psh = shd.param_shardings(params, plan, mesh)
    rep = NamedSharding(mesh, PartitionSpec())
    from repro.models.params import P

    def moment_sh(sh, leaf):
        inner = leaf.value if is_param(leaf) else leaf
        if isinstance(inner, QTensor):
            return P(QTensor(sh, rep), leaf.axes) if is_param(leaf) \
                else QTensor(sh, rep)
        return sh

    is_leaf = lambda x: isinstance(x, NamedSharding)
    mu = jax.tree_util.tree_map(moment_sh, psh, opt_state.mu, is_leaf=is_leaf)
    nu = jax.tree_util.tree_map(moment_sh, psh, opt_state.nu, is_leaf=is_leaf)
    return adamw.AdamWState(rep, mu, nu)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, mesh: Mesh, plan: shd.ParallelPlan,
                     ts: TrainStepConfig = TrainStepConfig(),
                     batch_fields=("tokens", "labels"),
                     extra_batch_specs: Optional[dict] = None):
    """Returns (train_step, in_shardings, out_shardings, donate)."""

    def train_step(params, opt_state, batch, step):
        with shd.activation_sharding(mesh, plan):
            def loss(p):
                return lm.loss_fn(p, cfg, batch,
                                  remat_policy=ts.remat_policy,
                                  moe_impl=ts.moe_impl)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            lr_scale = schedule.warmup_cosine(step, ts.warmup_steps,
                                              ts.total_steps)
            new_params, new_opt, om = adamw.update(grads, opt_state, params,
                                                   ts.opt, lr_scale=lr_scale)
        metrics = dict(metrics, loss=l, **om)
        return new_params, new_opt, metrics

    def shardings_for(params, opt_state, batch_shapes: dict):
        """batch_shapes: field → concrete shape (divisibility-aware specs)."""
        psh = shd.param_shardings(params, plan, mesh)
        osh = opt_shardings(params, plan, mesh, opt_state)
        bsh = {}
        for f, shape in batch_shapes.items():
            axes = ("batch", "seq") + (None,) * (len(shape) - 2)
            bsh[f] = NamedSharding(mesh,
                                   shd.spec_for_axes(axes, shape, plan, mesh))
        if extra_batch_specs:
            bsh.update({k: NamedSharding(mesh, v)
                        for k, v in extra_batch_specs.items()})
        rep = NamedSharding(mesh, PartitionSpec())
        in_sh = (psh, osh, bsh, rep)
        out_sh = (psh, osh, None)
        return in_sh, out_sh

    return train_step, shardings_for


# ---------------------------------------------------------------------------
# serve (decode) step
# ---------------------------------------------------------------------------

def decode_state_specs(cfg: ModelConfig, mesh: Mesh, plan: shd.ParallelPlan,
                       batch: int, max_len: int):
    """PartitionSpec tree mirroring lm.init_decode_state's structure."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = plan.model_axes[0]
    msize = sizes[model]
    dsize = 1
    for a in plan.batch_axes:
        dsize *= sizes[a]
    baxes = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
    b_ok = batch % dsize == 0

    def kv_spec():
        kh = cfg.num_kv_heads
        kh_s = model if kh % msize == 0 else None
        seq_s = None
        if kh_s is None and max_len % msize == 0:
            # GQA with few KV heads: shard the cache over *sequence* on the
            # model axis (flash-decode style) — scores/softmax shard over S
            # with only scalar-sized cross-shard reductions. 6.4× fewer
            # decode collectives than head_dim sharding, which forced GSPMD
            # into involuntary cache rematerialisation (§Perf log #8).
            seq_s = model
        elif not b_ok and max_len % dsize == 0:
            seq_s = baxes          # long-context: shard cache sequence (SP)
        p = PartitionSpec(None, baxes if b_ok else None, seq_s, kh_s, None)
        return (p, p)

    def ssm_spec():
        di = cfg.expand * cfg.d_model
        di_s = model if di % msize == 0 else None
        return ssm_lib.SSMState(
            PartitionSpec(None, baxes if b_ok else None, None, di_s),
            PartitionSpec(None, baxes if b_ok else None, di_s, None))

    def rwkv_spec():
        h_s = model if cfg.num_heads % msize == 0 else None
        d_s = model if cfg.d_model % msize == 0 else None
        return rwkv_lib.RWKVState(
            PartitionSpec(None, baxes if b_ok else None, h_s, None, None),
            PartitionSpec(None, baxes if b_ok else None, d_s),
            PartitionSpec(None, baxes if b_ok else None, d_s))

    def mk(kind):
        return {"attn": kv_spec, "mamba": ssm_spec, "rwkv": rwkv_spec}[kind[0]]()

    lead_kinds, period_kinds, _ = stack_plan_cached(cfg)
    lead = tuple(jax.tree_util.tree_map(lambda s: PartitionSpec(*s[1:]), mk(k),
                                        is_leaf=lambda x: isinstance(x, PartitionSpec))
                 for k in lead_kinds)
    period = tuple(mk(k) for k in period_kinds)
    return lm.DecodeState(lead, period, PartitionSpec())


@functools.lru_cache(maxsize=64)
def stack_plan_cached(cfg):
    return lm.stack_plan(cfg)


def build_serve_step(cfg: ModelConfig, mesh: Mesh, plan: shd.ParallelPlan,
                     batch: int, max_len: int, moe_impl: str = "capacity"):
    """Single-token decode step. Returns (serve_step, in_shardings)."""

    def serve_step(params, tokens, state):
        with shd.activation_sharding(mesh, plan):
            logits, new_state = lm.decode_step(params, cfg, tokens, state,
                                               moe_impl=moe_impl)
        return logits, new_state

    def shardings_for(params):
        psh = shd.param_shardings(params, plan, mesh)
        tok_sh = NamedSharding(mesh, shd.spec_for_axes(
            ("batch", None), (batch, 1), plan, mesh))
        st_spec = decode_state_specs(cfg, mesh, plan, batch, max_len)
        st_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), st_spec,
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        return (psh, tok_sh, st_sh)

    return serve_step, shardings_for


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, plan: shd.ParallelPlan,
                       moe_impl: str = "capacity",
                       remat_policy: str = "none"):
    """Full-sequence forward (inference prefill — logits only)."""

    def prefill(params, batch):
        with shd.activation_sharding(mesh, plan):
            logits, _ = lm.forward(params, cfg, batch["tokens"],
                                   prefix_embeds=batch.get("prefix_embeds"),
                                   enc_embeds=batch.get("enc_embeds"),
                                   remat_policy=remat_policy,
                                   moe_impl=moe_impl)
        return logits

    return prefill
