"""Seeded GraphSAGE-style k-hop neighbor sampling into CSR-sorted
subgraphs — the front half of the out-of-core mini-batch pipeline
(``docs/sampling.md``).

Everything in the repo before this module assumes the whole graph lives
on device. The sampler inverts that: the *graph* stays on host (or on
disk, via :class:`ShardedGraphStore`), and each training/serving step
sees only a small **subgraph** around a batch of seed nodes —

  * hop ``h`` expands the in-neighborhoods of the nodes discovered at
    hop ``h-1`` (seeds at hop 0), capped at ``fanouts[h]`` in-edges per
    node (GraphSAGE fanout sampling). ``fanout=None`` takes the exact
    full neighborhood — the mode the parity tests use: a depth-``L``
    exact subgraph reproduces a depth-``L`` GNN's logits on the seed
    nodes bit-for-bit up to float association;
  * local node ids are assigned in discovery order with **seeds first**,
    so the model's output rows ``[0, num_seeds)`` are the seed logits;
  * because nodes are expanded in increasing local-id order and each
    node's in-edges are contiguous, the emitted ``edge_index`` comes out
    **destination-sorted by construction** — the invariant every plan /
    kernel in the library requires (validated, never silently fixed);
  * the subgraph carries the **parent graph's** ``deg_inv_sqrt`` (GCN's
    normalizer is a property of the full graph, not of the sample), its
    features, and its labels.

Sampling is **deterministic in (seed, step)**: one ``Generator`` seeded
from exactly that pair drives the whole batch, and nodes are expanded in
a fixed order — the same step yields the same subgraph on any run, any
thread count, any prefetch depth. That is the property checkpoint replay
(:mod:`repro.train`) and the async pipeline (:mod:`repro.data.pipeline`)
lean on.

Graph access goes through a small store interface (``num_nodes`` /
``in_edges(node)`` / ``gather_nodes(ids)``), with two implementations:
:class:`InMemoryStore` (a CSR view over a resident
:class:`~repro.data.graphs.Graph`) and :class:`ShardedGraphStore` — the
out-of-core layout: contiguous destination ranges (every node's
in-edges live in exactly one shard), one ``.npz`` file per shard, and a
bounded LRU of resident shards, so graphs far larger than host memory
stream through the sampler shard by shard.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.data.graphs import Graph

__all__ = ["Subgraph", "InMemoryStore", "ShardedGraphStore",
           "save_graph_shards", "NeighborSampler"]


@dataclasses.dataclass(frozen=True)
class Subgraph(Graph):
    """A sampled neighborhood as a first-class :class:`Graph` (plans,
    padding, batching, and every model work on it unchanged), plus the
    sampling bookkeeping:

      * ``node_ids`` — global (parent-store) id of each local node;
        ``node_ids[:num_seeds]`` are the seed nodes, in seed order;
      * ``num_seeds`` — how many leading local nodes are seeds (the rows
        a loss / serving response should restrict to).
    """
    node_ids: Optional[np.ndarray] = None    # (V_sub,) int64 global ids
    num_seeds: int = 0

    def __post_init__(self):
        if self.node_ids is None:
            raise ValueError("Subgraph requires node_ids")
        if not (0 <= self.num_seeds <= self.num_nodes):
            raise ValueError(
                f"num_seeds={self.num_seeds} outside [0, {self.num_nodes}]")

    @property
    def seed_nodes(self) -> np.ndarray:
        """Global ids of the seed nodes (== node_ids[:num_seeds])."""
        return self.node_ids[:self.num_seeds]


# ---------------------------------------------------------------------------
# graph stores: CSR in-edge access, resident or out-of-core
# ---------------------------------------------------------------------------

class InMemoryStore:
    """CSR in-edge view over a resident :class:`~repro.data.graphs.Graph`.

    ``edge_index[1]`` is destination-sorted (the library invariant), so
    node ``d``'s in-edges are the contiguous slice
    ``src[indptr[d]:indptr[d+1]]`` — one ``searchsorted`` builds the
    whole index."""

    def __init__(self, graph: Graph):
        dst = graph.edge_index[1]
        if dst.size and np.any(np.diff(dst) < 0):
            raise ValueError("edge_index[1] must be sorted non-decreasing")
        self._g = graph
        self.num_nodes = int(graph.num_nodes)
        self.num_edges = int(graph.num_edges)
        self.feat = int(graph.x.shape[1])
        self.num_classes = int(graph.labels.max()) + 1 if graph.labels.size \
            else 1
        self.indptr = np.searchsorted(
            dst, np.arange(self.num_nodes + 1)).astype(np.int64)
        self.src = graph.edge_index[0]

    def in_edges(self, node: int) -> np.ndarray:
        """Global source ids of ``node``'s in-edges (CSR order; possibly
        empty — isolated nodes are first-class here)."""
        return self.src[self.indptr[node]:self.indptr[node + 1]]

    def in_degree(self, node: int) -> int:
        return int(self.indptr[node + 1] - self.indptr[node])

    def gather_nodes(self, ids: np.ndarray) -> dict:
        """Per-node data rows for the given global ids."""
        ids = np.asarray(ids)
        return {"x": self._g.x[ids],
                "labels": self._g.labels[ids],
                "deg_inv_sqrt": self._g.deg_inv_sqrt[ids]}


def save_graph_shards(graph: Graph, path: str, num_shards: int) -> str:
    """Write ``graph`` as an out-of-core shard directory for
    :class:`ShardedGraphStore`.

    Layout: ``meta.json`` (sizes + the node partition) and one
    ``shard_{i}.npz`` per shard holding a contiguous **destination**
    range's in-edges (``src`` + local ``indptr``) and its nodes' data
    rows. Boundaries are placed by in-edge balance (the same
    edge-balancing idea as :func:`repro.data.partition.partition_graph`,
    but dst-owned: the sampler reads in-neighborhoods, so a node's
    in-edges must never straddle shards)."""
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    store = InMemoryStore(graph)
    v, e = store.num_nodes, store.num_edges
    # node_ptr[s] .. node_ptr[s+1]: shard s's destination range, boundaries
    # at (approximately) equal cumulative in-edge counts
    targets = (np.arange(1, num_shards) * e) / num_shards
    cuts = np.searchsorted(store.indptr[1:-1], targets, side="left") + 1 \
        if v > 1 else np.zeros(0, np.int64)
    node_ptr = np.concatenate([[0], np.clip(cuts, 0, v), [v]]).astype(np.int64)
    node_ptr = np.maximum.accumulate(node_ptr)
    os.makedirs(path, exist_ok=True)
    for s in range(num_shards):
        lo, hi = int(node_ptr[s]), int(node_ptr[s + 1])
        e_lo, e_hi = int(store.indptr[lo]), int(store.indptr[hi])
        np.savez(os.path.join(path, f"shard_{s}.npz"),
                 indptr=(store.indptr[lo:hi + 1] - e_lo).astype(np.int64),
                 src=store.src[e_lo:e_hi].astype(np.int32),
                 x=graph.x[lo:hi],
                 labels=graph.labels[lo:hi],
                 deg_inv_sqrt=graph.deg_inv_sqrt[lo:hi])
    meta = {"name": graph.name, "num_nodes": v, "num_edges": e,
            "num_shards": num_shards, "feat": store.feat,
            "num_classes": store.num_classes,
            "node_ptr": [int(p) for p in node_ptr]}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    return path


class ShardedGraphStore:
    """Out-of-core graph access over a :func:`save_graph_shards` directory.

    At most ``cache_shards`` shard files are resident at a time (LRU) —
    the host-memory bound that lets graphs far larger than RAM feed the
    sampler. Locality is real, not hoped-for: a batch's seed nodes are
    contiguous ranges only by accident, but every *single* node's whole
    in-neighborhood is one shard, so a k-hop expansion touches O(distinct
    shards of the frontier) loads, amortized by the LRU."""

    def __init__(self, path: str, cache_shards: int = 2):
        if cache_shards < 1:
            raise ValueError("cache_shards must be >= 1")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        self.path = path
        self.name = meta["name"]
        self.num_nodes = int(meta["num_nodes"])
        self.num_edges = int(meta["num_edges"])
        self.num_shards = int(meta["num_shards"])
        self.feat = int(meta["feat"])
        self.num_classes = int(meta["num_classes"])
        self.node_ptr = np.asarray(meta["node_ptr"], np.int64)
        self.cache_shards = int(cache_shards)
        self.loads = 0               # shard file reads (the out-of-core cost)
        self._lru: "collections.OrderedDict[int, dict]" = \
            collections.OrderedDict()

    def _shard_of(self, node: int) -> int:
        return int(np.searchsorted(self.node_ptr, node, side="right") - 1)

    def _shard(self, s: int) -> dict:
        hit = self._lru.get(s)
        if hit is not None:
            self._lru.move_to_end(s)
            return hit
        with np.load(os.path.join(self.path, f"shard_{s}.npz")) as z:
            data = {k: z[k] for k in z.files}
        self.loads += 1
        self._lru[s] = data
        while len(self._lru) > self.cache_shards:
            self._lru.popitem(last=False)
        return data

    def in_edges(self, node: int) -> np.ndarray:
        s = self._shard_of(node)
        shard = self._shard(s)
        local = node - int(self.node_ptr[s])
        return shard["src"][shard["indptr"][local]:shard["indptr"][local + 1]]

    def in_degree(self, node: int) -> int:
        return int(self.in_edges(node).size)

    def gather_nodes(self, ids: np.ndarray) -> dict:
        ids = np.asarray(ids, np.int64)
        out = {"x": np.empty((ids.size, self.feat), np.float32),
               "labels": np.empty(ids.size, np.int32),
               "deg_inv_sqrt": np.empty(ids.size, np.float32)}
        shard_ids = np.searchsorted(self.node_ptr, ids, side="right") - 1
        for s in np.unique(shard_ids):
            rows = np.where(shard_ids == s)[0]
            shard = self._shard(int(s))
            local = ids[rows] - int(self.node_ptr[s])
            for k in out:
                out[k][rows] = shard[k][local]
        return out


# ---------------------------------------------------------------------------
# the sampler
# ---------------------------------------------------------------------------

class NeighborSampler:
    """Deterministic, seeded k-hop in-neighbor sampler (GraphSAGE fanouts).

    ``fanouts`` — one entry per hop; each is a per-node in-edge cap or
    ``None`` for the exact full neighborhood (``exact=True`` makes every
    hop exact — the parity-testing mode). ``batch_size`` seed nodes are
    drawn per step from ``seed_nodes`` (default: every node), without
    replacement within a batch, as a pure function of ``(seed, step)``.

    Every :meth:`sample` call yields a :class:`Subgraph` whose edges are
    destination-sorted and whose node data comes from the store — an
    empty in-neighborhood (isolated seed) yields a valid zero-edge
    subgraph, reusing the library's empty-edge guarantees end to end.
    """

    def __init__(self, store, fanouts: Sequence[Optional[int]] = (8, 4), *,
                 batch_size: int = 64, seed_nodes=None, exact: bool = False,
                 seed: int = 0, name: str = "sampled"):
        if isinstance(store, Graph):
            store = InMemoryStore(store)
        if not fanouts:
            raise ValueError("fanouts must name at least one hop")
        for f in fanouts:
            if f is not None and f < 1:
                raise ValueError(f"fanout must be >= 1 or None, got {f}")
        self.store = store
        self.fanouts = tuple(None if (exact or f is None) else int(f)
                             for f in fanouts)
        self.exact = bool(exact) or all(f is None for f in self.fanouts)
        self.seed = int(seed)
        self.name = name
        if seed_nodes is None:
            seed_nodes = np.arange(store.num_nodes, dtype=np.int64)
        self.seed_nodes = np.asarray(seed_nodes, np.int64)
        if self.seed_nodes.size == 0:
            raise ValueError("seed_nodes must be non-empty")
        self.batch_size = min(int(batch_size), self.seed_nodes.size)
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")

    def __len__(self) -> int:
        """Steps per epoch — distinct batches before seed reuse levels."""
        return max(self.seed_nodes.size // self.batch_size, 1)

    # -- seed selection -----------------------------------------------------
    def seeds_for(self, step: int) -> np.ndarray:
        """The step's seed nodes: a ``batch_size`` slice of a per-epoch
        permutation of ``seed_nodes`` — every epoch covers every seed
        node once (up to the tail), and the slice is a pure function of
        ``(seed, step)``."""
        epoch, k = divmod(int(step), len(self))
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0x5eed, epoch]))
        perm = rng.permutation(self.seed_nodes.size)
        return self.seed_nodes[perm[k * self.batch_size:
                                    (k + 1) * self.batch_size]]

    # -- the sampler core ---------------------------------------------------
    def sample(self, seeds, step: int = 0) -> Subgraph:
        """k-hop subgraph around explicit ``seeds`` (global ids, unique).

        ``step`` only keys the fanout RNG (ignored in exact mode); the
        expansion itself is fully deterministic."""
        seeds = np.asarray(seeds, np.int64)
        if seeds.size != np.unique(seeds).size:
            raise ValueError("seeds must be unique within a batch")
        if seeds.size and (seeds.min() < 0
                           or seeds.max() >= self.store.num_nodes):
            raise ValueError("seed id out of range")
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 1, int(step)]))

        node_ids = list(seeds)
        local = {int(n): i for i, n in enumerate(seeds)}
        e_src: list = []             # local src per edge
        e_dst: list = []             # local dst per edge (non-decreasing)
        frontier = list(seeds)
        for fanout in self.fanouts:
            next_frontier = []
            # frontier nodes are expanded in ascending local-id order and
            # every new node gets an id past all previously expanded ones,
            # so the appended (dst-contiguous) edges keep edge_index[1]
            # sorted non-decreasing — CSR order by construction
            for d in frontier:
                srcs = self.store.in_edges(int(d))
                if fanout is not None and srcs.size > fanout:
                    srcs = srcs[np.sort(rng.choice(srcs.size, fanout,
                                                   replace=False))]
                dl = local[int(d)]
                for s in srcs:
                    si = int(s)
                    sl = local.get(si)
                    if sl is None:
                        sl = local[si] = len(node_ids)
                        node_ids.append(si)
                        next_frontier.append(si)
                    e_src.append(sl)
                    e_dst.append(dl)
            frontier = next_frontier
        node_ids = np.asarray(node_ids, np.int64)
        edge_index = np.stack([
            np.asarray(e_src, np.int32) if e_src else np.zeros(0, np.int32),
            np.asarray(e_dst, np.int32) if e_dst else np.zeros(0, np.int32)])
        if edge_index[1].size and np.any(np.diff(edge_index[1]) < 0):
            raise AssertionError(
                "sampler invariant violated: destinations not sorted")
        data = self.store.gather_nodes(node_ids)
        return Subgraph(
            name=f"{self.name}-step{step}",
            edge_index=edge_index,
            num_nodes=int(node_ids.size),
            x=np.ascontiguousarray(data["x"], dtype=np.float32),
            labels=np.ascontiguousarray(data["labels"], dtype=np.int32),
            # the PARENT graph's normalizer: GCN's D^{-1/2} is a property
            # of the full graph — recomputing it from sampled degrees
            # would break exact-neighborhood parity
            deg_inv_sqrt=np.ascontiguousarray(data["deg_inv_sqrt"],
                                              dtype=np.float32),
            node_ids=node_ids,
            num_seeds=int(seeds.size),
        )

    def sample_batch(self, step: int) -> Subgraph:
        """One training batch: :meth:`seeds_for` then :meth:`sample` —
        the deterministic ``step -> Subgraph`` function the pipeline's
        producer threads evaluate ahead of the consumer."""
        return self.sample(self.seeds_for(step), step=step)

    # -- sizing helpers -----------------------------------------------------
    def max_sampled_shape(self) -> Tuple[int, int]:
        """A worst-case (V_sub, E_sub) bound for this sampler's batches —
        what a bucket-warmup ladder should cover. Exact-mode bounds use
        the full graph sizes (a k-hop ball can be the whole graph)."""
        if any(f is None for f in self.fanouts):
            return int(self.store.num_nodes), int(self.store.num_edges)
        v = e = self.batch_size
        width = self.batch_size
        for f in self.fanouts:
            new = width * f
            e = e + new if e != self.batch_size else new
            v += new
            width = new
        e = sum(self.batch_size * int(np.prod(self.fanouts[:h + 1]))
                for h in range(len(self.fanouts)))
        return min(v, self.store.num_nodes), min(e, self.store.num_edges)
