"""Async host→device mini-batch pipeline over the neighbor sampler —
the back half of the out-of-core path (``docs/sampling.md``).

The per-step host work of sampled training is substantial: k-hop
expansion, bucket padding, a plan stamp, and the host→device copy. Run
synchronously, all of it sits on the critical path between device steps.
This module moves it off:

  :class:`SampledBatchProducer`
      the **pure host function** ``step -> SampledBatch``: sample (via
      :class:`~repro.data.sampling.NeighborSampler`), pad onto the
      serving bucket ladder (:func:`~repro.serve.buckets.pad_to_bucket`),
      resolve the bucket's canonical :class:`~repro.serve.plan_cache.
      BucketEntry` from a (thread-safe) :class:`~repro.serve.plan_cache.
      PlanCache`, stamp the per-batch plan leaves, and ``jax.device_put``
      the arrays. Because the plan's static aux is the bucket entry's,
      every batch of a bucket shares one treedef — the consumer's jitted
      step compiles **once per bucket**, never per batch.

  :class:`PrefetchPipeline`
      bounded-depth double buffering: while the consumer runs step ``t``,
      a small thread pool produces steps ``t+1 .. t+depth`` so the next
      batch's arrays are already on device when the consumer asks.
      ``depth=0`` degrades to the synchronous blocking loader (the
      baseline the benchmarks compare against). Wait-time counters make
      the overlap *measurable*: ``stats()["overlap"]`` is the fraction of
      host production hidden behind device compute.

Determinism is load-bearing, not best-effort: a batch is a pure function
of ``(sampler.seed, step)`` — producer threads only decide *when* a batch
is materialized, never *what* it contains — so any prefetch depth, thread
count, or scheduling order yields the bit-identical batch stream, and
checkpoint replay (:mod:`repro.train`) remains exact through the async
path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.core.plan import SegmentPlan
from repro.data.graphs import Graph
from repro.data.sampling import NeighborSampler
from repro.obs import span
from repro.serve.buckets import BucketPolicy, ShapeBucket, pad_to_bucket
from repro.serve.plan_cache import (BucketEntry, PlanCache, bucket_max_chunks,
                                    measured_config)

__all__ = ["SampledBatch", "SampledBatchProducer", "PrefetchPipeline"]


@dataclasses.dataclass
class SampledBatch:
    """One device-ready mini-batch: the padded host graph plus everything
    a jitted step consumes — device arrays and the bucket-canonical plan.

    ``arrays`` holds ``x`` (V_bucket, F), ``edge_index`` (2, E_bucket),
    ``deg_inv_sqrt`` (V_bucket,), ``labels`` (V_bucket,) and
    ``label_mask`` (V_bucket,) float32 — 1.0 exactly on the seed rows,
    the rows a loss may read (sampled neighbors have truncated
    neighborhoods; training on their logits would inject fanout bias).
    """
    step: int
    graph: Graph                  # padded, host-side (parity / unpad use)
    bucket: ShapeBucket
    num_seeds: int
    seed_nodes: np.ndarray        # (num_seeds,) global ids
    plan: SegmentPlan             # bucket-static aux, per-batch leaves
    arrays: Dict[str, jax.Array]
    produce_s: float = 0.0        # host time to materialize this batch
    wait_s: float = 0.0           # consumer time blocked on this batch


class SampledBatchProducer:
    """The deterministic ``step -> SampledBatch`` host function.

    Plan canonicalization is delegated to a :class:`PlanCache` keyed and
    built exactly like a serving engine's — pass ``entry_key`` /
    ``entry_builder`` (e.g. :meth:`GNNServer.sampled_pipeline` passes its
    own) to *share* cache lines with an engine, or let the defaults build
    engine-equivalent entries standalone. ``feat`` is the plan's
    representative feature width (the model's widest layer, same
    convention as ``make_model_plan``)."""

    def __init__(self, sampler: NeighborSampler, *,
                 feat: int = 128,
                 policy: Optional[BucketPolicy] = None,
                 cache: Optional[PlanCache] = None,
                 entry_key: Optional[Callable[[ShapeBucket], object]] = None,
                 entry_builder: Optional[
                     Callable[[ShapeBucket], BucketEntry]] = None,
                 device=None,
                 perfdb=None):
        self.sampler = sampler
        self.feat = int(feat)
        self.policy = policy or BucketPolicy()
        self.cache = cache if cache is not None else PlanCache()
        self._entry_key = entry_key or (
            lambda b: (b, self.feat, "sampled", "plan", 0))
        self._entry_builder = entry_builder or self._default_entry
        self._device = device
        self._perfdb = perfdb

    def _default_entry(self, bucket: ShapeBucket) -> BucketEntry:
        """Engine-equivalent cache line: measured PerfDB winner when one
        exists (pure lookup — producer threads never sweep), else the
        decision-tree rules; worst-case bucket-static ``max_chunks``."""
        config = measured_config(bucket, self.feat, db=self._perfdb)
        if config is None:
            from repro.core.heuristics import select_config
            config = select_config(
                max(bucket.num_edges, 1),
                max(min(bucket.num_edges, bucket.num_nodes), 1),
                self.feat, tune=False)
        return BucketEntry(bucket, self.feat, config,
                           max_chunks=bucket_max_chunks(bucket, config))

    def entry_for(self, bucket: ShapeBucket) -> BucketEntry:
        return self.cache.get_or_build(
            self._entry_key(bucket),
            lambda: self._entry_builder(bucket))

    def buckets_for_warmup(self, probe_steps: int = 8) -> list:
        """The distinct buckets the first ``probe_steps`` batches touch —
        sampling is deterministic, so probing IS the schedule (host-only:
        nothing is padded or moved to device)."""
        seen = []
        for s in range(probe_steps):
            sub = self.sampler.sample_batch(s)
            from repro.serve.buckets import bucket_for
            b = bucket_for(sub.num_nodes, sub.num_edges, self.policy)
            if b not in seen:
                seen.append(b)
                obs.record_probe("pipeline.warmup_probe", str(b), step=s)
        return seen

    def produce(self, step: int) -> SampledBatch:
        """Materialize one batch. Pure in ``step``; safe from any thread
        (the cache is locked, JAX transfers are thread-safe; spans use a
        per-thread context, so producer-thread trees never interleave)."""
        with span("pipeline.produce", step=int(step)) as root:
            t0 = time.perf_counter()
            with span("pipeline.sample", step=int(step)):
                sub = self.sampler.sample_batch(step)
            with span("pipeline.pad"):
                padded, bucket = pad_to_bucket(sub, self.policy)
            root.set(bucket=str(bucket))
            with span("pipeline.plan_cache", bucket=str(bucket)):
                entry = self.entry_for(bucket)
            with span("pipeline.stamp"):
                plan = entry.stamp(padded.edge_index[1])
            mask = (np.arange(bucket.num_nodes) < sub.num_seeds
                    ).astype(np.float32)
            put = (lambda a: jax.device_put(a, self._device)) \
                if self._device else jax.device_put
            with span("pipeline.device_put"):
                arrays = {
                    "x": put(padded.x),
                    "edge_index": put(padded.edge_index),
                    "deg_inv_sqrt": put(padded.deg_inv_sqrt),
                    "labels": put(padded.labels),
                    "label_mask": put(mask),
                }
            return SampledBatch(
                step=int(step), graph=padded, bucket=bucket,
                num_seeds=sub.num_seeds, seed_nodes=sub.seed_nodes,
                plan=plan, arrays=arrays,
                produce_s=time.perf_counter() - t0)


class PrefetchPipeline:
    """Bounded-depth async prefetch over a ``step -> SampledBatch``
    producer.

    ``batch(step)`` returns the batch for ``step`` and keeps the window
    ``step+1 .. step+depth`` in flight on the pool. Sequential
    consumption (the training loop) therefore finds its next batch
    already produced — host sampling/padding/planning and the H2D copy
    overlap the consumer's device step. Out-of-window or backward jumps
    are produced synchronously (determinism makes that merely slow, never
    wrong). ``depth=0`` is the blocking loader: every batch is produced
    inline, which is the baseline ``stats()['overlap']`` measures against.

    Always :meth:`close` (or use as a context manager) — the pool's
    threads are non-daemon."""

    def __init__(self, producer, depth: int = 2,
                 num_threads: Optional[int] = None):
        if depth < 0:
            raise ValueError("depth must be >= 0")
        produce = producer.produce if hasattr(producer, "produce") \
            else producer
        self._produce = produce
        self.depth = int(depth)
        self.num_threads = max(1, int(num_threads if num_threads is not None
                                      else min(self.depth or 1, 4)))
        self._pool: Optional[ThreadPoolExecutor] = None
        if self.depth > 0:
            self._pool = ThreadPoolExecutor(
                max_workers=self.num_threads,
                thread_name_prefix="repro-prefetch")
        self._pending: Dict[int, Future] = {}
        self._lock = threading.Lock()
        self._closed = False
        # accounting (consumer-thread writes) — registry-backed under this
        # pipeline's instance label; vital so stats() works with
        # observability disabled
        reg = obs.get_registry()
        self._labels = {"pipeline": obs.next_id("pipeline")}
        self._m_batches = reg.counter("pipeline.batches", ("pipeline",),
                                      vital=True)
        self._m_sync_falls = reg.counter("pipeline.sync_falls",
                                         ("pipeline",), vital=True)
        self._m_wait = reg.histogram("pipeline.wait_s", ("pipeline",),
                                     vital=True)
        self._m_produce = reg.histogram("pipeline.produce_s", ("pipeline",),
                                        vital=True)
        for m in (self._m_batches, self._m_sync_falls, self._m_wait,
                  self._m_produce):
            m.touch(**self._labels)

    # registry-backed views of the original counter attributes
    @property
    def batches(self) -> int:
        return int(self._m_batches.value(**self._labels))

    @property
    def sync_falls(self) -> int:
        return int(self._m_sync_falls.value(**self._labels))

    @property
    def wait_s(self) -> float:
        return self._m_wait.total(**self._labels)

    @property
    def produce_s(self) -> float:
        return self._m_produce.total(**self._labels)

    # -- scheduling ----------------------------------------------------------
    def _schedule(self, step: int) -> None:
        with self._lock:
            if self._closed or step in self._pending:
                return
            self._pending[step] = self._pool.submit(self._produce, step)

    def batch(self, step: int) -> SampledBatch:
        """The batch for ``step`` (bit-identical at any depth)."""
        step = int(step)
        if self._closed:
            raise RuntimeError("pipeline is closed")
        t0 = time.perf_counter()
        if self._pool is None:
            b = self._produce(step)
            b.wait_s = time.perf_counter() - t0
        else:
            with self._lock:
                fut = self._pending.pop(step, None)
            if fut is None:
                # cold start or random access: produce here, synchronously
                self._m_sync_falls.inc(**self._labels)
                b = self._produce(step)
            else:
                b = fut.result()
            b.wait_s = time.perf_counter() - t0
            for ahead in range(step + 1, step + 1 + self.depth):
                self._schedule(ahead)
        self._m_batches.inc(**self._labels)
        self._m_wait.observe(b.wait_s, **self._labels)
        self._m_produce.observe(b.produce_s, **self._labels)
        return b

    # -- accounting ----------------------------------------------------------
    def stats(self) -> Dict:
        """Overlap accounting. ``overlap`` = fraction of host production
        hidden from the consumer (0 for the blocking loader by
        construction). ``*_steady`` medians drop the first batch — the
        cold start pays compiles and cache misses that say nothing about
        steady-state overlap."""
        wait_hist = self._m_wait.samples(**self._labels)
        produce_hist = self._m_produce.samples(**self._labels)
        wait = np.asarray(wait_hist[1:] or wait_hist or [0.0])
        prod = np.asarray(produce_hist[1:] or produce_hist or [0.0])
        return {
            "depth": self.depth,
            "num_threads": self.num_threads,
            "batches": self.batches,
            "sync_falls": self.sync_falls,
            "wait_s": self.wait_s,
            "produce_s": self.produce_s,
            "overlap": (1.0 - self.wait_s / self.produce_s
                        if self.produce_s > 0 else 0.0),
            "wait_s_median_steady": float(np.median(wait)),
            "produce_s_median_steady": float(np.median(prod)),
        }

    def close(self) -> None:
        """Shut the pool down; idempotent. In-flight futures are awaited
        (they hold no external resources beyond device buffers)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pending = list(self._pending.values())
            self._pending.clear()
        for fut in pending:
            fut.cancel()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "PrefetchPipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # backstop; close() is the contract
        try:
            self.close()
        except Exception:
            pass
