"""Partitioned graphs for sharded message passing (the GraphTensor-style
partition-aware path; see ``docs/distributed_mp.md``).

:func:`partition_graph` splits a :class:`~repro.data.graphs.Graph` into
``num_shards`` pieces for a 1-D device mesh:

  * **nodes** — one contiguous range per shard (``node_ptr``), with the
    boundaries placed by *out-degree* balance so each shard owns roughly
    ``|E| / num_shards`` edges even on power-law graphs;
  * **edges** — every edge lives on the shard that owns its **source**
    node, so the gather side of message passing reads only shard-local
    features (no feature all-gather). Each shard's edge list keeps the
    global dst-sorted order (a subsequence of a sorted list is sorted), is
    padded to the common length ``edges_per_shard``, and carries
    *remapped* indices: ``src_local`` relative to the shard's node block,
    ``dst_global`` in the global segment space. Padding slots use the
    kernels' own drop convention — ``dst = num_nodes`` rows fall outside
    every output window;
  * **halo** — a *cut* edge is one whose destination is owned by another
    shard: its contribution is a partial aggregate that the merge step of
    :mod:`repro.core.dist_mp` combines across shards (psum / pmax /
    softmax stat-merge). :class:`HaloInfo` records how many such edges and
    distinct remote destinations each shard produces.

The result is a registered pytree (device-array leaves, static aux), so a
:class:`PartitionedGraph` threads through ``jax.jit`` closures and
``shard_map`` without retriggering compilation. Round-trips are exact:
``unpartition_nodes(pg, pg.shard_nodes(x)) == x`` and likewise for edges.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import Graph

__all__ = ["HaloInfo", "PartitionedGraph", "partition_graph",
           "unpartition_nodes", "unpartition_edges"]


@dataclasses.dataclass(frozen=True)
class HaloInfo:
    """Cut-edge metadata of a partition (static, per shard)."""
    cut_edges: Tuple[int, ...]       # edges whose dst is owned elsewhere
    halo_nodes: Tuple[int, ...]      # distinct remote destinations per shard
    total_cut: int
    total_edges: int

    @property
    def cut_fraction(self) -> float:
        return self.total_cut / self.total_edges if self.total_edges else 0.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """A graph split into ``num_shards`` stacked per-shard pieces.

    Leaves are stacked ``(num_shards, ...)`` device arrays that ride
    ``shard_map`` with ``PartitionSpec("shard")``; everything else is
    static aux data.
    """
    # -- leaves (stacked per shard) -----------------------------------------
    src_local: jax.Array    # (S, E_pad) int32: src - node_ptr[s]; pad -> 0
    dst_global: jax.Array   # (S, E_pad) int32: global dst, sorted; pad -> V
    edge_valid: jax.Array   # (S, E_pad) bool: False on padding slots
    edge_gather: jax.Array  # (S, E_pad) int32: global edge slot; pad -> 0
    node_gather: jax.Array  # (S, V_pad) int32: global node row; pad -> 0
    node_valid: jax.Array   # (S, V_pad) bool
    deg: jax.Array          # (V,) float32 global in-degree — the mean
    #                         merge's psum of per-shard counts, evaluated
    #                         once here (it is static partition metadata)
    # -- static aux ---------------------------------------------------------
    num_shards: int
    num_nodes: int           # V (global)
    num_edges: int           # E (global, unpadded)
    nodes_per_shard: int     # V_pad = max shard node-range size
    edges_per_shard: int     # E_pad = max shard edge count
    node_ptr: Tuple[int, ...]   # (S+1,) contiguous node partition
    halo: HaloInfo

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.src_local, self.dst_global, self.edge_valid,
                    self.edge_gather, self.node_gather, self.node_valid,
                    self.deg)
        aux = (self.num_shards, self.num_nodes, self.num_edges,
               self.nodes_per_shard, self.edges_per_shard, self.node_ptr,
               self.halo)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- shard/unshard helpers ----------------------------------------------
    def shard_nodes(self, x):
        """(V, ...) global node values -> (S, V_pad, ...) stacked local
        blocks (padding rows repeat row 0; they are never read by a valid
        ``src_local``)."""
        return jnp.take(jnp.asarray(x), self.node_gather.reshape(-1),
                        axis=0).reshape(self.num_shards, self.nodes_per_shard,
                                        *np.shape(x)[1:])

    def shard_edges(self, vals):
        """(E, ...) per-edge values (global dst-sorted order) ->
        (S, E_pad, ...) stacked, with padding slots zeroed."""
        vals = jnp.asarray(vals)
        out = jnp.take(vals, self.edge_gather.reshape(-1), axis=0).reshape(
            self.num_shards, self.edges_per_shard, *vals.shape[1:])
        mask = self.edge_valid.reshape(self.num_shards, self.edges_per_shard,
                                       *([1] * (vals.ndim - 1)))
        return jnp.where(mask, out, jnp.zeros((), out.dtype))

    def make_plan(self, feat: Optional[int] = None, config=None,
                  tune: Optional[bool] = None):
        """One :class:`~repro.core.plan.PartitionedPlan` (stacked per-shard
        chunk metadata + a shared config/grid bound) for this partition.

        Host-side, like every plan builder: call it outside ``jit`` (once
        per partition) and pass the result through ``pplan=``/``plan=``."""
        if isinstance(self.dst_global, jax.core.Tracer):
            raise ValueError(
                "PartitionedPlan must be built outside jit (the chunk "
                "metadata is evaluated on the host); build it once with "
                "partition.make_plan(...) and pass it via pplan=/plan=")
        from repro.core.plan import make_partitioned_plan
        return make_partitioned_plan(self, feat=128 if feat is None else feat,
                                     config=config, tune=tune)


def _node_boundaries(outdeg: np.ndarray, num_shards: int) -> np.ndarray:
    """Contiguous node boundaries balanced by out-degree (edge ownership)."""
    v = outdeg.size
    cum = np.concatenate([[0], np.cumsum(outdeg, dtype=np.int64)])
    total = int(cum[-1])
    if total == 0:
        # no edges: plain node-count split
        bounds = np.linspace(0, v, num_shards + 1).round().astype(np.int64)
    else:
        targets = total * np.arange(1, num_shards) / num_shards
        inner = np.searchsorted(cum, targets, side="left")
        bounds = np.concatenate([[0], inner, [v]]).astype(np.int64)
    # monotone + in range even on degenerate degree distributions
    bounds = np.maximum.accumulate(np.clip(bounds, 0, v))
    bounds[0], bounds[-1] = 0, v
    return bounds


def partition_graph(graph: Graph, num_shards: int) -> PartitionedGraph:
    """Contiguous 1-D node partition + source-owned edge shards (see module
    docstring). ``num_shards == 1`` is the identity partition (one shard,
    no padding, no cut edges)."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    v, e = graph.num_nodes, graph.num_edges
    if num_shards > max(v, 1):
        raise ValueError(
            f"num_shards={num_shards} exceeds num_nodes={v}")
    src = np.asarray(graph.edge_index[0], np.int64)
    dst = np.asarray(graph.edge_index[1], np.int64)
    # the per-shard kernels and stat merges assume dst-sorted edge lists
    # (subsequences of a sorted list); fail loudly like make_plan does
    # instead of silently mis-aggregating
    if e and np.any(dst[1:] < dst[:-1]):
        raise ValueError("edge_index[1] (destinations) must be sorted "
                         "non-decreasing to partition the graph")

    outdeg = np.bincount(src, minlength=v) if e else np.zeros(v, np.int64)
    node_ptr = _node_boundaries(outdeg, num_shards)

    # shard of each edge = owner of its source node
    shard_of = (np.searchsorted(node_ptr, src, side="right") - 1 if e
                else np.zeros(0, np.int64))
    counts = np.bincount(shard_of, minlength=num_shards).astype(np.int64)
    e_pad = int(counts.max()) if e else 0
    v_pad = int(np.diff(node_ptr).max()) if v else 0

    src_local = np.zeros((num_shards, e_pad), np.int32)
    dst_global = np.full((num_shards, e_pad), v, np.int32)
    edge_valid = np.zeros((num_shards, e_pad), bool)
    edge_gather = np.zeros((num_shards, e_pad), np.int32)
    node_gather = np.zeros((num_shards, v_pad), np.int32)
    node_valid = np.zeros((num_shards, v_pad), bool)
    cut_edges, halo_nodes = [], []
    for s in range(num_shards):
        lo, hi = int(node_ptr[s]), int(node_ptr[s + 1])
        vs = hi - lo
        node_gather[s, :vs] = np.arange(lo, hi)
        node_valid[s, :vs] = True
        # original order is preserved, so each shard's dst stays sorted
        rows = np.flatnonzero(shard_of == s)
        n = rows.size
        src_local[s, :n] = (src[rows] - lo).astype(np.int32)
        dst_global[s, :n] = dst[rows].astype(np.int32)
        edge_valid[s, :n] = True
        edge_gather[s, :n] = rows.astype(np.int32)
        remote = (dst[rows] < lo) | (dst[rows] >= hi)
        cut_edges.append(int(remote.sum()))
        halo_nodes.append(int(np.unique(dst[rows][remote]).size))

    halo = HaloInfo(cut_edges=tuple(cut_edges), halo_nodes=tuple(halo_nodes),
                    total_cut=int(sum(cut_edges)), total_edges=e)
    return PartitionedGraph(
        src_local=jnp.asarray(src_local),
        dst_global=jnp.asarray(dst_global),
        edge_valid=jnp.asarray(edge_valid),
        edge_gather=jnp.asarray(edge_gather),
        node_gather=jnp.asarray(node_gather),
        node_valid=jnp.asarray(node_valid),
        deg=jnp.asarray((np.bincount(dst, minlength=v) if e
                         else np.zeros(v)).astype(np.float32)),
        num_shards=num_shards,
        num_nodes=v,
        num_edges=e,
        nodes_per_shard=v_pad,
        edges_per_shard=e_pad,
        node_ptr=tuple(int(b) for b in node_ptr),
        halo=halo,
    )


def unpartition_nodes(pg: PartitionedGraph, stacked):
    """Inverse of :meth:`PartitionedGraph.shard_nodes`: scatter stacked
    (S, V_pad, ...) local node blocks back to global (V, ...) order."""
    stacked = jnp.asarray(stacked)
    flat = stacked.reshape(pg.num_shards * pg.nodes_per_shard,
                           *stacked.shape[2:])
    out = jnp.zeros((pg.num_nodes, *stacked.shape[2:]), stacked.dtype)
    idx = jnp.where(pg.node_valid, pg.node_gather, pg.num_nodes).reshape(-1)
    # out-of-range scatter slots (padding) are dropped
    return out.at[idx].set(flat, mode="drop")


def unpartition_edges(pg: PartitionedGraph, stacked):
    """Inverse of :meth:`PartitionedGraph.shard_edges`: scatter stacked
    (S, E_pad, ...) per-edge values back to global (E, ...) order."""
    stacked = jnp.asarray(stacked)
    flat = stacked.reshape(pg.num_shards * pg.edges_per_shard,
                           *stacked.shape[2:])
    out = jnp.zeros((pg.num_edges, *stacked.shape[2:]), stacked.dtype)
    idx = jnp.where(pg.edge_valid, pg.edge_gather, pg.num_edges).reshape(-1)
    return out.at[idx].set(flat, mode="drop")
