"""Deterministic synthetic LM data pipeline.

Batches are a *learnable* synthetic language (a fixed random first-order
Markov chain over the vocab with Zipfian marginals), so a few hundred
training steps show a real loss decrease (examples/train_lm.py).

Sharded iteration: each host materialises only its slice of the global
batch (``host_id``/``num_hosts``), deterministically from (seed, step) —
restart-safe without data-loader state in checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDatasetConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4          # candidate successors per token (learnability)


class SyntheticTokens:
    def __init__(self, cfg: TokenDatasetConfig, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed Markov structure: each token has `branching` successors with
        # Zipfian transition probabilities
        self._succ = rng.integers(0, v, size=(v, cfg.branching), dtype=np.int64)
        p = 1.0 / np.arange(1, cfg.branching + 1)
        self._probs = p / p.sum()
        zipf = 1.0 / np.arange(1, v + 1)
        self._init_probs = zipf / zipf.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (this host's shard)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id, 0xD00D))
        b, s = self.local_batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self._init_probs)
        choice = rng.choice(cfg.branching, size=(b, s), p=self._probs)
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choice[:, t]]
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
