"""Synthetic graph generator matching the paper's evaluation datasets
(Table II stats), for benchmarks and GNN examples.

Degree distributions are power-law (configurable skew) — the realistic
regime for segment-reduction load imbalance.  Edges come out sorted by
destination (``edge_index[1]`` non-decreasing), the PyG convention GeoT
relies on (paper §IV).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.perfdb import TABLE_II


@dataclasses.dataclass(frozen=True)
class Graph:
    name: str
    edge_index: np.ndarray        # (2, E) int32, [1] sorted non-decreasing
    num_nodes: int
    x: np.ndarray                 # (V, F) float32
    labels: np.ndarray            # (V,) int32
    deg_inv_sqrt: np.ndarray      # (V,) float32
    # block-diagonal batch bookkeeping (batch_graphs); None for single graphs
    node_ptr: Optional[np.ndarray] = None    # (G+1,) node offsets per graph
    edge_ptr: Optional[np.ndarray] = None    # (G+1,) edge offsets per graph
    # pad_graph bookkeeping: the real (pre-padding) sizes, or None when the
    # graph has never been padded. Padded nodes are isolated (no incident
    # real edges); padded edges carry dst = num_nodes — the kernels' drop id
    orig_num_nodes: Optional[int] = None
    orig_num_edges: Optional[int] = None
    # per-instance plan memo (see make_plan); excluded from init/eq/repr —
    # init=False so dataclasses.replace() starts a fresh memo instead of
    # aliasing the source graph's (replaced edges must not hit stale plans)
    _plan_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                          compare=False, init=False)

    @property
    def num_edges(self) -> int:
        return self.edge_index.shape[1]

    @property
    def num_graphs(self) -> int:
        return 1 if self.node_ptr is None else len(self.node_ptr) - 1

    def make_plan(self, feat: Optional[int] = None, config=None,
                  tune: Optional[bool] = None):
        """Precompute the reduction schedule for this graph (built once,
        reused across layers / steps — see :mod:`repro.core.plan`).
        ``tune=True`` picks the config from a measured autotuner sweep.

        Memoized per ``(feat, config, tune)``: a model calling
        ``g.make_plan`` every layer (or every training step) pays the chunk
        metadata + config selection once. The graph is frozen, so the memo
        only goes stale if the arrays are mutated in place — call
        :meth:`invalidate_plan_cache` after any such surgery."""
        feat = self.x.shape[1] if feat is None else feat
        key = (int(feat), config, tune)
        plan = self._plan_cache.get(key)
        if plan is None:
            from repro.core.plan import make_graph_plan
            plan = make_graph_plan(self.edge_index, self.num_nodes, feat=feat,
                                   config=config, tune=tune)
            self._plan_cache[key] = plan
        return plan

    def invalidate_plan_cache(self) -> None:
        """Drop memoized plans (after in-place edge/feature surgery)."""
        self._plan_cache.clear()

    def partition(self, num_shards: int):
        """Split into ``num_shards`` for sharded message passing (see
        :mod:`repro.data.partition` / :mod:`repro.core.dist_mp`)."""
        from repro.data.partition import partition_graph
        return partition_graph(self, num_shards)


@dataclasses.dataclass(frozen=True)
class TypedGraph(Graph):
    """A :class:`Graph` whose edges carry relation types (heterogeneous /
    relational GNNs — RGCN, relational GAT).

    Layout contract: ``edge_index`` stays **destination-sorted** (the plan
    /kernels' requirement, unchanged from Graph) and ``edge_type`` is
    aligned with those dst-sorted edges. The grouped ``segment_matmul``
    instead needs rows contiguous per relation, so construction
    precomputes the reconciling permutation triple once:

      * ``type_perm`` — stable argsort of ``edge_type``; because it is
        stable, edges come out in (type, dst) lexicographic order and
        each relation's rows form one contiguous group;
      * ``inv_type_perm`` — its inverse, fused into the reduce's gather
        operand by :func:`repro.core.mp.mp_typed` (the un-permute costs
        no extra launch);
      * ``type_counts`` — rows per relation (the grouped matmul's
        ``group_sizes``; zeros for unused relations are fine).

    Construction validates the layout and round-trips the permutation
    (``type_perm[inv_type_perm] == arange``), mirroring ``make_plan``'s
    sortedness checks, so a malformed typed graph fails loudly at build
    time rather than silently misrouting messages."""
    edge_type: Optional[np.ndarray] = None       # (E,) int32, dst-aligned
    num_relations: int = 1
    type_perm: Optional[np.ndarray] = None       # derived; see __post_init__
    inv_type_perm: Optional[np.ndarray] = None
    type_counts: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.edge_type is None:
            raise ValueError("TypedGraph requires edge_type")
        et = np.asarray(self.edge_type, np.int32)
        if et.shape != (self.num_edges,):
            raise ValueError(
                f"edge_type shape {et.shape} != (num_edges={self.num_edges},)")
        if et.size and (et.min() < 0 or et.max() >= self.num_relations):
            raise ValueError(
                f"edge_type ids must lie in [0, {self.num_relations}); "
                f"got range [{et.min()}, {et.max()}]")
        if np.any(np.diff(self.edge_index[1]) < 0):
            raise ValueError("edge_index[1] (destinations) must be sorted "
                             "non-decreasing")
        object.__setattr__(self, "edge_type", et)
        if self.type_perm is None:
            perm = np.argsort(et, kind="stable").astype(np.int32)
            inv = np.empty_like(perm)
            inv[perm] = np.arange(perm.size, dtype=np.int32)
            counts = np.bincount(et, minlength=self.num_relations)
            object.__setattr__(self, "type_perm", perm)
            object.__setattr__(self, "inv_type_perm", inv)
            object.__setattr__(self, "type_counts",
                               counts.astype(np.int32))
        # round-trip validation: the permutation must be a bijection whose
        # image is type-sorted with the advertised group sizes
        perm, inv, counts = self.type_perm, self.inv_type_perm, self.type_counts
        if not np.array_equal(perm[inv], np.arange(perm.size)):
            raise ValueError("type_perm/inv_type_perm do not round-trip")
        pt = et[perm]
        if np.any(np.diff(pt) < 0):
            raise ValueError("type_perm does not sort edge_type")
        if int(counts.sum()) != et.size or not np.array_equal(
                counts, np.bincount(et, minlength=self.num_relations)):
            raise ValueError("type_counts disagree with edge_type")

    @property
    def typed_src(self) -> np.ndarray:
        """Source ids in (type, dst) order — the grouped matmul's gather."""
        return self.edge_index[0][self.type_perm]

    def make_relation_plan(self, feat: Optional[int] = None, config=None,
                           tune: Optional[bool] = None):
        """Precompute the grouped-matmul schedule over the relation
        segments (memoized like :meth:`make_plan`; keyed separately so the
        reduce plan and the relation plan coexist in one cache)."""
        feat = self.x.shape[1] if feat is None else feat
        key = ("relation", int(feat), config, tune)
        plan = self._plan_cache.get(key)
        if plan is None:
            from repro.core.plan import make_relation_plan
            plan = make_relation_plan(self.type_counts,
                                      num_rows=self.num_edges, feat=feat,
                                      config=config, tune=tune)
            self._plan_cache[key] = plan
        return plan


def synth_typed_graph(name: str, num_nodes: int, num_edges: int,
                      num_relations: int = 4, feat: int = 32,
                      num_classes: int = 16, alpha: float = 1.3,
                      type_alpha: float = 1.2, seed: int = 0) -> TypedGraph:
    """A :func:`synth_graph` whose edges additionally carry zipf-skewed
    relation ids (``type_alpha`` controls the skew; large values leave
    most relations nearly empty — the imbalance regime the grouped kernel
    must mask correctly)."""
    g = synth_graph(name, num_nodes, num_edges, feat=feat,
                    num_classes=num_classes, alpha=alpha, seed=seed)
    rng = np.random.default_rng(seed + 1)
    if num_edges > 0:
        w = np.minimum(rng.zipf(type_alpha, size=num_relations)
                       .astype(np.float64), max(num_edges / 2.0, 1.0))
        et = rng.choice(num_relations, size=num_edges,
                        p=w / w.sum()).astype(np.int32)
    else:
        et = np.zeros(0, np.int32)
    return TypedGraph(
        name=g.name, edge_index=g.edge_index, num_nodes=g.num_nodes,
        x=g.x, labels=g.labels, deg_inv_sqrt=g.deg_inv_sqrt,
        edge_type=et, num_relations=num_relations)


def synth_graph(name: str, num_nodes: int, num_edges: int, feat: int = 32,
                num_classes: int = 16, alpha: float = 1.3,
                seed: int = 0) -> Graph:
    """Power-law in-degree graph with the given |V|, |E|."""
    rng = np.random.default_rng(seed)
    if num_edges > 0:
        w = rng.zipf(alpha, size=num_nodes).astype(np.float64)
        # cap at E/4 but never below 1 (zipf samples are >= 1): a cap of 0
        # would zero the whole weight vector and divide by 0 below
        w = np.minimum(w, max(num_edges / 4.0, 1.0))
        p = w / w.sum()
        dst = rng.choice(num_nodes, size=num_edges, p=p).astype(np.int32)
        dst.sort(kind="stable")
        src = rng.integers(0, num_nodes, size=num_edges, dtype=np.int32)
    else:
        # empty-edge graph (isolated nodes): a valid (2, 0) edge_index —
        # plans, mp, and the models must all keep working on it
        dst = np.zeros(0, np.int32)
        src = np.zeros(0, np.int32)
    deg = np.bincount(dst, minlength=num_nodes).astype(np.float32)
    return Graph(
        name=name,
        edge_index=np.stack([src, dst]),
        num_nodes=num_nodes,
        x=rng.standard_normal((num_nodes, feat), dtype=np.float32),
        labels=rng.integers(0, num_classes, num_nodes, dtype=np.int32),
        deg_inv_sqrt=(1.0 / np.sqrt(np.maximum(deg, 1.0))).astype(np.float32),
    )


def pad_graph(g: Graph, num_nodes: int, num_edges: int) -> Graph:
    """Pad ``g`` to exactly (``num_nodes``, ``num_edges``) without changing
    what any real node computes.

    Padded nodes are isolated (zero features, label 0, ``deg_inv_sqrt`` = 1
    — the zero-degree convention of :func:`synth_graph`); padded edges carry
    ``dst = num_nodes``, the drop id every kernel (and the jnp reference
    scatters) already uses for its own row padding, so they fall outside
    every output window and the aggregation over real nodes is bit-identical
    to the unpadded graph under the same kernel config. Because real
    destinations are < ``g.num_nodes`` <= ``num_nodes``, appending drop
    edges keeps ``edge_index[1]`` sorted.

    The real sizes are recorded in ``orig_num_nodes`` / ``orig_num_edges``
    (carried through repeated padding) for the round-trip helpers
    :func:`unpad_nodes` / :func:`unpad_edges` / :func:`unpad_graph`; batch
    pointers survive padding, so a padded batch still unbatches.
    """
    v0 = g.orig_num_nodes if g.orig_num_nodes is not None else g.num_nodes
    e0 = g.orig_num_edges if g.orig_num_edges is not None else g.num_edges
    if num_nodes < g.num_nodes or num_edges < g.num_edges:
        raise ValueError(
            f"pad_graph cannot shrink: graph is (V={g.num_nodes}, "
            f"E={g.num_edges}), target (V={num_nodes}, E={num_edges})")
    dv, de = num_nodes - g.num_nodes, num_edges - g.num_edges
    pad_edges = np.stack([np.zeros(de, np.int32),
                          np.full(de, num_nodes, np.int32)])
    return Graph(
        name=g.name,
        edge_index=np.concatenate([g.edge_index, pad_edges], axis=1),
        num_nodes=num_nodes,
        x=np.concatenate(
            [g.x, np.zeros((dv, g.x.shape[1]), g.x.dtype)], axis=0),
        labels=np.concatenate([g.labels, np.zeros(dv, g.labels.dtype)]),
        deg_inv_sqrt=np.concatenate(
            [g.deg_inv_sqrt, np.ones(dv, g.deg_inv_sqrt.dtype)]),
        node_ptr=g.node_ptr,
        edge_ptr=g.edge_ptr,
        orig_num_nodes=v0,
        orig_num_edges=e0,
    )


def unpad_nodes(padded: Graph, values):
    """Slice a (V_padded, ...) per-node array back to the real rows."""
    if padded.orig_num_nodes is None:
        return values
    return values[:padded.orig_num_nodes]


def unpad_edges(padded: Graph, values):
    """Slice an (E_padded, ...) per-edge array back to the real edges."""
    if padded.orig_num_edges is None:
        return values
    return values[:padded.orig_num_edges]


def unpad_graph(padded: Graph) -> Graph:
    """Exact inverse of :func:`pad_graph` (array-for-array)."""
    if padded.orig_num_nodes is None:
        return padded
    v0, e0 = padded.orig_num_nodes, padded.orig_num_edges
    return Graph(
        name=padded.name,
        edge_index=padded.edge_index[:, :e0],
        num_nodes=v0,
        x=padded.x[:v0],
        labels=padded.labels[:v0],
        deg_inv_sqrt=padded.deg_inv_sqrt[:v0],
        node_ptr=padded.node_ptr,
        edge_ptr=padded.edge_ptr,
    )


def batch_graphs(graphs: Sequence[Graph], name: Optional[str] = None) -> Graph:
    """Block-diagonal multi-graph batching (PyG ``Batch`` convention).

    Node ids of graph g are offset by ``sum(|V_0..g-1|)``; edges are
    concatenated in graph order. Because every member's ``edge_index[1]`` is
    sorted and the offsets are increasing, the batched destinations remain
    sorted — so one :class:`~repro.core.plan.SegmentPlan` built on the batch
    covers all member graphs at once, and a single fused segment-reduce call
    aggregates the whole batch (no per-graph loop, no padding)."""
    if not graphs:
        raise ValueError("batch_graphs needs at least one graph")
    if len(graphs) == 1 and graphs[0].node_ptr is None:
        # single-graph fast path: the block-diagonal of one graph IS the
        # graph — share its arrays (no concatenate copies) and carry over
        # its memoized plans (safe: the plan describes these same arrays),
        # so a serving loop batching [g] does not silently rebuild what
        # g.make_plan already paid for
        g = graphs[0]
        out = Graph(
            name=name or g.name,
            edge_index=g.edge_index,
            num_nodes=g.num_nodes,
            x=g.x,
            labels=g.labels,
            deg_inv_sqrt=g.deg_inv_sqrt,
            node_ptr=np.array([0, g.num_nodes], np.int64),
            edge_ptr=np.array([0, g.num_edges], np.int64),
            orig_num_nodes=g.orig_num_nodes,
            orig_num_edges=g.orig_num_edges,
        )
        out._plan_cache.update(g._plan_cache)
        return out
    if any(g.orig_num_nodes is not None for g in graphs):
        # a padded member's drop edges (dst = its padded V) would offset
        # onto the NEXT member's first node and aggregate into it — batch
        # first, pad the batch (the serving engine's order)
        raise ValueError("batch_graphs cannot batch padded graphs; "
                         "batch first, then pad_graph the batch")
    node_ptr = np.zeros(len(graphs) + 1, np.int64)
    edge_ptr = np.zeros(len(graphs) + 1, np.int64)
    for i, g in enumerate(graphs):
        node_ptr[i + 1] = node_ptr[i] + g.num_nodes
        edge_ptr[i + 1] = edge_ptr[i] + g.num_edges
    edge_index = np.concatenate(
        [g.edge_index.astype(np.int64) + node_ptr[i]
         for i, g in enumerate(graphs)], axis=1).astype(np.int32)
    return Graph(
        name=name or "batch(" + "+".join(g.name for g in graphs) + ")",
        edge_index=edge_index,
        num_nodes=int(node_ptr[-1]),
        x=np.concatenate([g.x for g in graphs], axis=0),
        labels=np.concatenate([g.labels for g in graphs], axis=0),
        deg_inv_sqrt=np.concatenate([g.deg_inv_sqrt for g in graphs], axis=0),
        node_ptr=node_ptr,
        edge_ptr=edge_ptr,
    )


def unbatch_nodes(batched: Graph, values):
    """Split a (V_total, ...) per-node array back into per-graph arrays."""
    if batched.node_ptr is None:
        return [values]
    return [values[batched.node_ptr[i]:batched.node_ptr[i + 1]]
            for i in range(batched.num_graphs)]


def unbatch_edges(batched: Graph, values):
    """Split a (E_total, ...) per-edge array back into per-graph arrays
    (mirror of :func:`unbatch_nodes`, sliced by ``edge_ptr``) — e.g. the
    per-edge attention coefficients of a batched GAT forward."""
    if batched.edge_ptr is None:
        return [values]
    return [values[batched.edge_ptr[i]:batched.edge_ptr[i + 1]]
            for i in range(batched.num_graphs)]


_TABLE = {name: (v, e) for name, v, e in TABLE_II}


def dataset(name: str, feat: int = 32, seed: int = 0,
            scale: float = 1.0) -> Graph:
    """A paper-dataset stand-in by name ('cora', 'ogbn-arxiv', …) with the
    exact |V|, |E| of Table II (optionally scaled down for smoke tests)."""
    v, e = _TABLE[name]
    v, e = max(8, int(v * scale)), max(8, int(e * scale))
    return synth_graph(name, v, e, feat=feat, seed=seed)


def all_dataset_names():
    return list(_TABLE)
