"""Synthetic graph generator matching the paper's evaluation datasets
(Table II stats), for benchmarks and GNN examples.

Degree distributions are power-law (configurable skew) — the realistic
regime for segment-reduction load imbalance.  Edges come out sorted by
destination (``edge_index[1]`` non-decreasing), the PyG convention GeoT
relies on (paper §IV).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.perfdb import TABLE_II


@dataclasses.dataclass(frozen=True)
class Graph:
    name: str
    edge_index: np.ndarray        # (2, E) int32, [1] sorted non-decreasing
    num_nodes: int
    x: np.ndarray                 # (V, F) float32
    labels: np.ndarray            # (V,) int32
    deg_inv_sqrt: np.ndarray      # (V,) float32

    @property
    def num_edges(self) -> int:
        return self.edge_index.shape[1]


def synth_graph(name: str, num_nodes: int, num_edges: int, feat: int = 32,
                num_classes: int = 16, alpha: float = 1.3,
                seed: int = 0) -> Graph:
    """Power-law in-degree graph with the given |V|, |E|."""
    rng = np.random.default_rng(seed)
    w = rng.zipf(alpha, size=num_nodes).astype(np.float64)
    w = np.minimum(w, num_edges / 4.0)
    p = w / w.sum()
    dst = rng.choice(num_nodes, size=num_edges, p=p).astype(np.int32)
    dst.sort(kind="stable")
    src = rng.integers(0, num_nodes, size=num_edges, dtype=np.int32)
    deg = np.bincount(dst, minlength=num_nodes).astype(np.float32)
    return Graph(
        name=name,
        edge_index=np.stack([src, dst]),
        num_nodes=num_nodes,
        x=rng.standard_normal((num_nodes, feat), dtype=np.float32),
        labels=rng.integers(0, num_classes, num_nodes, dtype=np.int32),
        deg_inv_sqrt=(1.0 / np.sqrt(np.maximum(deg, 1.0))).astype(np.float32),
    )


_TABLE = {name: (v, e) for name, v, e in TABLE_II}


def dataset(name: str, feat: int = 32, seed: int = 0,
            scale: float = 1.0) -> Graph:
    """A paper-dataset stand-in by name ('cora', 'ogbn-arxiv', …) with the
    exact |V|, |E| of Table II (optionally scaled down for smoke tests)."""
    v, e = _TABLE[name]
    v, e = max(8, int(v * scale)), max(8, int(e * scale))
    return synth_graph(name, v, e, feat=feat, seed=seed)


def all_dataset_names():
    return list(_TABLE)
