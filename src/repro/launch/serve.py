"""Batched serving driver: prefill a batch of prompts, then decode.

  python -m repro.launch.serve --arch qwen3-8b --reduced --batch 4 \
      --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.launch.train import reduced_100m
from repro.models import layers, lm


def prefill_into_cache(params, cfg, tokens, state):
    """Sequential prefill through decode_step (simple, exactly matches the
    decode path; a fused prefill kernel is a serving optimization)."""
    logits = None
    for t in range(tokens.shape[1]):
        logits, state = lm.decode_step(params, cfg, tokens[:, t:t + 1], state)
    return logits, state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=cfglib.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = cfglib.get_config(args.arch)
    if args.reduced:
        cfg = reduced_100m(cfg)
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    max_len = args.prompt_len + args.gen + 1
    state = lm.init_decode_state(cfg, args.batch, max_len,
                                 jnp.dtype(cfg.dtype))

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    step = jax.jit(lambda p, t, s: lm.decode_step(p, cfg, t, s))
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, state = step(params, prompts[:, t:t + 1], state)
    prefill_t = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok))
        logits, state = step(params, tok, state)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1, :cfg.vocab_size] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    decode_t = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {prefill_t:.2f}s")
    print(f"decode:  {args.gen} tokens in {decode_t:.2f}s "
          f"({args.batch*args.gen/max(decode_t,1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}]", gen[b][:12].tolist())
    return gen


if __name__ == "__main__":
    main()
