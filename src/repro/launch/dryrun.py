import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
#   Set here (dry-run only) — smoke tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (EXPERIMENTS.md §Dry-run):
  * compiled.memory_analysis()  — per-device bytes: proves it fits,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
  * analytic per-device parameter/optimizer bytes.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # every runnable cell, both meshes
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.configs import shapes as shapelib
from repro.distributed import sharding as shd, step as steplib
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.params import is_param
from repro.optim import adamw

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# optimizer-state precision per arch (memory plan; DESIGN.md §6)
STATE_DTYPE = {"kimi-k2-1t-a32b": "int8", "command-r-plus-104b": "bfloat16"}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9_]+\[[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in optimized HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def sharded_bytes(tree, shardings, mesh) -> int:
    """Per-device bytes of a pytree under the given shardings."""
    total = 0
    leaves = jax.tree_util.tree_leaves(tree)
    shs = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    if len(shs) == 1:
        shs = shs * len(leaves)
    for leaf, sh in zip(leaves, shs):
        size = leaf.size * jnp.dtype(leaf.dtype).itemsize
        if isinstance(sh, jax.sharding.NamedSharding):
            spec = sh.spec
            denom = 1
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for dim, entry in enumerate(spec):
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    denom *= sizes[ax]
            size //= max(denom, 1)
        total += size
    return total


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return {}
    keep = {}
    for k, v in ca.items():
        if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed")):
            keep[k] = float(v)
    return keep


def diff_cell(arch: str, shape: str, multi_pod: bool = False,
              verbose: bool = True):
    """Roofline differencing: lower the cell with 1 and 2 *unrolled* scan
    periods; the difference isolates the true per-period cost that the
    while-loop cost analysis under-reports (benchmarks/roofline.py)."""
    import dataclasses
    cfg = cfglib.get_config(arch)
    if shapelib.cell_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "status": "skipped"}
    _, period_kinds, n_periods = lm.stack_plan(cfg)
    p = max(len(period_kinds), 1)
    lead = cfg.first_dense
    out = {"arch": arch, "shape": shape,
           "mesh": "multi" if multi_pod else "single",
           "n_periods_full": n_periods, "period_len": p}
    for k in (1, 2):
        sub = dataclasses.replace(cfg, num_layers=lead + k * p,
                                  unroll_layers=True)
        res = run_cell(arch, shape, multi_pod, verbose=False, cfg=sub)
        if res.get("status") != "ok":
            out["status"] = "error"
            out["error"] = res.get("error", "sub-lower failed")
            return out
        out[f"flops_{k}p"] = res["cost_analysis"].get("flops", 0.0)
        out[f"bytes_{k}p"] = res["cost_analysis"].get("bytes accessed", 0.0)
        out[f"coll_{k}p"] = float(res["collectives"]["total_bytes"])
    out["status"] = "ok"
    if verbose:
        print(f"[diff {arch} × {shape}] per-period "
              f"flops={out['flops_2p']-out['flops_1p']:.3e} "
              f"coll={out['coll_2p']-out['coll_1p']:.3e}B", flush=True)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             cfg=None):
    cfg = cfg if cfg is not None else cfglib.get_config(arch)
    skip = shapelib.cell_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape,
              "mesh": "multi" if multi_pod else "single"}
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        return result

    cell = shapelib.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # SP plan for unshardable-batch long-context decode
    seq_axis = "data" if (cell.kind == "decode"
                          and cell.global_batch % 16 != 0) else None
    # Serving plan: resident (non-FSDP) weights when the TP-only shard fits
    # HBM — FSDP weight all-gathers per decode token dominated the jamba
    # long_500k cell (235 MB × 12/step measured — §Perf log #9). Training
    # keeps FSDP (optimizer states need it).
    fsdp = True
    if cell.kind == "decode":
        # decide from the FULL registry config — diff_cell lowers reduced-
        # layer variants and must use the same plan as the full cell
        full_cfg = cfglib.get_config(arch)
        n_par = sum(l.size for l in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), full_cfg,
                                           jnp.bfloat16))))
        tp_resident_bytes = n_par * 2 / 16
        fsdp = tp_resident_bytes > 12e9      # kimi/command-r keep FSDP
    plan = shd.ParallelPlan.for_mesh(mesh, fsdp=fsdp, seq_shard_axis=seq_axis)
    specs = shapelib.input_specs(cfg, shape)
    dtype = jnp.dtype(cfg.dtype)

    t0 = time.time()
    params_sds = jax.eval_shape(
        lambda: lm.init(jax.random.PRNGKey(0), cfg, dtype))
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params_sds))
    result["num_params"] = int(n_params)

    with mesh:
        if cell.kind == "train":
            ts = steplib.TrainStepConfig(
                opt=adamw.AdamWConfig(
                    state_dtype=STATE_DTYPE.get(arch, "float32")),
                remat_policy="full")
            step_fn, shardings_for = steplib.build_train_step(cfg, mesh, plan, ts)
            opt_sds = jax.eval_shape(
                lambda: adamw.init(params_sds, ts.opt))
            batch_sds = {k: v for k, v in specs.items()}
            in_sh, out_sh = shardings_for(
                params_sds, opt_sds,
                {k: v.shape for k, v in specs.items()})
            step_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(step_fn, in_shardings=in_sh).lower(
                params_sds, opt_sds, batch_sds, step_sds)
            result["param_bytes_per_device"] = sharded_bytes(
                params_sds, in_sh[0], mesh)
            result["opt_bytes_per_device"] = sharded_bytes(
                opt_sds, in_sh[1], mesh)
        elif cell.kind == "prefill":
            prefill = steplib.build_prefill_step(cfg, mesh, plan,
                                                 remat_policy="none")
            psh = shd.param_shardings(params_sds, plan, mesh)
            bsh = {k: jax.sharding.NamedSharding(
                mesh, shd.spec_for_axes(("batch", "seq"), v.shape[:2], plan,
                                        mesh))
                for k, v in specs.items()}
            lowered = jax.jit(
                lambda p, b: prefill(p, b),
                in_shardings=(psh, bsh)).lower(params_sds, specs)
            result["param_bytes_per_device"] = sharded_bytes(
                params_sds, psh, mesh)
        else:  # decode
            serve_fn, shardings_for = steplib.build_serve_step(
                cfg, mesh, plan, cell.global_batch, cell.seq_len)
            state_sds = jax.eval_shape(
                lambda: lm.init_decode_state(cfg, cell.global_batch,
                                             cell.seq_len, dtype))
            psh, tok_sh, st_sh = shardings_for(params_sds)
            enc = specs.get("enc_out")
            if enc is not None:
                fn = lambda p, t, s, e: serve_fn(p, t, s)  # enc unused in dense path
            lowered = jax.jit(
                serve_fn, in_shardings=(psh, tok_sh, st_sh)).lower(
                params_sds, specs["tokens"], state_sds)
            result["param_bytes_per_device"] = sharded_bytes(
                params_sds, psh, mesh)
            result["cache_bytes_per_device"] = sharded_bytes(
                state_sds, st_sh, mesh)

        result["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)

    result["memory_analysis"] = _mem_analysis(compiled)
    result["cost_analysis"] = _cost_analysis(compiled)
    result["collectives"] = collective_bytes(compiled.as_text())
    result["status"] = "ok"
    if verbose:
        ma = result["memory_analysis"]
        print(f"[{arch} × {shape} × {result['mesh']}] OK "
              f"compile={result['compile_s']}s "
              f"flops={result['cost_analysis'].get('flops', 0):.3e} "
              f"coll={result['collectives']['total_bytes']:.3e}B "
              f"temp={ma.get('temp_size_in_bytes', 0):.3e}B",
              flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=cfglib.ARCH_NAMES)
    ap.add_argument("--shape", choices=shapelib.SHAPE_NAMES)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--diff", action="store_true",
                    help="roofline differencing mode (1p/2p unrolled lowers)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.list:
        for a in cfglib.ARCH_NAMES:
            cfg = cfglib.get_config(a)
            for s in shapelib.SHAPE_NAMES:
                skip = shapelib.cell_applicable(cfg, s)
                print(f"{a:24s} {s:12s} {'SKIP: ' + skip if skip else 'run'}")
        return

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for a in cfglib.ARCH_NAMES:
            for s in shapelib.SHAPE_NAMES:
                for m in (False, True):
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.mesh == "multi"))

    failures = 0
    for a, s, m in cells:
        tag = f"{a}__{s}__{'multi' if m else 'single'}"
        base = RESULTS_DIR.parent / "roofline_diff" if args.diff else RESULTS_DIR
        base.mkdir(parents=True, exist_ok=True)
        out_path = pathlib.Path(args.out) if args.out else base / f"{tag}.json"
        try:
            res = diff_cell(a, s, m) if args.diff else run_cell(a, s, m)
        except Exception as e:  # noqa: BLE001 — recorded per cell
            res = {"arch": a, "shape": s,
                   "mesh": "multi" if m else "single",
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
            failures += 1
            print(f"[{tag}] FAILED: {e!r}", flush=True)
        out_path.write_text(json.dumps(res, indent=2))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
