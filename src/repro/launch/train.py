"""End-to-end LM training driver — a thin CLI over :func:`repro.train.fit`
(the orchestration layer owns the jitted step, checkpoint/resume, and the
fault-tolerant loop; this file only parses flags and wires the
provider/task/trainer trio).

Examples:
  # ~100M-param LM for a few hundred steps on CPU (examples deliverable):
  python -m repro.launch.train --arch qwen3-8b --reduced --steps 300

  # host-mesh distributed smoke (2×2 devices, the pjit build-step path):
  python -m repro.launch.train --arch qwen3-moe-30b-a3b --reduced \
      --mesh host --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs as cfglib
from repro.checkpoint import checkpoint as ckpt
from repro.data.tokens import TokenDatasetConfig
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import LMTask, TokenProvider, TrainerConfig, fit


def reduced_100m(cfg):
    """~100M-param config of the same family (example driver scale)."""
    over = dict(num_layers=max(4, min(cfg.num_layers, 8)), d_model=512,
                num_heads=8, num_kv_heads=min(cfg.num_kv_heads, 4) or 4,
                head_dim=64, d_ff=2048, vocab_size=32768, max_seq=2048,
                dtype="float32")
    if cfg.num_experts:
        over.update(num_experts=8, top_k=2, moe_d_ff=512)
    if cfg.family == "hybrid":
        over.update(num_layers=8)
    return dataclasses.replace(cfg, **over)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=cfglib.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="~100M-param variant (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--moe-impl", choices=["capacity", "ragged"],
                    default="capacity")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = cfglib.get_config(args.arch)
    if args.reduced:
        cfg = reduced_100m(cfg)
    if cfg.family == "audio":
        raise SystemExit("use examples/gnn_training.py-style drivers for "
                         "enc-dec")

    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        lm.init(jax.random.PRNGKey(0), cfg)))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"vocab={cfg.padded_vocab} layers={cfg.num_layers}")

    task = LMTask(cfg, moe_impl=args.moe_impl)
    data = TokenProvider(TokenDatasetConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))
    mesh = make_host_mesh(2, 2) if args.mesh == "host" else None

    trainer_cfg = TrainerConfig(
        steps=args.steps, opt=adamw.AdamWConfig(lr=args.lr),
        warmup_steps=20, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=args.log_every)

    start = ckpt.latest_step(args.ckpt_dir) if args.ckpt_dir else None
    if start:
        print(f"resuming from checkpoint step {start}")
    result = fit(task, data, trainer_cfg, mesh=mesh,
                 resume=bool(args.ckpt_dir))
    ckpt.wait_pending()
    print(f"final loss {result.losses[-1]:.4f} "
          f"(first {result.losses[0]:.4f})")
    return result.losses


if __name__ == "__main__":
    main()
