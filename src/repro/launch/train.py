"""End-to-end training driver (fault-tolerant loop).

Examples:
  # ~100M-param LM for a few hundred steps on CPU (examples deliverable):
  python -m repro.launch.train --arch qwen3-8b --reduced --steps 300

  # host-mesh distributed smoke (2×2 devices):
  python -m repro.launch.train --arch qwen3-moe-30b-a3b --reduced \
      --mesh host --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.checkpoint import checkpoint as ckpt
from repro.data.tokens import SyntheticTokens, TokenDatasetConfig
from repro.distributed import sharding as shd, step as steplib
from repro.distributed.fault_tolerance import (ResilientLoop,
                                               ResilientLoopConfig)
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw


def reduced_100m(cfg):
    """~100M-param config of the same family (example driver scale)."""
    over = dict(num_layers=max(4, min(cfg.num_layers, 8)), d_model=512,
                num_heads=8, num_kv_heads=min(cfg.num_kv_heads, 4) or 4,
                head_dim=64, d_ff=2048, vocab_size=32768, max_seq=2048,
                dtype="float32")
    if cfg.num_experts:
        over.update(num_experts=8, top_k=2, moe_d_ff=512)
    if cfg.family == "hybrid":
        over.update(num_layers=8)
    return dataclasses.replace(cfg, **over)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=cfglib.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="~100M-param variant (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--moe-impl", choices=["capacity", "ragged"],
                    default="capacity")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = cfglib.get_config(args.arch)
    if args.reduced:
        cfg = reduced_100m(cfg)
    if cfg.family == "audio":
        raise SystemExit("use examples/gnn_train.py-style drivers for enc-dec")

    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"vocab={cfg.padded_vocab} layers={cfg.num_layers}")

    ts = steplib.TrainStepConfig(
        opt=adamw.AdamWConfig(lr=args.lr), warmup_steps=20,
        total_steps=args.steps, remat_policy="none", moe_impl=args.moe_impl)
    opt_state = adamw.init(params, ts.opt)

    data = SyntheticTokens(TokenDatasetConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    if args.mesh == "host":
        mesh = make_host_mesh(2, 2)
        plan = shd.ParallelPlan.for_mesh(mesh)
        fn, shardings_for = steplib.build_train_step(cfg, mesh, plan, ts)
        in_sh, _ = shardings_for(params, opt_state,
                                 {"tokens": (args.batch, args.seq),
                                  "labels": (args.batch, args.seq)})
        with mesh:
            params = jax.device_put(params, in_sh[0])
            opt_state = jax.device_put(opt_state, in_sh[1])
            train_step = jax.jit(fn, in_shardings=in_sh,
                                 donate_argnums=(0, 1))
    else:
        mesh = None

        def fn(params, opt_state, batch, step):
            def loss(p):
                return lm.loss_fn(p, cfg, batch, remat_policy="none",
                                  moe_impl=args.moe_impl)
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            from repro.optim import schedule
            lr_scale = schedule.warmup_cosine(step, ts.warmup_steps,
                                              ts.total_steps)
            new_p, new_o, om = adamw.update(grads, opt_state, params, ts.opt,
                                            lr_scale)
            return new_p, new_o, dict(metrics, loss=l, **om)

        train_step = jax.jit(fn, donate_argnums=(0, 1))

    losses = []

    def step_fn(state, step):
        params, opt_state = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        t0 = time.time()
        params, opt_state, metrics = train_step(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"dt {time.time()-t0:.2f}s", flush=True)
        return (params, opt_state), metrics

    loop = ResilientLoop(
        ResilientLoopConfig(args.ckpt_dir, ckpt_every=args.ckpt_every),
        step_fn, (params, opt_state))
    start = ckpt.latest_step(args.ckpt_dir) or 0
    if start:
        print(f"resuming from checkpoint step {start}")
        loop.state = ckpt.restore(loop.state, args.ckpt_dir, step=start)
    loop.run(args.steps, start_step=start)
    ckpt.wait_pending()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
