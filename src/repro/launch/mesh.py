"""Production mesh construction.

A function — not a module-level constant — so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pipe: int | None = None):
    """Small mesh over host devices for integration tests."""
    if pipe:
        return jax.make_mesh((data, model, pipe), ("data", "model", "pipe"))
    return jax.make_mesh((data, model), ("data", "model"))
