"""LRU cache of per-bucket plan templates and jit executables.

The hot-path problem this solves: a :class:`~repro.core.plan.SegmentPlan`
is a pytree whose *static aux* (kernel config, tight ``max_chunks``,
degree stats) differs per graph — so even two graphs padded to the same
(V, E) bucket would retrace a jitted forward if each brought its own
plan. A :class:`BucketEntry` therefore canonicalizes everything static
**per bucket**:

  * one :class:`~repro.core.config_space.KernelConfig`, resolved once per
    bucket — a measured PerfDB winner when one exists for the bucket's
    shape class (:func:`measured_config`; a pure lookup, never an inline
    sweep), else the generated decision-tree rules;
  * ``max_chunks`` pinned to a bucket-static bound (see ``chunk_policy``
    on the engine) instead of the per-graph tight value;
  * canonical per-bucket :class:`~repro.core.plan.SegmentStats` (skew 1),
    so cost-model decisions (transform/aggregate order) are a function of
    the bucket, not the request.

Per request, only the plan's *leaves* change: :meth:`BucketEntry.stamp`
recomputes the chunk metadata (one ``searchsorted`` over the padded
destinations) and grafts it onto the template — zero ``make_plan`` /
config-selection / compile work on a cache hit, which the counters (and
the tests) verify.

The cache is capacity-bounded LRU: evicting an entry drops its executable
(recompiled on next touch, counted as a fresh miss). ``warm`` prefills
entries ahead of traffic without polluting the hit/miss accounting.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, Hashable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.config_space import KernelConfig
from repro.core.plan import SegmentPlan, SegmentStats
from repro.serve.buckets import ShapeBucket

__all__ = ["CacheStats", "BucketEntry", "PlanCache", "measured_config",
           "bucket_max_chunks"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def measured_config(bucket: ShapeBucket, feat: int,
                    op: str = "segment_reduce",
                    db=None) -> Optional[KernelConfig]:
    """The PerfDB's measured winner for the bucket's shape class, or None.

    This is the serving tier of the selection precedence: a *lookup only*
    — serving must never pay a wall-clock sweep inline. Populate the DB
    offline (``tune=True`` plan builds, the ablation benchmark, or
    :meth:`GNNServer.warmup` with ``tune=True``)."""
    import jax

    from repro.core import autotune
    from repro.core.features import InputFeatures
    from repro.kernels.ops import _default_interpret

    if db is None:
        db = autotune.PerfDB()
    backend = jax.default_backend()
    if _default_interpret() and backend != "cpu":
        backend += "+interp"
    feats = InputFeatures(int(bucket.num_edges), int(bucket.num_nodes),
                          int(feat))
    entry = db.get(autotune.perf_key(backend, op, feats))
    if entry is None:
        return None
    return KernelConfig(*entry["best"])


def bucket_max_chunks(bucket: ShapeBucket, config: KernelConfig,
                      policy: str = "worst") -> int:
    """Bucket-static chunk-grid bound.

    ``"worst"`` — every row block (``ceil(E_bucket / m_b)``): one compile
    per bucket, guaranteed to cover any graph in it (a block's chunk range
    is a subrange of all chunks). The tight per-graph grid is traded for
    executable reuse — the serving latency/predictability tradeoff
    (``docs/serving.md``). No other policy is bucket-static; growth
    policies live in the engine."""
    if policy != "worst":
        raise ValueError(f"unknown bucket-static chunk policy {policy!r}")
    m_pad = _round_up(max(bucket.num_edges, 1), config.m_b)
    return max(m_pad // config.m_b, 1)


def _canonical_stats(bucket: ShapeBucket) -> SegmentStats:
    """Deterministic per-bucket stats (skew 1): cost-model decisions made
    from a template must match for every graph in the bucket, or the
    traced program (transform/aggregate order) would differ per request."""
    e, v = bucket.num_edges, bucket.num_nodes
    live = max(min(e, v), 1)
    avg = e / live
    return SegmentStats(num_rows=e, num_segments=v, live_segments=live,
                        max_degree=max(int(np.ceil(avg)), 1),
                        avg_degree=avg, std_degree=0.0)


class BucketEntry:
    """One cache line: the bucket's canonical plan template + (set by the
    engine) the jit executable compiled against its static aux."""

    def __init__(self, bucket: ShapeBucket, feat: int, config: KernelConfig,
                 max_chunks: Optional[int] = None):
        self.bucket = bucket
        self.feat = int(feat)
        self.config = config
        self.max_chunks = (bucket_max_chunks(bucket, config)
                           if max_chunks is None else int(max_chunks))
        self.m_pad = _round_up(max(bucket.num_edges, 1), config.m_b)
        # all-pad index: the template's leaves describe "no real edges";
        # stamp() replaces them with a request's actual chunk metadata
        self.template = self._stamp_plan(
            np.full(0, bucket.num_nodes, np.int32), template=None)
        self.executable = None        # attached by the engine
        self.compiled = False
        self.compile_s = 0.0

    # -- per-request leaves -------------------------------------------------
    def _stamp_plan(self, dst: np.ndarray, template) -> SegmentPlan:
        from repro.kernels.segment_reduce import chunk_metadata
        v, cfg = self.bucket.num_nodes, self.config
        idxp = np.full(self.m_pad, v, np.int32)
        idxp[:dst.size] = dst
        cf, cc = chunk_metadata(idxp, v, cfg.s_b, cfg.m_b, self.m_pad)
        if template is not None:
            return dataclasses.replace(template, chunk_first=jnp.asarray(cf),
                                       chunk_count=jnp.asarray(cc))
        return SegmentPlan(chunk_first=jnp.asarray(cf),
                           chunk_count=jnp.asarray(cc),
                           num_rows=self.bucket.num_edges,
                           num_segments=v,
                           max_chunks=self.max_chunks,
                           config=cfg,
                           stats=_canonical_stats(self.bucket))

    def stamp(self, dst) -> SegmentPlan:
        """A servable plan for one padded graph: the request's chunk
        metadata (leaves) under the bucket's static aux — same pytree
        treedef as the template, so the executable never retraces."""
        dst = np.asarray(dst, np.int32)
        if dst.size != self.bucket.num_edges:
            raise ValueError(
                f"stamp expects {self.bucket.num_edges} padded edges "
                f"(bucket {self.bucket}), got {dst.size}")
        return self._stamp_plan(dst, self.template)


class CacheStats:
    """Hit/miss/eviction + build/compile-time accounting — a view over
    labeled instruments in the :mod:`repro.obs` metrics registry. Each
    stats object carries a process-unique ``cache`` label, so every
    PlanCache's counters export side by side in one telemetry dump;
    the instruments are *vital* (they count even when observability is
    disabled — the serving contract's tests rely on them). Attribute
    reads/writes (``stats.hits += 1``) go straight through to the
    registry series."""

    _INT_FIELDS = ("hits", "misses", "evictions", "prefills",
                   "plan_builds", "compiles")
    _FLOAT_FIELDS = ("plan_build_s", "compile_s")

    def __init__(self, cache_id: Optional[str] = None):
        from repro import obs
        reg = obs.get_registry()
        self.cache_id = cache_id or obs.next_id("cache")
        self._labels = {"cache": self.cache_id}
        self._metrics = {
            f: reg.counter(f"serve.plan_cache.{f}", labels=("cache",),
                           vital=True)
            for f in self._INT_FIELDS + self._FLOAT_FIELDS}
        for m in self._metrics.values():
            m.touch(**self._labels)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> Dict:
        d = {f: getattr(self, f)
             for f in self._INT_FIELDS + self._FLOAT_FIELDS}
        d["hit_rate"] = round(self.hit_rate, 4)
        return d


def _stats_field(field: str, as_int: bool):
    def fget(self):
        v = self._metrics[field].value(**self._labels)
        return int(v) if as_int else v

    def fset(self, v):
        self._metrics[field].set(float(v), **self._labels)

    return property(fget, fset)


for _f in CacheStats._INT_FIELDS:
    setattr(CacheStats, _f, _stats_field(_f, as_int=True))
for _f in CacheStats._FLOAT_FIELDS:
    setattr(CacheStats, _f, _stats_field(_f, as_int=False))
del _f


class PlanCache:
    """Capacity-bounded LRU over :class:`BucketEntry` cache lines.

    Keys are whatever tuple the caller serves under — the engine uses
    ``(bucket, feat, model, impl, shards)`` so one cache can back several
    engines. ``weight=`` on the counting methods attributes a lookup to
    the number of *requests* it served (a batch of k graphs sharing one
    bucket counts k hits), which is the hit-rate a serving SLO cares
    about.

    Thread-safe: the prefetch pipeline's producer threads
    (:mod:`repro.data.pipeline`) hit the same cache concurrently with the
    consumer, so every read-modify-write — LRU reorder, eviction, stats
    bump, and the build inside :meth:`get_or_build` — happens under one
    re-entrant lock. Holding the lock across the builder intentionally
    serializes misses on the same key: N racing threads produce exactly
    one ``BucketEntry`` (``plan_builds`` counts distinct keys, not
    threads), which is the invariant the zero-retrace accounting needs.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.stats = CacheStats()
        self._lock = threading.RLock()
        self._entries: "collections.OrderedDict[Hashable, BucketEntry]" = \
            collections.OrderedDict()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self):
        with self._lock:
            return list(self._entries)

    # -- core --------------------------------------------------------------
    def lookup(self, key: Hashable, weight: int = 1) -> Optional[BucketEntry]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += weight
                from repro import obs
                obs.record_cache_event(self.stats.cache_id, "miss",
                                       key=str(key), weight=weight)
                return None
            self._entries.move_to_end(key)
            self.stats.hits += weight
            return entry

    def insert(self, key: Hashable, entry: BucketEntry) -> None:
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                old_key, _ = self._entries.popitem(last=False)
                self.stats.evictions += 1
                from repro import obs
                obs.record_cache_event(self.stats.cache_id, "eviction",
                                       key=str(old_key),
                                       capacity=self.capacity)

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], BucketEntry],
                     weight: int = 1) -> BucketEntry:
        """One serving lookup: LRU hit, or build + insert on miss (the
        build time lands in ``plan_build_s``; the *compile* happens on the
        entry's first execution and is accounted by the engine). The lock
        is held across the builder — concurrent misses on one key build
        once (the RLock makes a builder that re-enters the cache safe)."""
        with self._lock:
            entry = self.lookup(key, weight=weight)
            if entry is None:
                t0 = time.perf_counter()
                entry = builder()
                self.stats.plan_builds += 1
                self.stats.plan_build_s += time.perf_counter() - t0
                self.insert(key, entry)
            return entry

    def warm(self, key: Hashable,
             builder: Callable[[], BucketEntry]) -> BucketEntry:
        """Prefill ahead of traffic: like :meth:`get_or_build` but counted
        as a prefill, not a miss — warmup must not dilute the serving
        hit-rate it exists to protect."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                return entry
            t0 = time.perf_counter()
            entry = builder()
            self.stats.prefills += 1
            self.stats.plan_builds += 1
            self.stats.plan_build_s += time.perf_counter() - t0
            self.insert(key, entry)
            return entry
