"""Shape buckets: pad variable-shape graphs onto a fixed ladder of
(num_nodes, num_edges) classes so the planned Pallas path can serve a
stream of arbitrary graphs with a *bounded* set of compiled executables.

Every jit'd forward is specialized on (V, E) — and, through the
:class:`~repro.core.plan.SegmentPlan` pytree, on the plan's static aux
(config, ``max_chunks``, stats). Served raw, a stream of random-shape
graphs would recompile per request. Bucketing rounds (V, E) up a
geometric ladder (power-of-two by default) and pads the graph to the
bucket with :func:`repro.data.graphs.pad_graph`:

  * padded **edges** carry ``dst = V_bucket`` — the drop id the kernels
    already use for their own row padding — so they fall outside every
    output window and real-node logits are **bit-identical** to the
    unpadded graph under the same kernel config;
  * padded **nodes** are isolated; their output rows are sliced away by
    ``unpad_nodes``.

The number of distinct buckets a workload can touch is O(log² of its
shape range), which is exactly the executable-cache bound the serving
engine advertises (see ``docs/serving.md``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.data.graphs import Graph, pad_graph

__all__ = ["ShapeBucket", "BucketPolicy", "bucket_size", "bucket_rungs",
           "bucket_for", "pad_to_bucket"]


@dataclasses.dataclass(frozen=True, order=True)
class ShapeBucket:
    """One shape class: graphs are padded to exactly this (V, E)."""
    num_nodes: int
    num_edges: int

    def __str__(self) -> str:
        return f"V{self.num_nodes}xE{self.num_edges}"


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """The bucket ladder: floors and a geometric growth factor.

    ``growth=2.0`` (default) is the power-of-two ladder — at most 2x node
    and edge padding waste, ~log2 buckets per decade of shape. A finer
    ``growth`` (e.g. 1.5) trades more compiles for less padded compute;
    coarser floors merge micro-graphs into one bucket.
    """
    min_nodes: int = 64
    min_edges: int = 64
    growth: float = 2.0

    def __post_init__(self):
        if self.growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {self.growth}")
        if self.min_nodes < 1 or self.min_edges < 1:
            raise ValueError("bucket floors must be >= 1")


def bucket_size(n: int, floor: int, growth: float = 2.0) -> int:
    """Smallest rung of the ladder ``floor * growth^k`` that is >= n."""
    size = int(floor)
    while size < n:
        size = max(int(size * growth), size + 1)
    return size


def bucket_rungs(hi: int, floor: int, growth: float = 2.0) -> list:
    """Every ladder rung up to (and including) ``bucket_size(hi)`` — the
    single source of the rung rule, so warmup ladders built from it can
    never desynchronize from the buckets :func:`bucket_for` picks."""
    sizes, size = [], int(floor)
    while True:
        sizes.append(size)
        if size >= hi:
            return sizes
        size = max(int(size * growth), size + 1)


def bucket_for(num_nodes: int, num_edges: int,
               policy: Optional[BucketPolicy] = None) -> ShapeBucket:
    """The shape class of a (V, E) graph under ``policy``."""
    policy = policy or BucketPolicy()
    return ShapeBucket(
        num_nodes=bucket_size(num_nodes, policy.min_nodes, policy.growth),
        num_edges=bucket_size(num_edges, policy.min_edges, policy.growth),
    )


def pad_to_bucket(g: Graph, policy: Optional[BucketPolicy] = None,
                  bucket: Optional[ShapeBucket] = None,
                  ) -> Tuple[Graph, ShapeBucket]:
    """Pad ``g`` to its bucket (or an explicit one); returns (padded,
    bucket). Round-trip with ``unpad_nodes`` / ``unpad_graph``."""
    if bucket is None:
        bucket = bucket_for(g.num_nodes, g.num_edges, policy)
    return pad_graph(g, bucket.num_nodes, bucket.num_edges), bucket
