"""Iteration-level continuous micro-batching for graph requests.

The graph twin of :class:`repro.serve.lm.ContinuousBatcher`
(same submit / step / run-until-drained shape): queued requests are
admitted FIFO into **block-diagonal** batches — one
:func:`repro.data.graphs.batch_graphs` call per batch, so a single fused
segment-reduce launch aggregates every member graph at once — under

  * a **token budget** (``max_batch_nodes`` / ``max_batch_edges``): the
    block-diagonal batch's |V| and |E| are what the padded forward pays
    for, so admission caps them (a request alone over budget is still
    admitted as a singleton — it must be servable);
  * a **count cap** (``max_batch_graphs``); and
  * a **latency deadline** (``max_wait_s``): an under-budget batch is
    held back for more traffic until its oldest member has waited this
    long. ``max_wait_s=0`` (default) serves whatever is queued each step
    — the pure-throughput setting for synchronous drains.

Unlike LM decode, graph inference is single-shot: a request occupies its
batch for exactly one step, so "continuous" here means per-iteration
admission — every step forms a fresh batch from whatever has queued,
keeping the padded executable full without waiting for stragglers.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

from repro import obs
from repro.data.graphs import Graph

__all__ = ["GraphRequest", "GraphBatcher"]


@dataclasses.dataclass
class GraphRequest:
    """One queued inference request."""
    uid: int
    graph: Graph
    t_submit: float = dataclasses.field(default_factory=time.perf_counter)


class GraphBatcher:
    """FIFO admission into block-diagonal batches under budget + deadline."""

    def __init__(self, max_batch_nodes: int = 4096,
                 max_batch_edges: Optional[int] = None,
                 max_batch_graphs: int = 16,
                 max_wait_s: float = 0.0):
        if max_batch_nodes < 1 or max_batch_graphs < 1:
            raise ValueError("batch budgets must be >= 1")
        self.max_batch_nodes = int(max_batch_nodes)
        self.max_batch_edges = (None if max_batch_edges is None
                                else int(max_batch_edges))
        self.max_batch_graphs = int(max_batch_graphs)
        self.max_wait_s = float(max_wait_s)
        self.queue: Deque[GraphRequest] = deque()
        # admission telemetry (non-vital: purely observational — nothing
        # in the serving contract reads these back)
        reg = obs.get_registry()
        self._labels = {"batcher": obs.next_id("batcher")}
        self._m_submitted = reg.counter("serve.submitted", ("batcher",))
        self._m_depth = reg.gauge("serve.queue_depth", ("batcher",))
        self._m_submitted.touch(**self._labels)
        self._m_depth.touch(**self._labels)

    def __len__(self) -> int:
        return len(self.queue)

    def submit(self, req: GraphRequest) -> None:
        self.queue.append(req)
        self._m_submitted.inc(**self._labels)
        self._m_depth.set(len(self.queue), **self._labels)

    # -- admission ----------------------------------------------------------
    def _fits(self, req: GraphRequest, nodes: int, edges: int,
              count: int) -> bool:
        if count >= self.max_batch_graphs:
            return False
        if count and nodes + req.graph.num_nodes > self.max_batch_nodes:
            return False            # count==0: oversize singleton is allowed
        if (count and self.max_batch_edges is not None
                and edges + req.graph.num_edges > self.max_batch_edges):
            return False
        return True

    def _budget_full(self, nodes: int, edges: int, count: int) -> bool:
        """Would the next queued request NOT fit?"""
        return bool(self.queue) and not self._fits(self.queue[0], nodes,
                                                   edges, count)

    def next_batch(self, now: Optional[float] = None,
                   flush: bool = False) -> List[GraphRequest]:
        """Admit the next batch, or [] when it pays to wait.

        A batch is released when it is budget-full, when its oldest member
        has waited ``max_wait_s``, or when ``flush`` forces a drain.
        """
        if not self.queue:
            return []
        now = time.perf_counter() if now is None else now
        deadline_hit = (flush or
                        now - self.queue[0].t_submit >= self.max_wait_s)
        batch: List[GraphRequest] = []
        nodes = edges = 0
        while self.queue and self._fits(self.queue[0], nodes, edges,
                                        len(batch)):
            req = self.queue.popleft()
            batch.append(req)
            nodes += req.graph.num_nodes
            edges += req.graph.num_edges
        # a batch at the graph-count cap is full even with an empty queue —
        # no future request could join it, so holding it for the deadline
        # would be pure added latency
        full = (len(batch) >= self.max_batch_graphs
                or self._budget_full(nodes, edges, len(batch)))
        if not deadline_hit and not full:
            # under budget and under deadline: hold for more traffic
            for req in reversed(batch):
                self.queue.appendleft(req)
            return []
        self._m_depth.set(len(self.queue), **self._labels)
        return batch
