"""GNNServer: synchronous GNN inference serving over the planned Pallas
path.

One ``step()`` of the serving loop:

    queue ──GraphBatcher──▶ block-diagonal batch (batch_graphs)
          ──buckets──────▶ pad to the batch's ShapeBucket (drop-id edges)
          ──PlanCache────▶ BucketEntry: canonical config / max_chunks /
                           stats + the jit executable for this bucket
          ──stamp────────▶ per-request chunk metadata (plan leaves only)
          ──executable───▶ models/gnn.forward, one compiled program per
                           bucket, retrace-free across requests
          ──unpad/unbatch▶ per-request logits + latency / fusion stats

Compile discipline: the executable is keyed on the bucket (and the
entry's bucket-static plan aux), so a stream of arbitrary-shape graphs
triggers **at most one compile per bucket touched** — the property the
acceptance tests pin. A cache hit performs zero ``make_plan`` / config
selection / trace work; the per-request cost is one ``searchsorted``
stamp plus the padded forward.

``shards > 1`` routes the same loop through the partitioned path
(:mod:`repro.core.dist_mp`): the *padded* batch is partitioned per
request, so all shard shapes are bucket-derived; the partition's own
static aux (node boundaries, halo) still varies with the degree
distribution, so sharded serving trades the one-compile-per-bucket
guarantee for mesh execution (documented in ``docs/serving.md``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data.graphs import (Graph, batch_graphs, synth_graph,
                               unbatch_nodes, unpad_nodes)
from repro.models import gnn
from repro.obs import span
from repro.serve.batcher import GraphBatcher, GraphRequest
from repro.serve.buckets import BucketPolicy, ShapeBucket, pad_to_bucket
from repro.serve.plan_cache import (BucketEntry, PlanCache, bucket_max_chunks,
                                    measured_config)

__all__ = ["ServedResult", "GNNServer"]


@dataclasses.dataclass
class ServedResult:
    """Per-request outcome + the latency/efficiency breakdown."""
    uid: int
    logits: np.ndarray            # (V_request, C)
    bucket: ShapeBucket
    batch_size: int               # graphs co-served in this step
    queue_s: float                # submit -> admission
    serve_s: float                # batch -> pad -> stamp -> forward
    latency_s: float              # submit -> result
    cache_hit: bool
    compiled: bool                # this step paid the bucket's compile
    pad_nodes: int                # bucket V minus batch V (waste)
    pad_edges: int
    fusion: Dict[str, int]        # trace-time fusion audit (compile steps
    #                               only — cache hits trace nothing)


class GNNServer:
    """Synchronous serving engine for one (model family, params) pair.

    ``submit()`` enqueues graphs; ``step()`` serves one micro-batch;
    ``run_until_drained()`` loops. All four model families work (GCN /
    GIN / SAGE / multi-head GAT — heads are carried by ``params``).

    Knobs (the SLO surface, see ``docs/serving.md``): bucket ``policy``
    (pad waste vs compile count), ``cache_capacity`` (executables held),
    batch budget + ``max_wait_s`` (throughput vs tail latency), ``tune``
    (pay autotuner sweeps at warmup for measured kernel configs).
    """

    def __init__(self, params, model: str, *, impl: str = "pallas",
                 feat: Optional[int] = None,
                 policy: Optional[BucketPolicy] = None,
                 cache_capacity: int = 32,
                 max_batch_nodes: int = 4096,
                 max_batch_edges: Optional[int] = None,
                 max_batch_graphs: int = 16,
                 max_wait_s: float = 0.0,
                 tune: Optional[bool] = None,
                 shards: int = 0,
                 perfdb=None):
        if model not in gnn.MODELS:
            raise ValueError(f"unknown model {model!r}; one of {gnn.MODELS}")
        self.params = params
        self.model = model
        self.impl = impl
        self.feat = int(feat) if feat is not None else _widest_layer(params)
        self.policy = policy or BucketPolicy()
        self.tune = tune
        self.shards = int(shards)
        if perfdb is None:
            # one PerfDB instance for the engine's lifetime: it parses the
            # on-disk JSON once and serves every bucket build from memory
            from repro.core.autotune import PerfDB
            perfdb = PerfDB()
        self._perfdb = perfdb
        self._mesh = None
        if self.shards > 1:
            from repro.core.dist_mp import make_shard_mesh
            self._mesh = make_shard_mesh(self.shards)
        self.cache = PlanCache(capacity=cache_capacity)
        self.batcher = GraphBatcher(max_batch_nodes=max_batch_nodes,
                                    max_batch_edges=max_batch_edges,
                                    max_batch_graphs=max_batch_graphs,
                                    max_wait_s=max_wait_s)
        self._uid = 0
        self.results: Dict[int, ServedResult] = {}
        # telemetry: all per-engine accounting lives in the repro.obs
        # registry under this engine's instance label (vital — stats()
        # works with observability disabled). reset() zeroes the window
        # without dropping cache lines or compiled executables.
        reg = obs.get_registry()
        self._labels = {"engine": obs.next_id("engine")}
        self._m_requests = reg.counter("serve.requests", ("engine",),
                                       vital=True)
        self._m_batches = reg.counter("serve.batches", ("engine",),
                                      vital=True)
        self._m_serve_s = reg.counter("serve.serve_s", ("engine",),
                                      vital=True)
        self._m_compiles = reg.counter("serve.compiles", ("engine",),
                                       vital=True)
        self._m_latency = reg.histogram("serve.request_latency_s",
                                        ("engine",), vital=True)
        self._m_queue = reg.histogram("serve.queue_s", ("engine",),
                                      vital=True)
        self._m_pad_nodes = reg.histogram("serve.pad_node_frac",
                                          ("engine",), vital=True,
                                          buckets=(1.0, 1.5, 2.0, 4.0, 8.0))
        self._m_pad_edges = reg.histogram("serve.pad_edge_frac",
                                          ("engine",), vital=True,
                                          buckets=(1.0, 1.5, 2.0, 4.0, 8.0))
        for m in (self._m_requests, self._m_batches, self._m_serve_s,
                  self._m_compiles, self._m_latency, self._m_queue,
                  self._m_pad_nodes, self._m_pad_edges):
            m.touch(**self._labels)
        self._compile_cause = "cold"  # attribution for the next trace

    # -- admission -----------------------------------------------------------
    def submit(self, graph: Graph, uid: Optional[int] = None) -> int:
        """Enqueue one graph; returns its request id."""
        if graph.orig_num_nodes is not None:
            raise ValueError("submit expects unpadded graphs; the engine "
                             "pads to its own buckets")
        if uid is not None and (uid in self.results
                                or any(r.uid == uid
                                       for r in self.batcher.queue)):
            raise ValueError(f"duplicate request uid {uid}: its result "
                             "would silently overwrite the earlier one")
        if uid is None:
            uid = self._uid
        self._uid = max(self._uid, uid) + 1
        self.batcher.submit(GraphRequest(uid=uid, graph=graph))
        return uid

    # -- cache entries -------------------------------------------------------
    def _entry_key(self, bucket: ShapeBucket):
        return (bucket, self.feat, self.model, self.impl, self.shards)

    def _build_entry(self, bucket: ShapeBucket) -> BucketEntry:
        """Resolve the bucket's canonical config and build its cache line.

        Precedence: measured PerfDB winner for the bucket's shape class
        (pure lookup — serving never sweeps inline) > with ``tune=True``,
        a fresh autotuner sweep (warmup-only territory), stored under the
        *same* (E_bucket, V_bucket, feat) shape class and the same DB the
        lookup reads so the next engine replays it for free > the
        generated decision-tree rules."""
        config = measured_config(bucket, self.feat, db=self._perfdb)
        if config is None and self.tune:
            from repro.core import autotune
            config = autotune.tune(
                op="segment_reduce", idx_size=max(bucket.num_edges, 1),
                num_segments=max(bucket.num_nodes, 1), feat=self.feat,
                db=self._perfdb).config
        if config is None:
            from repro.core.heuristics import select_config
            config = select_config(
                max(bucket.num_edges, 1),
                max(min(bucket.num_edges, bucket.num_nodes), 1),
                self.feat, tune=False)
        entry = BucketEntry(bucket, self.feat, config,
                            max_chunks=bucket_max_chunks(bucket, config))
        entry.executable = self._make_executable(bucket)
        return entry

    def _note_trace(self, bucket: ShapeBucket) -> None:
        """Fires as a Python side effect at trace time only — it IS the
        compile counter the stats report, and every firing leaves an
        attribution record naming the bucket and the cause that led the
        engine here (warmup / bucket_miss / sampled_ingest / ...)."""
        self._m_compiles.inc(**self._labels)
        obs.record_compile(
            "serve.forward", self._compile_cause,
            engine=self._labels["engine"], bucket=str(bucket),
            model=self.model, impl=self.impl, feat=self.feat,
            shards=self.shards)

    def _make_executable(self, bucket: ShapeBucket):
        """One jitted forward per bucket. The plan rides as a pytree arg:
        its leaves (chunk metadata) change per request, its static aux is
        pinned by the entry — so re-invocation never retraces. The
        trace-counter bump is a Python side effect and fires only while
        tracing: it IS the compile counter the stats report."""
        num_nodes, model, impl = bucket.num_nodes, self.model, self.impl

        if self.shards > 1:
            mesh = self._mesh

            def fwd_sharded(params, x, edge_index, dis, plan, partition):
                self._note_trace(bucket)
                return gnn.forward(params, model, x, edge_index, num_nodes,
                                   dis, impl=impl, plan=plan, mesh=mesh,
                                   partition=partition)
            return jax.jit(fwd_sharded)

        def fwd(params, x, edge_index, dis, plan):
            self._note_trace(bucket)
            return gnn.forward(params, model, x, edge_index, num_nodes, dis,
                               impl=impl, plan=plan)
        return jax.jit(fwd)

    # -- one serving iteration ----------------------------------------------
    def step(self, flush: bool = False) -> List[ServedResult]:
        """Admit one micro-batch and serve it; [] when the batcher holds."""
        reqs = self.batcher.next_batch(flush=flush)
        if not reqs:
            return []
        with span("serve.step", engine=self._labels["engine"],
                  requests=len(reqs)) as root:
            t0 = time.perf_counter()
            with span("serve.batch", graphs=len(reqs)):
                batch = batch_graphs([r.graph for r in reqs])
            with span("serve.pad"):
                padded, bucket = pad_to_bucket(batch, self.policy)
            root.set(bucket=str(bucket))
            self._compile_cause = "bucket_miss"
            with span("serve.plan_cache", bucket=str(bucket)):
                entry = self.cache.get_or_build(
                    self._entry_key(bucket),
                    lambda: self._build_entry(bucket),
                    weight=len(reqs))
            hit = entry.compiled

            from repro.kernels.ops import fusion_scope
            traces_before = self.compiles
            with fusion_scope() as fusion:
                logits = self._run(entry, padded, compiled=hit)
            logits = np.asarray(jax.block_until_ready(logits))
            if not entry.compiled:
                entry.compiled = True
                entry.compile_s = time.perf_counter() - t0
                self.cache.stats.compile_s += entry.compile_s
            self.cache.stats.compiles += self.compiles - traces_before

            t1 = time.perf_counter()
            self._m_batches.inc(**self._labels)
            self._m_serve_s.inc(t1 - t0, **self._labels)
            self._m_pad_nodes.observe(
                bucket.num_nodes / max(batch.num_nodes, 1), **self._labels)
            self._m_pad_edges.observe(
                bucket.num_edges / max(batch.num_edges, 1), **self._labels)
            per_graph = unbatch_nodes(batch, unpad_nodes(padded, logits))
            fusion_counts = dict(fusion)
            out = []
            for req, y in zip(reqs, per_graph):
                res = ServedResult(
                    uid=req.uid, logits=y, bucket=bucket,
                    batch_size=len(reqs),
                    queue_s=t0 - req.t_submit, serve_s=t1 - t0,
                    latency_s=t1 - req.t_submit, cache_hit=hit,
                    compiled=not hit,
                    pad_nodes=bucket.num_nodes - batch.num_nodes,
                    pad_edges=bucket.num_edges - batch.num_edges,
                    fusion=fusion_counts)
                self.results[req.uid] = res
                self._m_requests.inc(**self._labels)
                self._m_latency.observe(res.latency_s, **self._labels)
                self._m_queue.observe(res.queue_s, **self._labels)
                out.append(res)
            return out

    def _run(self, entry: BucketEntry, padded: Graph,
             compiled: Optional[bool] = None):
        x = jnp.asarray(padded.x)
        dis = jnp.asarray(padded.deg_inv_sqrt)
        ei = jnp.asarray(padded.edge_index)
        if compiled is None:
            compiled = entry.compiled
        exec_span = "serve.execute" if compiled else "serve.compile"
        if self.shards > 1:
            # the sharded path consumes a PartitionedPlan; the bucket
            # template's stamp is single-device-only and is skipped here
            from repro.core.plan import make_partitioned_plan
            from repro.data.partition import partition_graph
            with span("serve.stamp", sharded=True):
                pg = partition_graph(padded, self.shards)
                pplan = make_partitioned_plan(pg, feat=self.feat,
                                              config=entry.config)
            with span(exec_span, bucket=str(entry.bucket)):
                return entry.executable(self.params, x, ei, dis, pplan, pg)
        with span("serve.stamp"):
            plan = entry.stamp(padded.edge_index[1])
        with span(exec_span, bucket=str(entry.bucket)):
            return entry.executable(self.params, x, ei, dis, plan)

    # -- sampled (out-of-core) ingest -----------------------------------------
    def sampled_pipeline(self, sampler, *, depth: int = 2,
                         num_threads: Optional[int] = None):
        """An async prefetch pipeline whose batches are served by *this*
        engine's cache lines: the producer shares ``self.cache`` and
        builds entries with ``self._build_entry`` (executable attached),
        so a batch's plan is stamped under the exact static aux
        :meth:`serve_sampled` will execute — one compile per bucket
        across the producer threads and the serving loop combined."""
        from repro.data.pipeline import PrefetchPipeline, SampledBatchProducer
        if self.shards > 1:
            raise NotImplementedError(
                "sampled serving is single-device (the sharded path "
                "re-partitions per request)")
        producer = SampledBatchProducer(
            sampler, feat=self.feat, policy=self.policy, cache=self.cache,
            entry_key=self._entry_key, entry_builder=self._build_entry,
            perfdb=self._perfdb)
        return PrefetchPipeline(producer, depth=depth,
                                num_threads=num_threads)

    def serve_sampled(self, batch) -> np.ndarray:
        """Serve one :class:`~repro.data.pipeline.SampledBatch`: the seed
        rows' logits, (num_seeds, C). Batches from
        :meth:`sampled_pipeline` reuse their stamped plan as-is; a batch
        produced against a foreign cache is re-stamped under this
        engine's entry so the executable never retraces on aux drift."""
        if self.shards > 1:
            raise NotImplementedError("sampled serving is single-device")
        with span("serve.step", engine=self._labels["engine"],
                  bucket=str(batch.bucket), sampled=True):
            t0 = time.perf_counter()
            self._compile_cause = "sampled_ingest"
            with span("serve.plan_cache", bucket=str(batch.bucket)):
                entry = self.cache.get_or_build(
                    self._entry_key(batch.bucket),
                    lambda: self._build_entry(batch.bucket))
            plan = batch.plan
            if (plan.config != entry.config
                    or plan.max_chunks != entry.max_chunks):
                with span("serve.stamp", restamp=True):
                    plan = entry.stamp(batch.graph.edge_index[1])
            traces_before = self.compiles
            exec_span = "serve.execute" if entry.compiled else "serve.compile"
            with span(exec_span, bucket=str(batch.bucket)):
                logits = entry.executable(
                    self.params, batch.arrays["x"],
                    batch.arrays["edge_index"],
                    batch.arrays["deg_inv_sqrt"], plan)
                logits = np.asarray(jax.block_until_ready(logits))
            if not entry.compiled:
                entry.compiled = True
                entry.compile_s = time.perf_counter() - t0
                self.cache.stats.compile_s += entry.compile_s
            self.cache.stats.compiles += self.compiles - traces_before
            self._m_batches.inc(**self._labels)
            self._m_serve_s.inc(time.perf_counter() - t0, **self._labels)
            return logits[:batch.num_seeds]

    def run_until_drained(self, max_steps: int = 100_000
                          ) -> Dict[int, ServedResult]:
        steps = 0
        while self.batcher.queue and steps < max_steps:
            self.step(flush=True)
            steps += 1
        return self.results

    # -- warmup ---------------------------------------------------------------
    def warmup(self, buckets: Sequence[ShapeBucket]) -> int:
        """Prefill cache lines and compile their executables ahead of
        traffic, against an all-padding synthetic member of each bucket
        (every edge a drop edge — shape-complete, data-free). With
        ``tune=True`` this is also where autotuner sweeps are paid.
        Returns the number of entries compiled; prefills do not count as
        cache misses."""
        buckets = list(buckets)
        if len(buckets) > self.cache.capacity:
            raise ValueError(
                f"warming {len(buckets)} buckets into a capacity-"
                f"{self.cache.capacity} cache would evict the earliest "
                "prefills immediately; raise cache_capacity")
        compiled = 0
        self._compile_cause = "warmup"
        for bucket in buckets:
            entry = self.cache.warm(self._entry_key(bucket),
                                    lambda b=bucket: self._build_entry(b))
            if entry.compiled:
                continue
            g = synth_graph(f"warmup-{bucket}", min(2, bucket.num_nodes), 0,
                            feat=_input_feat(self.params, self.model))
            padded, _ = pad_to_bucket(g, bucket=bucket)
            t0 = time.perf_counter()
            traces_before = self.compiles
            jax.block_until_ready(self._run(entry, padded, compiled=False))
            entry.compiled = True
            entry.compile_s = time.perf_counter() - t0
            self.cache.stats.compile_s += entry.compile_s
            self.cache.stats.compiles += self.compiles - traces_before
            compiled += 1
        return compiled

    # -- stats ----------------------------------------------------------------
    @property
    def compiles(self) -> int:
        """Executable traces so far (warmup + serving)."""
        return int(self._m_compiles.value(**self._labels))

    def stats(self) -> Dict:
        """The engine's serving-window summary, read off the registry.

        Well-defined on a cold engine: every count is 0, throughput /
        latencies are 0.0 and pad overheads 1.0 (no padding observed ==
        no waste) — never a ZeroDivisionError or NaN."""
        requests = int(self._m_requests.value(**self._labels))
        batches = int(self._m_batches.value(**self._labels))
        serve_s = self._m_serve_s.value(**self._labels)
        n_lat = self._m_latency.count(**self._labels)
        n_pad = self._m_pad_nodes.count(**self._labels)
        return {
            "requests": requests,
            "batches": batches,
            "mean_batch_size": requests / batches if batches else 0.0,
            "compiles": self.compiles,
            "buckets": len(self.cache),
            "cache": self.cache.stats.as_dict(),
            "throughput_rps": requests / serve_s if serve_s else 0.0,
            "latency_mean_s": (self._m_latency.mean(**self._labels)
                               if n_lat else 0.0),
            "latency_p95_s": (self._m_latency.percentile(95, **self._labels)
                              if n_lat else 0.0),
            "pad_node_overhead": (self._m_pad_nodes.mean(**self._labels)
                                  if n_pad else 1.0),
            "pad_edge_overhead": (self._m_pad_edges.mean(**self._labels)
                                  if n_pad else 1.0),
        }

    def reset(self) -> None:
        """Zero this engine's serving-window accounting (counters,
        latency/padding histograms, delivered results). Cache lines and
        compiled executables are kept — ``reset()`` starts a fresh
        measurement window, not a fresh engine — so ``stats()`` right
        after is the documented cold-path shape."""
        for m in (self._m_requests, self._m_batches, self._m_serve_s,
                  self._m_compiles, self._m_latency, self._m_queue,
                  self._m_pad_nodes, self._m_pad_edges):
            m.reset(**self._labels)
            m.touch(**self._labels)
        self.results.clear()


def _widest_layer(params) -> int:
    """The representative feature width for config selection: the widest
    trailing dim of any >=2-D parameter (mirrors make_model_plan's
    'widest layer width' guidance)."""
    dims = [int(a.shape[-1]) for a in jax.tree_util.tree_leaves(params)
            if hasattr(a, "ndim") and a.ndim >= 2]
    return max(dims, default=128)


_FIRST_W = {"gcn": "w", "gin": "mlp1", "sage": "w_self", "gat": "w"}


def _input_feat(params, model: str) -> int:
    """d_in of the first layer (for warmup's synthetic graphs)."""
    return int(params[0][_FIRST_W[model]].value.shape[0])
