"""GNNServer: synchronous GNN inference serving over the planned Pallas
path.

One ``step()`` of the serving loop:

    queue ──GraphBatcher──▶ block-diagonal batch (batch_graphs)
          ──buckets──────▶ pad to the batch's ShapeBucket (drop-id edges)
          ──PlanCache────▶ BucketEntry: canonical config / max_chunks /
                           stats + the jit executable for this bucket
          ──stamp────────▶ per-request chunk metadata (plan leaves only)
          ──executable───▶ models/gnn.forward, one compiled program per
                           bucket, retrace-free across requests
          ──unpad/unbatch▶ per-request logits + latency / fusion stats

Compile discipline: the executable is keyed on the bucket (and the
entry's bucket-static plan aux), so a stream of arbitrary-shape graphs
triggers **at most one compile per bucket touched** — the property the
acceptance tests pin. A cache hit performs zero ``make_plan`` / config
selection / trace work; the per-request cost is one ``searchsorted``
stamp plus the padded forward.

``shards > 1`` routes the same loop through the partitioned path
(:mod:`repro.core.dist_mp`): the *padded* batch is partitioned per
request, so all shard shapes are bucket-derived; the partition's own
static aux (node boundaries, halo) still varies with the degree
distribution, so sharded serving trades the one-compile-per-bucket
guarantee for mesh execution (documented in ``docs/serving.md``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import (Graph, batch_graphs, synth_graph,
                               unbatch_nodes, unpad_nodes)
from repro.models import gnn
from repro.serve.batcher import GraphBatcher, GraphRequest
from repro.serve.buckets import BucketPolicy, ShapeBucket, pad_to_bucket
from repro.serve.plan_cache import (BucketEntry, PlanCache, bucket_max_chunks,
                                    measured_config)

__all__ = ["ServedResult", "GNNServer"]


@dataclasses.dataclass
class ServedResult:
    """Per-request outcome + the latency/efficiency breakdown."""
    uid: int
    logits: np.ndarray            # (V_request, C)
    bucket: ShapeBucket
    batch_size: int               # graphs co-served in this step
    queue_s: float                # submit -> admission
    serve_s: float                # batch -> pad -> stamp -> forward
    latency_s: float              # submit -> result
    cache_hit: bool
    compiled: bool                # this step paid the bucket's compile
    pad_nodes: int                # bucket V minus batch V (waste)
    pad_edges: int
    fusion: Dict[str, int]        # trace-time fusion audit (compile steps
    #                               only — cache hits trace nothing)


class GNNServer:
    """Synchronous serving engine for one (model family, params) pair.

    ``submit()`` enqueues graphs; ``step()`` serves one micro-batch;
    ``run_until_drained()`` loops. All four model families work (GCN /
    GIN / SAGE / multi-head GAT — heads are carried by ``params``).

    Knobs (the SLO surface, see ``docs/serving.md``): bucket ``policy``
    (pad waste vs compile count), ``cache_capacity`` (executables held),
    batch budget + ``max_wait_s`` (throughput vs tail latency), ``tune``
    (pay autotuner sweeps at warmup for measured kernel configs).
    """

    def __init__(self, params, model: str, *, impl: str = "pallas",
                 feat: Optional[int] = None,
                 policy: Optional[BucketPolicy] = None,
                 cache_capacity: int = 32,
                 max_batch_nodes: int = 4096,
                 max_batch_edges: Optional[int] = None,
                 max_batch_graphs: int = 16,
                 max_wait_s: float = 0.0,
                 tune: Optional[bool] = None,
                 shards: int = 0,
                 perfdb=None):
        if model not in gnn.MODELS:
            raise ValueError(f"unknown model {model!r}; one of {gnn.MODELS}")
        self.params = params
        self.model = model
        self.impl = impl
        self.feat = int(feat) if feat is not None else _widest_layer(params)
        self.policy = policy or BucketPolicy()
        self.tune = tune
        self.shards = int(shards)
        if perfdb is None:
            # one PerfDB instance for the engine's lifetime: it parses the
            # on-disk JSON once and serves every bucket build from memory
            from repro.core.autotune import PerfDB
            perfdb = PerfDB()
        self._perfdb = perfdb
        self._mesh = None
        if self.shards > 1:
            from repro.core.dist_mp import make_shard_mesh
            self._mesh = make_shard_mesh(self.shards)
        self.cache = PlanCache(capacity=cache_capacity)
        self.batcher = GraphBatcher(max_batch_nodes=max_batch_nodes,
                                    max_batch_edges=max_batch_edges,
                                    max_batch_graphs=max_batch_graphs,
                                    max_wait_s=max_wait_s)
        self._uid = 0
        self._trace_events = 0        # bumped inside executables at trace
        self.results: Dict[int, ServedResult] = {}
        self._latencies: List[float] = []
        self._batches = 0
        self._serve_s = 0.0           # wall time inside step() serving
        self._pad_node_frac: List[float] = []
        self._pad_edge_frac: List[float] = []

    # -- admission -----------------------------------------------------------
    def submit(self, graph: Graph, uid: Optional[int] = None) -> int:
        """Enqueue one graph; returns its request id."""
        if graph.orig_num_nodes is not None:
            raise ValueError("submit expects unpadded graphs; the engine "
                             "pads to its own buckets")
        if uid is not None and (uid in self.results
                                or any(r.uid == uid
                                       for r in self.batcher.queue)):
            raise ValueError(f"duplicate request uid {uid}: its result "
                             "would silently overwrite the earlier one")
        if uid is None:
            uid = self._uid
        self._uid = max(self._uid, uid) + 1
        self.batcher.submit(GraphRequest(uid=uid, graph=graph))
        return uid

    # -- cache entries -------------------------------------------------------
    def _entry_key(self, bucket: ShapeBucket):
        return (bucket, self.feat, self.model, self.impl, self.shards)

    def _build_entry(self, bucket: ShapeBucket) -> BucketEntry:
        """Resolve the bucket's canonical config and build its cache line.

        Precedence: measured PerfDB winner for the bucket's shape class
        (pure lookup — serving never sweeps inline) > with ``tune=True``,
        a fresh autotuner sweep (warmup-only territory), stored under the
        *same* (E_bucket, V_bucket, feat) shape class and the same DB the
        lookup reads so the next engine replays it for free > the
        generated decision-tree rules."""
        config = measured_config(bucket, self.feat, db=self._perfdb)
        if config is None and self.tune:
            from repro.core import autotune
            config = autotune.tune(
                op="segment_reduce", idx_size=max(bucket.num_edges, 1),
                num_segments=max(bucket.num_nodes, 1), feat=self.feat,
                db=self._perfdb).config
        if config is None:
            from repro.core.heuristics import select_config
            config = select_config(
                max(bucket.num_edges, 1),
                max(min(bucket.num_edges, bucket.num_nodes), 1),
                self.feat, tune=False)
        entry = BucketEntry(bucket, self.feat, config,
                            max_chunks=bucket_max_chunks(bucket, config))
        entry.executable = self._make_executable(bucket)
        return entry

    def _make_executable(self, bucket: ShapeBucket):
        """One jitted forward per bucket. The plan rides as a pytree arg:
        its leaves (chunk metadata) change per request, its static aux is
        pinned by the entry — so re-invocation never retraces. The
        trace-counter bump is a Python side effect and fires only while
        tracing: it IS the compile counter the stats report."""
        num_nodes, model, impl = bucket.num_nodes, self.model, self.impl

        if self.shards > 1:
            mesh = self._mesh

            def fwd_sharded(params, x, edge_index, dis, plan, partition):
                self._trace_events += 1
                return gnn.forward(params, model, x, edge_index, num_nodes,
                                   dis, impl=impl, plan=plan, mesh=mesh,
                                   partition=partition)
            return jax.jit(fwd_sharded)

        def fwd(params, x, edge_index, dis, plan):
            self._trace_events += 1
            return gnn.forward(params, model, x, edge_index, num_nodes, dis,
                               impl=impl, plan=plan)
        return jax.jit(fwd)

    # -- one serving iteration ----------------------------------------------
    def step(self, flush: bool = False) -> List[ServedResult]:
        """Admit one micro-batch and serve it; [] when the batcher holds."""
        reqs = self.batcher.next_batch(flush=flush)
        if not reqs:
            return []
        t0 = time.perf_counter()
        batch = batch_graphs([r.graph for r in reqs])
        padded, bucket = pad_to_bucket(batch, self.policy)
        entry = self.cache.get_or_build(
            self._entry_key(bucket),
            lambda: self._build_entry(bucket),
            weight=len(reqs))
        hit = entry.compiled

        from repro.kernels.ops import fusion_scope
        traces_before = self._trace_events
        with fusion_scope() as fusion:
            logits = self._run(entry, padded)
        logits = np.asarray(jax.block_until_ready(logits))
        if not entry.compiled:
            entry.compiled = True
            entry.compile_s = time.perf_counter() - t0
            self.cache.stats.compile_s += entry.compile_s
        self.cache.stats.compiles += self._trace_events - traces_before

        t1 = time.perf_counter()
        self._batches += 1
        self._serve_s += t1 - t0
        self._pad_node_frac.append(bucket.num_nodes / max(batch.num_nodes, 1))
        self._pad_edge_frac.append(bucket.num_edges / max(batch.num_edges, 1))
        per_graph = unbatch_nodes(batch, unpad_nodes(padded, logits))
        fusion_counts = dict(fusion)
        out = []
        for req, y in zip(reqs, per_graph):
            res = ServedResult(
                uid=req.uid, logits=y, bucket=bucket, batch_size=len(reqs),
                queue_s=t0 - req.t_submit, serve_s=t1 - t0,
                latency_s=t1 - req.t_submit, cache_hit=hit,
                compiled=not hit,
                pad_nodes=bucket.num_nodes - batch.num_nodes,
                pad_edges=bucket.num_edges - batch.num_edges,
                fusion=fusion_counts)
            self.results[req.uid] = res
            self._latencies.append(res.latency_s)
            out.append(res)
        return out

    def _run(self, entry: BucketEntry, padded: Graph):
        x = jnp.asarray(padded.x)
        dis = jnp.asarray(padded.deg_inv_sqrt)
        ei = jnp.asarray(padded.edge_index)
        if self.shards > 1:
            # the sharded path consumes a PartitionedPlan; the bucket
            # template's stamp is single-device-only and is skipped here
            from repro.core.plan import make_partitioned_plan
            from repro.data.partition import partition_graph
            pg = partition_graph(padded, self.shards)
            pplan = make_partitioned_plan(pg, feat=self.feat,
                                          config=entry.config)
            return entry.executable(self.params, x, ei, dis, pplan, pg)
        plan = entry.stamp(padded.edge_index[1])
        return entry.executable(self.params, x, ei, dis, plan)

    # -- sampled (out-of-core) ingest -----------------------------------------
    def sampled_pipeline(self, sampler, *, depth: int = 2,
                         num_threads: Optional[int] = None):
        """An async prefetch pipeline whose batches are served by *this*
        engine's cache lines: the producer shares ``self.cache`` and
        builds entries with ``self._build_entry`` (executable attached),
        so a batch's plan is stamped under the exact static aux
        :meth:`serve_sampled` will execute — one compile per bucket
        across the producer threads and the serving loop combined."""
        from repro.data.pipeline import PrefetchPipeline, SampledBatchProducer
        if self.shards > 1:
            raise NotImplementedError(
                "sampled serving is single-device (the sharded path "
                "re-partitions per request)")
        producer = SampledBatchProducer(
            sampler, feat=self.feat, policy=self.policy, cache=self.cache,
            entry_key=self._entry_key, entry_builder=self._build_entry,
            perfdb=self._perfdb)
        return PrefetchPipeline(producer, depth=depth,
                                num_threads=num_threads)

    def serve_sampled(self, batch) -> np.ndarray:
        """Serve one :class:`~repro.data.pipeline.SampledBatch`: the seed
        rows' logits, (num_seeds, C). Batches from
        :meth:`sampled_pipeline` reuse their stamped plan as-is; a batch
        produced against a foreign cache is re-stamped under this
        engine's entry so the executable never retraces on aux drift."""
        if self.shards > 1:
            raise NotImplementedError("sampled serving is single-device")
        t0 = time.perf_counter()
        entry = self.cache.get_or_build(
            self._entry_key(batch.bucket),
            lambda: self._build_entry(batch.bucket))
        plan = batch.plan
        if plan.config != entry.config or plan.max_chunks != entry.max_chunks:
            plan = entry.stamp(batch.graph.edge_index[1])
        traces_before = self._trace_events
        logits = entry.executable(
            self.params, batch.arrays["x"], batch.arrays["edge_index"],
            batch.arrays["deg_inv_sqrt"], plan)
        logits = np.asarray(jax.block_until_ready(logits))
        if not entry.compiled:
            entry.compiled = True
            entry.compile_s = time.perf_counter() - t0
            self.cache.stats.compile_s += entry.compile_s
        self.cache.stats.compiles += self._trace_events - traces_before
        self._batches += 1
        self._serve_s += time.perf_counter() - t0
        return logits[:batch.num_seeds]

    def run_until_drained(self, max_steps: int = 100_000
                          ) -> Dict[int, ServedResult]:
        steps = 0
        while self.batcher.queue and steps < max_steps:
            self.step(flush=True)
            steps += 1
        return self.results

    # -- warmup ---------------------------------------------------------------
    def warmup(self, buckets: Sequence[ShapeBucket]) -> int:
        """Prefill cache lines and compile their executables ahead of
        traffic, against an all-padding synthetic member of each bucket
        (every edge a drop edge — shape-complete, data-free). With
        ``tune=True`` this is also where autotuner sweeps are paid.
        Returns the number of entries compiled; prefills do not count as
        cache misses."""
        buckets = list(buckets)
        if len(buckets) > self.cache.capacity:
            raise ValueError(
                f"warming {len(buckets)} buckets into a capacity-"
                f"{self.cache.capacity} cache would evict the earliest "
                "prefills immediately; raise cache_capacity")
        compiled = 0
        for bucket in buckets:
            entry = self.cache.warm(self._entry_key(bucket),
                                    lambda b=bucket: self._build_entry(b))
            if entry.compiled:
                continue
            g = synth_graph(f"warmup-{bucket}", min(2, bucket.num_nodes), 0,
                            feat=_input_feat(self.params, self.model))
            padded, _ = pad_to_bucket(g, bucket=bucket)
            t0 = time.perf_counter()
            traces_before = self._trace_events
            jax.block_until_ready(self._run(entry, padded))
            entry.compiled = True
            entry.compile_s = time.perf_counter() - t0
            self.cache.stats.compile_s += entry.compile_s
            self.cache.stats.compiles += self._trace_events - traces_before
            compiled += 1
        return compiled

    # -- stats ----------------------------------------------------------------
    @property
    def compiles(self) -> int:
        """Executable traces so far (warmup + serving)."""
        return self._trace_events

    def stats(self) -> Dict:
        lat = np.asarray(self._latencies) if self._latencies else None
        return {
            "requests": len(self.results),
            "batches": self._batches,
            "mean_batch_size": (len(self.results) / self._batches
                                if self._batches else 0.0),
            "compiles": self._trace_events,
            "buckets": len(self.cache),
            "cache": self.cache.stats.as_dict(),
            "throughput_rps": (len(self.results) / self._serve_s
                               if self._serve_s else 0.0),
            "latency_mean_s": float(lat.mean()) if lat is not None else 0.0,
            "latency_p95_s": (float(np.percentile(lat, 95))
                              if lat is not None else 0.0),
            "pad_node_overhead": (float(np.mean(self._pad_node_frac))
                                  if self._pad_node_frac else 1.0),
            "pad_edge_overhead": (float(np.mean(self._pad_edge_frac))
                                  if self._pad_edge_frac else 1.0),
        }


def _widest_layer(params) -> int:
    """The representative feature width for config selection: the widest
    trailing dim of any >=2-D parameter (mirrors make_model_plan's
    'widest layer width' guidance)."""
    dims = [int(a.shape[-1]) for a in jax.tree_util.tree_leaves(params)
            if hasattr(a, "ndim") and a.ndim >= 2]
    return max(dims, default=128)


_FIRST_W = {"gcn": "w", "gin": "mlp1", "sage": "w_self", "gat": "w"}


def _input_feat(params, model: str) -> int:
    """d_in of the first layer (for warmup's synthetic graphs)."""
    return int(params[0][_FIRST_W[model]].value.shape[0])
