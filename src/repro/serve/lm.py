"""Continuous-batching LM serving scheduler (``repro.serve.lm``).

Moved here from the seed-era ``repro.serving`` package — ``serve/`` is the
one serving namespace (GNN engine in :mod:`repro.serve.engine`, the LM
token-level scheduler here); ``repro.serving`` now raises with a pointer.

Production serving keeps the decode batch full: finished requests release
their slot immediately and queued requests claim it mid-flight (vLLM-style
iteration-level scheduling). The jit'd ``decode_step`` stays static-shape —
per-slot state lives in fixed (B, …) buffers and slot turnover is a host-side
concern plus one masked cache reset.

Pieces:
  Request        — prompt + max_new_tokens (+ callbacks for streaming)
  SlotState      — host view of one batch slot
  ContinuousBatcher — admits/evicts requests, runs prefill (per-slot token
                   feed) and batched decode ticks, collects outputs.

Single-host implementation (the pjit serve_step drops in for the step
function at pod scale — the scheduler only touches host metadata).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 16
    on_token: Optional[Callable[[int, int], None]] = None   # (uid, token)


@dataclasses.dataclass
class SlotState:
    request: Optional[Request] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_pos: int = 0                # tokens of the prompt already fed

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        return (self.request is not None
                and self.prompt_pos < len(self.request.prompt))


class ContinuousBatcher:
    """Iteration-level scheduler over a fixed decode batch."""

    def __init__(self, params, cfg: ModelConfig, batch_size: int,
                 max_len: int, dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.batch = batch_size
        self.max_len = max_len
        self.slots = [SlotState() for _ in range(batch_size)]
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, List[int]] = {}
        self.state = lm.init_decode_state(cfg, batch_size, max_len, dtype)
        # per-slot position counter (the shared DecodeState.length advances
        # globally; per-slot validity is tracked by position masks)
        self.positions = np.zeros(batch_size, np.int32)
        # ragged decode: every slot advances at its own cache position
        self._step = jax.jit(
            lambda p, t, s, l: lm.decode_step(p, cfg, t, s, lengths=l))

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.free and self.queue:
                req = self.queue.popleft()
                self.slots[i] = SlotState(request=req)
                self._reset_slot_cache(i)
                self.positions[i] = 0

    def _reset_slot_cache(self, i: int):
        """Zero slot i's cache/state rows.

        Structural, not shape-matched: lead caches carry batch at axis 0,
        period caches at axis 1 (after the stacked-periods dim) — guessing
        by size breaks when num_layers == batch_size."""
        def zero_at(axis):
            def f(x):
                if not hasattr(x, "ndim") or x.ndim <= axis:
                    return x
                idx = [slice(None)] * x.ndim
                idx[axis] = i
                return x.at[tuple(idx)].set(0)
            return f

        self.state = lm.DecodeState(
            jax.tree_util.tree_map(zero_at(0), self.state.lead),
            jax.tree_util.tree_map(zero_at(1), self.state.period),
            self.state.length)

    # -- one scheduler tick --------------------------------------------------
    def tick(self) -> int:
        """Admit → build the token batch (prompt token for prefilling slots,
        last generated token for decoding slots) → one decode_step →
        collect/evict. Returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return 0
        tokens = np.zeros((self.batch, 1), np.int32)
        was_prefill = [False] * self.batch
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            if slot.prefilling:
                was_prefill[i] = True
                tokens[i, 0] = slot.request.prompt[slot.prompt_pos]
            else:
                tokens[i, 0] = slot.generated[-1]

        logits, self.state = self._step(self.params, jnp.asarray(tokens),
                                        self.state,
                                        jnp.asarray(self.positions))
        next_tok = np.asarray(
            jnp.argmax(logits[:, -1, :self.cfg.vocab_size], -1))

        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            self.positions[i] += 1
            if was_prefill[i]:
                slot.prompt_pos += 1
                if slot.prompt_pos < len(slot.request.prompt):
                    continue              # mid-prompt: no output yet
                # the tick that consumed the LAST prompt token produced the
                # logits of the first generated token — fall through
            tok = int(next_tok[i])
            slot.generated.append(tok)
            if slot.request.on_token:
                slot.request.on_token(slot.request.uid, tok)
            done = (len(slot.generated) >= slot.request.max_new_tokens
                    or self.positions[i] >= self.max_len - 1)
            if done:
                self.finished[slot.request.uid] = slot.generated
                self.slots[i] = SlotState()   # slot freed ⇒ next tick admits
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        ticks = 0
        while (self.queue or any(not s.free for s in self.slots)) \
                and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
