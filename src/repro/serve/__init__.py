"""GNN inference serving: shape-bucketed padding, plan/executable cache,
and block-diagonal continuous batching over the planned Pallas path.

See ``docs/serving.md`` for the design; entry point:

    from repro.serve import GNNServer
    server = GNNServer(params, "gcn", impl="pallas")
    uid = server.submit(graph)
    server.run_until_drained()
    logits = server.results[uid].logits
"""
from repro.serve.batcher import GraphBatcher, GraphRequest
from repro.serve.buckets import (BucketPolicy, ShapeBucket, bucket_for,
                                 bucket_rungs, pad_to_bucket)
from repro.serve.engine import GNNServer, ServedResult
from repro.serve.plan_cache import (BucketEntry, CacheStats, PlanCache,
                                    measured_config)

__all__ = ["GNNServer", "ServedResult", "GraphBatcher", "GraphRequest",
           "BucketPolicy", "ShapeBucket", "bucket_for", "bucket_rungs",
           "pad_to_bucket", "BucketEntry", "CacheStats", "PlanCache",
           "measured_config"]
