"""O(1)-complexity input features for data-aware config selection
(paper §III-C): ``Idx_size``, ``Idx_max`` (O(1) because Idx is sorted —
it is the last element), ``avg = Idx_size / Idx_max``, plus feature size F.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class InputFeatures:
    idx_size: int        # M = |E|
    idx_max: int         # ≈ number of live segments (last element + 1)
    feat: int            # F = N
    dtype_bytes: int = 4  # io dtype width (4 = fp32, 2 = bf16); NOT part of
                          # as_vector() — the generated decision tree is
                          # trained on the 3-D shape vector, dtype selects a
                          # separate PerfDB shelf via perf_key instead.

    @property
    def avg(self) -> float:
        """Average segment length (≈ average in-degree)."""
        return self.idx_size / max(self.idx_max, 1)

    def as_vector(self) -> np.ndarray:
        """Feature vector for the decision tree: log-scaled sizes + avg + F.

        Log scaling matches the orders-of-magnitude spread across graph
        datasets (Table II spans 9K → 23M edges). Deliberately excludes
        dtype_bytes — see the field comment."""
        return np.array([
            np.log2(max(self.idx_size, 1)),
            np.log2(max(self.avg, 2 ** -4)),
            np.log2(max(self.feat, 1)),
        ], dtype=np.float64)

    @staticmethod
    def names() -> list[str]:
        return ["log2_idx_size", "log2_avg", "log2_feat"]


def extract_features(idx, feat: int, dtype_bytes: int = 4) -> InputFeatures:
    """idx must be sorted non-decreasing; max is O(1) (last element)."""
    idx = np.asarray(idx)
    idx_max = int(idx[-1]) + 1 if idx.size else 1
    return InputFeatures(idx_size=int(idx.size), idx_max=idx_max,
                         feat=int(feat), dtype_bytes=int(dtype_bytes))
