"""Tunable hierarchical tiling space (paper §III-A/B, Table I), re-based on
TPU geometry.

Parameter mapping (GPU → TPU, see DESIGN.md §2):

    T_M, T_N  (thread groups / block)   →  S_b, N_b  (out-rows / cols per VMEM block)
    M_t, N_t  (data / thread group)     →  M_b       (input rows per chunk)
    G_t       (synced threads, PR only) →  K_c       (rows per MXU sub-matmul)
    schedule  (SR / PR)                 →  schedule  (VPU row-scan / MXU one-hot)

Like the paper (§III-C) we prune the space to a constant-size candidate set
grounded in hardware constraints: N_b multiples of the 128-lane register
width, M_b multiples of the 8-sublane height, and VMEM budget
(in + out + one-hot tiles ≤ ~16 MiB/2 for double buffering).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, List

VMEM_BYTES = 16 * 1024 * 1024          # v5e VMEM per core
LANES = 128                            # vector register lanes
SUBLANES = 8                           # vector register sublanes (fp32)

# --- io dtype axis -----------------------------------------------------------
# The kernels carry an *io dtype* (the dtype of x / weights / outputs in HBM)
# orthogonal to the accumulator dtype, which is always fp32. Lowering the io
# dtype halves the bytes per row-DMA on the bandwidth-bound gather/scatter
# stages — the paper's segment reduces are bandwidth-bound (§IV), so io dtype
# is a first-class tuning axis next to the tile sizes.
IO_DTYPES = ("float32", "bfloat16")

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def _io_dtype_name(dtype) -> str:
    name = getattr(dtype, "name", None)
    if isinstance(name, str):
        return name
    import numpy as np
    try:
        return np.dtype(dtype).name     # handles type classes (jnp.float32)
    except TypeError:
        return str(dtype)


def io_dtype_bytes(dtype) -> int:
    """Bytes per element of an io dtype (name, np.dtype, jax dtype, or
    scalar type class)."""
    name = _io_dtype_name(dtype)
    try:
        return _DTYPE_BYTES[name]
    except KeyError:
        import numpy as np
        return int(np.dtype(name).itemsize)


def canonical_io_dtype(dtype) -> str:
    """Canonical string name for the io dtype axis ('float32', 'bfloat16')."""
    return _io_dtype_name(dtype)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """A point in the tunable space ⟨schedule, S_b, N_b, M_b, K_c⟩."""
    schedule: str = "SR"    # "SR" (VPU sequential) | "PR" (MXU one-hot)
    s_b: int = 128          # output rows per block (PR out-tile height)
    n_b: int = 128          # feature columns per block
    m_b: int = 256          # input rows per chunk
    k_c: int = 8            # MXU contraction sub-chunk (PR only; SR ⇒ 1)

    def __post_init__(self):
        if self.schedule == "SR":
            object.__setattr__(self, "k_c", 1)

    def astuple(self):
        return (self.schedule, self.s_b, self.n_b, self.m_b, self.k_c)

    def vmem_bytes(self, dtype_bytes: int = 4) -> int:
        """VMEM working set: X chunk + out block + one-hot (PR), x2 buffered."""
        x_tile = self.m_b * self.n_b * dtype_bytes
        out_tile = self.s_b * self.n_b * dtype_bytes
        onehot = self.m_b * self.s_b * dtype_bytes if self.schedule == "PR" else 0
        idx_tile = self.m_b * 4
        return 2 * (x_tile + idx_tile) + out_tile + onehot


# Tunable op keys: every kernel the selection tiers (PerfDB / generated
# rules / hand-crafted) may be asked about. The gather variants are distinct
# keys because their measured profiles differ (mean carries an in-kernel
# count, max forces the SR walk); segment_softmax consumes only (S_b, M_b).
OP_KEYS = (
    "segment_reduce",
    "gather_segment_reduce",
    "gather_segment_reduce_mean",
    "gather_segment_reduce_max",
    "segment_softmax",
    "segment_matmul",
    "grouped_segment_matmul",
    "sddmm",
    "fused_transform_reduce",
)

# Pruned candidate ranges (paper §III-C prunes to constant space; ours are
# anchored to (8,128) tiling and MXU dims instead of warp sizes).
SCHEDULES = ("SR", "PR")
S_B_CANDIDATES = (64, 128, 256)
N_B_CANDIDATES = (128, 256, 512)
M_B_CANDIDATES = (128, 256, 512, 1024)
K_C_CANDIDATES = (8, 16, 32)


def enumerate_configs(feat_dim: int | None = None,
                      dtype_bytes: int = 4) -> Iterator[KernelConfig]:
    """All valid configs (VMEM-feasible; N_b ≤ padded feature dim)."""
    for sched in SCHEDULES:
        kcs = (1,) if sched == "SR" else K_C_CANDIDATES
        for s_b, n_b, m_b, k_c in itertools.product(
                S_B_CANDIDATES, N_B_CANDIDATES, M_B_CANDIDATES, kcs):
            cfg = KernelConfig(sched, s_b, n_b, m_b, k_c)
            if cfg.vmem_bytes(dtype_bytes) > VMEM_BYTES:
                continue
            if k_c > m_b:
                continue
            if feat_dim is not None and n_b > max(LANES, _round_up(feat_dim, LANES)):
                continue
            yield cfg


def all_configs(feat_dim: int | None = None) -> List[KernelConfig]:
    return list(enumerate_configs(feat_dim))


def default_config(feat_dim: int = 128) -> KernelConfig:
    """Static fallback (the 'hand-crafted rule' baseline of Fig. 8):
    SR for F > 4 else PR, mirroring the paper's empirical rule."""
    if feat_dim > 4:
        return KernelConfig("SR", 128, min(512, _round_up(feat_dim, LANES)), 512, 1)
    return KernelConfig("PR", 128, 128, 256, 16)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
