"""Sharded message passing over a device mesh (data -> plan -> mp -> models).

The single-device :mod:`repro.core.mp` primitive becomes a two-stage
program on a 1-D ``"shard"`` mesh:

  1. **local** — every shard runs the *same* single-launch fused Pallas
     aggregation (:mod:`repro.kernels.gather_segment_reduce` /
     :mod:`repro.kernels.segment_softmax`) over its own edge shard, with a
     per-shard :class:`~repro.core.plan.SegmentPlan` sliced out of a
     stacked :class:`~repro.core.plan.PartitionedPlan`. Features are read
     shard-locally (edges live with their source node — see
     :mod:`repro.data.partition`), so the gather never crosses the mesh.
  2. **merge** — cut-edge (halo) contributions are combined across shards
     with the reduce's own algebra:

       sum      psum (or :func:`repro.distributed.collectives.ring_allreduce`)
       mean     psum of the partial *sums* and of the per-destination
                *counts*, then one divide — never an average of averages
       max      pmax, rendered as ``all_gather`` + max so the merge stays
                differentiable (``lax.pmax`` has no differentiation rule).
                At *tied* maxima spanning shards the gradient is a valid
                subgradient (it sums to the cotangent over each segment)
                but may split ties differently than the single-device
                even split — exact tie parity would require
                re-materializing the (|E|, F) message tensor, the very
                thing the fused kernels avoid; ties are measure-zero for
                continuous features
       softmax  two-stage online-softmax stat merge: each shard's fused
                kernel output is exact w.r.t. its local statistics; the
                global answer is a per-segment rescale by ``z_loc/z_glob``
                with both sum-exps measured at the pmax'd global max

All entry points accept *global* arrays (node features ``(V, F)``,
per-edge values ``(E,)`` in the graph's dst-sorted order) and return the
replicated global result, so a sharded call is a drop-in replacement for
its single-device twin — ``mp_sharded(x, pg, ...) == mp(x, edge_index,
...)`` up to float-summation order.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

from repro.core import ops as geot
from repro.core.config_space import KernelConfig

__all__ = ["make_shard_mesh", "mp_sharded", "mp_transform_sharded",
           "segment_softmax_sharded"]

_AXIS = "shard"


def make_shard_mesh(num_shards: int, axis_name: str = _AXIS) -> Mesh:
    """A 1-D mesh over the first ``num_shards`` local devices. Host
    platforms fake the device count via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devs = jax.devices()
    if len(devs) < num_shards:
        raise ValueError(
            f"mesh needs {num_shards} devices, found {len(devs)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
    import numpy as np
    return Mesh(np.asarray(devs[:num_shards]), (axis_name,))


def _check(pg, mesh: Optional[Mesh], axis_name: str) -> Mesh:
    mesh = make_shard_mesh(pg.num_shards, axis_name) if mesh is None else mesh
    if mesh.shape[axis_name] != pg.num_shards:
        raise ValueError(
            f"mesh axis {axis_name!r} has {mesh.shape[axis_name]} devices "
            f"but the partition has {pg.num_shards} shards")
    return mesh


def _allreduce(y, axis_name: str, collective: str):
    if collective == "ring":
        from repro.distributed import collectives
        return collectives.ring_allreduce(y, axis_name)
    if collective != "psum":
        raise ValueError(f"unknown collective: {collective!r}")
    return jax.lax.psum(y, axis_name)


def _pmax(y, axis_name: str):
    # pmax with a VJP: all-gather the shard partials and reduce with jnp.max
    # (lax.pmax itself has no differentiation rule)
    return jnp.max(jax.lax.all_gather(y, axis_name), axis=0)


def _edge_stack(pg, vals):
    """Per-edge values -> stacked (S, E_pad, ...): accepts global (E, ...)
    order or an already-stacked array (e.g. sharded softmax output)."""
    vals = jnp.asarray(vals)
    if vals.ndim >= 2 and vals.shape[:2] == (pg.num_shards,
                                             pg.edges_per_shard):
        return vals
    if vals.shape[:1] == (pg.num_edges,):
        return pg.shard_edges(vals)
    raise ValueError(
        f"per-edge values must be global ({pg.num_edges}, ...) or stacked "
        f"({pg.num_shards}, {pg.edges_per_shard}, ...), got {vals.shape}")


def mp_sharded(x, pg, *, reduce: str = "sum", edge_weight=None, pplan=None,
               mesh: Optional[Mesh] = None, impl: str = "pallas",
               config: Optional[KernelConfig] = None,
               collective: str = "psum", axis_name: str = _AXIS):
    """Sharded message passing: ``Y[d] = reduce_{(s,d) in E} (w_e *) X[s]``
    over a :class:`~repro.data.partition.PartitionedGraph`.

    ``x``: global (V, F) node features; ``edge_weight``: global (E,) or
    stacked (S, E_pad) per-edge weights; ``pplan``: a
    :class:`~repro.core.plan.PartitionedPlan` (built on demand when
    omitted). Returns the replicated global (V, F) aggregate, matching
    ``core.mp.mp`` (max fills empty neighbourhoods with 0)."""
    if reduce not in ("sum", "mean", "max"):
        raise ValueError(f"unknown reduce: {reduce!r}")
    mesh = _check(pg, mesh, axis_name)
    if pplan is None:
        pplan = pg.make_plan(feat=int(x.shape[-1]), config=config)
    v = pg.num_nodes
    x_stack = pg.shard_nodes(x)
    w_stack = None if edge_weight is None else _edge_stack(pg, edge_weight)
    # mean = psum of the local fused *sums* and of the per-destination
    # counts, then one divide — the halo-correct algebra (never a mean of
    # means). The count psum is static partition metadata, already merged
    # into pg.deg at partition time, so the runtime pays one collective.
    kernel_reduce = "sum" if reduce == "mean" else reduce

    def local(xb, sb, db, cfb, ccb, degb, wb):
        plan = pplan.local_plan(cfb, ccb)
        if wb is None:
            part = geot.index_segment_reduce(xb[0], sb[0], db[0], v,
                                             kernel_reduce, impl, None, plan)
        else:
            part = geot.index_weight_segment_reduce(xb[0], sb[0], wb[0],
                                                    db[0], v, kernel_reduce,
                                                    impl, None, plan)
        if reduce == "max":
            y = _pmax(part, axis_name)
            return jnp.where(y == -jnp.inf, jnp.zeros_like(y), y)
        s = _allreduce(part, axis_name, collective)
        if reduce == "mean":
            s = s / jnp.maximum(degb, 1.0)[:, None].astype(s.dtype)
        return s

    args = [x_stack, pg.src_local, pg.dst_global, pplan.chunk_first,
            pplan.chunk_count]
    in_specs = [PS(axis_name)] * 5 + [PS()]    # deg rides replicated
    args.append(pg.deg)
    if w_stack is None:
        fn = lambda a, b, c, d, e, f: local(a, b, c, d, e, f, None)  # noqa: E731
    else:
        fn, args, in_specs = local, args + [w_stack], in_specs + [PS(axis_name)]
    return shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=PS(), check_rep=False)(*args)


def mp_transform_sharded(x, w, pg, *, reduce: str = "sum", edge_weight=None,
                         pplan=None, mesh: Optional[Mesh] = None,
                         impl: str = "pallas",
                         config: Optional[KernelConfig] = None,
                         collective: str = "psum", order: str = "auto",
                         axis_name: str = _AXIS):
    """Sharded ``mp_transform``: aggregate(X·W) or aggregate(X)·W with the
    same cost-model reordering as the single-device path — the dense
    matmul runs on the replicated side of the mesh, the aggregation runs
    fused per shard. Non-linear reduces (``max``) pin transform-first
    (one shared resolver with ``mp_transform``: :func:`.mp.resolve_order`)."""
    from repro.core.mp import resolve_order
    # allow_fused=False: the one-launch SpMM+GEMM arm is single-device only —
    # the sharded reduce's collective merge (psum of partial aggregates /
    # mean counts) must happen *between* aggregate and transform, so the
    # per-shard (S, d_in) partials have to surface
    order = resolve_order(reduce, order, int(x.shape[-1]),
                          int(w.shape[-1]), plan=pplan,
                          num_edges=pg.num_edges, num_nodes=pg.num_nodes,
                          config=config, allow_fused=False)
    kw = dict(reduce=reduce, edge_weight=edge_weight, pplan=pplan, mesh=mesh,
              impl=impl, config=config, collective=collective,
              axis_name=axis_name)
    if order == "aggregate_first":
        return mp_sharded(x, pg, **kw) @ w
    return mp_sharded(x @ w, pg, **kw)


def segment_softmax_sharded(e, pg, *, pplan=None, mesh: Optional[Mesh] = None,
                            impl: str = "pallas",
                            config: Optional[KernelConfig] = None,
                            axis_name: str = _AXIS):
    """Sharded segment softmax over destinations (GAT attention).

    ``e``: global (E,) or (E, H) logits. Each shard runs the fused
    single-launch softmax kernel over its local edges, then the local
    answers are corrected by the two-stage online-softmax merge:

        m_glob = pmax_s(segment_max(e))          (running max)
        z_loc  = segment_sum(exp(e - m_glob))    (sum-exp at the global max)
        p      = p_loc * z_loc / psum_s(z_loc)

    Segments fully local to one shard rescale by exactly 1. Returns the
    **stacked** (S, E_pad[, H]) attention weights — feed them straight
    back into :func:`mp_sharded` as ``edge_weight``, or map to global
    order with :func:`repro.data.partition.unpartition_edges`."""
    mesh = _check(pg, mesh, axis_name)
    if pplan is None:
        feat = int(e.shape[-1]) if jnp.ndim(e) > 1 else 1
        pplan = pg.make_plan(feat=feat, config=config)
    v = pg.num_nodes
    e_stack = _edge_stack(pg, e)

    def local(eb, db, vb, cfb, ccb):
        el, dl, valid = eb[0], db[0], vb[0]
        plan = pplan.local_plan(cfb, ccb)
        p_loc = geot.segment_softmax(el, dl, v, impl, None, plan)
        # the merge's (m, z) statistics run as jnp segment ops — recorded
        # under "merge:" so the fusion accounting stays honest: they are
        # the collective halo algebra, not a fallback of the aggregation
        # (which is the fused p_loc launch above)
        from repro.kernels import ops as kops
        kops.account("merge", "segment_softmax_stats")
        # local online stats over valid edges only (padding carries
        # dst == V and drops out of the scatter)
        squeeze = el.ndim == 1
        e2 = el[:, None] if squeeze else el
        m_loc = jax.lax.stop_gradient(jax.ops.segment_max(
            e2, dl, v, indices_are_sorted=True))
        m_glob = _pmax(m_loc, axis_name)
        m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
        z_loc = jax.ops.segment_sum(
            jnp.exp(e2 - jnp.take(m_safe, dl, axis=0, mode="fill",
                                  fill_value=0))
            * valid[:, None].astype(e2.dtype),
            dl, v, indices_are_sorted=True)
        z_glob = jax.lax.psum(z_loc, axis_name)
        # z_loc is this shard's sum-exp measured at the *global* max, so
        # p_glob = p_loc * z_loc / z_glob per segment (the exp(m_loc - m_glob)
        # of the textbook merge is already inside z_loc); locally-empty
        # segments have z_loc = 0 and never feed a local edge
        factor = z_loc / jnp.maximum(z_glob, 1e-20)
        p2 = (p_loc[:, None] if squeeze else p_loc)
        p2 = jnp.where(
            valid[:, None],
            p2 * jnp.take(factor, dl, axis=0, mode="fill", fill_value=0),
            0.0)
        return p2[:, 0] if squeeze else p2

    out = shard_map(local, mesh=mesh, in_specs=(PS(axis_name),) * 5,
                    out_specs=PS(axis_name), check_rep=False)(
        e_stack, pg.dst_global, pg.edge_valid, pplan.chunk_first,
        pplan.chunk_count)
    # out_specs concatenate the per-shard blocks; restack to (S, E_pad, ...)
    return out.reshape(pg.num_shards, pg.edges_per_shard, *out.shape[1:])
