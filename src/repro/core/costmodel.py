"""Analytical TPU cost model for blocked segment reduction.

Plays two roles (DESIGN.md §7):
  1. Populates the performance database on this CPU-only container (the
     paper benchmarks configs on an A100; we derive GFlops from a v5e
     roofline model instead — the *pipeline* downstream of the database is
     identical to the paper's).
  2. Provides the per-config napkin math used in §Perf hillclimbing.

Model (see DESIGN.md §2 for the schedule mapping):

grid = (ceil(S / S_b) out-blocks) × (ceil(N / N_b) col-tiles) × (chunks).
Each out-block consumes its input row range [row_ptr[b], row_ptr[b+1]) in
chunks of M_b rows; boundary chunks are re-read by adjacent out-blocks.

  PR (MXU):  per chunk, one-hot P (M_b × S_b) is built on the VPU and
             out += Pᵀ @ X on the MXU in ceil(M_b/K_c) sub-matmuls of
             contraction depth K_c (deeper ⇒ better pipeline utilisation).
  SR (VPU):  per chunk, a sequential row walk, vectorized across N lanes;
             each segment end costs a dynamic-slice flush.

All times in seconds.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.config_space import KernelConfig, LANES, SUBLANES


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12       # per chip
    peak_flops_fp32: float = 98.5e12      # MXU fp32 ~ half
    hbm_bw: float = 819e9                 # bytes/s
    vpu_flops: float = 4 * 8 * 128 * 0.94e9  # 4 ALUs × (8,128) regs × clock
    ici_bw: float = 50e9                  # bytes/s per link (≈ 45-50 GB/s)
    grid_step_overhead: float = 0.3e-6    # s per grid step (scalar core)
    dyn_store_cycles: float = 16.0        # VMEM dynamic-row store
    clock: float = 0.94e9


V5E = TpuSpec()


def _ceil(a: float, b: float) -> int:
    return int(math.ceil(a / b))


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute_s: float
    memory_s: float
    overhead_s: float

    @property
    def total_s(self) -> float:
        # compute/memory overlap (double-buffered DMA); overhead serializes
        return max(self.compute_s, self.memory_s) + self.overhead_s

    def gflops(self, useful_flops: float) -> float:
        return useful_flops / self.total_s / 1e9


def segment_reduce_cost(m: int, s: int, n: int, cfg: KernelConfig,
                        dtype_bytes: int = 4, spec: TpuSpec = V5E,
                        skew: float = 1.0) -> CostBreakdown:
    """Cost of one blocked segment reduction.

    m: input rows (|E|), s: segments (|V|), n: feature dim F.
    skew ≥ 1 inflates the chunk count of the heaviest out-block
    (power-law degree distributions make max_chunks > mean_chunks)."""
    n_pad = max(n, LANES)                      # lane padding below 128
    n_tiles = _ceil(n_pad, cfg.n_b)
    n_b_eff = min(cfg.n_b, n_pad)
    out_blocks = _ceil(s, cfg.s_b)

    rows_per_block = m / out_blocks
    chunks_per_block = max(1.0, rows_per_block / cfg.m_b)
    # boundary chunks shared with the neighbouring out-block are re-read
    reread_rows = min(2 * cfg.m_b, rows_per_block) * (out_blocks - 1)
    total_rows_read = m + max(0.0, reread_rows)

    # ---- memory ----
    x_bytes = total_rows_read * n_b_eff * dtype_bytes * n_tiles
    idx_bytes = total_rows_read * 4 * n_tiles
    y_bytes = s * n_pad * dtype_bytes
    memory_s = (x_bytes + idx_bytes + y_bytes) / spec.hbm_bw

    # ---- compute ----
    if cfg.schedule == "PR":
        # one-hot build on the VPU + Pᵀ@X on the MXU
        onehot_ops = total_rows_read * cfg.s_b * n_tiles
        vpu_s = onehot_ops / spec.vpu_flops
        macs = total_rows_read * cfg.s_b * n_b_eff * n_tiles
        peak = spec.peak_flops_bf16 if dtype_bytes == 2 else spec.peak_flops_fp32
        # MXU efficiency: output-tile padding × contraction pipeline fill
        pad_eff = (min(cfg.s_b, 128) / 128.0) * (min(n_b_eff, 128) / 128.0)
        pipe_eff = cfg.k_c / (cfg.k_c + 4.0)
        mxu_s = 2.0 * macs / (peak * max(pad_eff, 1e-3) * pipe_eff)
        compute_s = vpu_s + mxu_s
    else:
        # sequential row walk: one (1, N_b) VREG add per row; rows do not
        # parallelize, so the effective width is n_b_eff lanes only
        row_cycles = max(1.0, n_b_eff / LANES) * (SUBLANES / 8.0)
        walk_s = total_rows_read * row_cycles * n_tiles / spec.clock
        flush_s = min(m, s + out_blocks) * spec.dyn_store_cycles / spec.clock * n_tiles
        compute_s = walk_s + flush_s

    # ---- grid overhead ----
    grid_steps = out_blocks * n_tiles * max(1, int(chunks_per_block * skew))
    overhead_s = grid_steps * spec.grid_step_overhead

    return CostBreakdown(compute_s, memory_s, overhead_s)


def useful_flops(m: int, n: int) -> float:
    """One add per input element is the useful work of a segment sum."""
    return float(m) * float(n)


def spmm_cost(m: int, s: int, n: int, cfg: KernelConfig,
              dtype_bytes: int = 4, spec: TpuSpec = V5E,
              skew: float = 1.0) -> CostBreakdown:
    """Fused gather + weight + segment reduce (index_weight_segment_reduce).

    Adds the gather traffic of H rows (random access ⇒ DMA granularity
    penalty when N_b*dtype < 512B) and the per-edge multiply. ``skew``
    (max/avg degree, from a SegmentPlan's stats) inflates the heaviest
    block's chunk count exactly as in :func:`segment_reduce_cost` — the
    degree distribution feeds the mp transform/aggregate reordering."""
    base = segment_reduce_cost(m, s, n, cfg, dtype_bytes, spec, skew=skew)
    n_pad = max(n, LANES)
    n_tiles = _ceil(n_pad, cfg.n_b)
    n_b_eff = min(cfg.n_b, n_pad)
    row_bytes = n_b_eff * dtype_bytes
    dma_eff = min(1.0, row_bytes / 512.0)      # 512B DMA granularity
    gather_bytes = m * row_bytes * n_tiles / max(dma_eff, 1e-3)
    mul_s = m * n_b_eff * n_tiles / spec.vpu_flops
    return CostBreakdown(base.compute_s + mul_s,
                         base.memory_s + gather_bytes / spec.hbm_bw,
                         base.overhead_s)


def dense_matmul_cost(rows: int, d_in: int, d_out: int,
                      dtype_bytes: int = 4,
                      spec: TpuSpec = V5E) -> CostBreakdown:
    """Plain (rows, d_in) @ (d_in, d_out) on the MXU — the dense half of the
    two-launch ``mp_transform`` orders (X@W transforms |V| rows, Agg(X)@W
    transforms |S| rows). Needed once the fused arm joins the comparison:
    the dense matmul no longer cancels between the candidates."""
    din = max(d_in, LANES)
    dout = max(d_out, LANES)
    bytes_ = (rows * din + din * dout + rows * dout) * dtype_bytes
    peak = spec.peak_flops_bf16 if dtype_bytes == 2 else spec.peak_flops_fp32
    compute_s = 2.0 * rows * din * dout / peak
    steps = _ceil(rows, 128) * _ceil(dout, 128)
    return CostBreakdown(compute_s, bytes_ / spec.hbm_bw,
                         steps * spec.grid_step_overhead)


def fused_transform_reduce_cost(m: int, s: int, d_in: int, d_out: int,
                                cfg: KernelConfig, dtype_bytes: int = 4,
                                spec: TpuSpec = V5E,
                                skew: float = 1.0) -> CostBreakdown:
    """One-launch SpMM+GEMM (:mod:`repro.kernels.fused_transform_reduce`).

    Aggregates at full d_in width with **no feature tiling** (each input row
    is gathered exactly once — the width-tiled gather kernel re-reads rows
    ``n_tiles`` times) and runs the dense transform in-kernel against the
    VMEM-resident W, so the (S, d_in) aggregate never round-trips HBM: the
    two-launch aggregate-first path pays ``2·S·d_in·bytes`` (write + re-read)
    plus a second launch's grid overhead that this arm simply does not have."""
    din = max(d_in, LANES)
    dout = max(d_out, LANES)
    # aggregation at full width — n_b covers d_in, so n_tiles == 1
    wide = dataclasses.replace(cfg, n_b=_ceil(din, LANES) * LANES)
    base = spmm_cost(m, s, d_in, wide, dtype_bytes, spec, skew=skew)
    # in-kernel GEMM: one (S_b, d_in)·(d_in, d_out) per out-block
    peak = spec.peak_flops_bf16 if dtype_bytes == 2 else spec.peak_flops_fp32
    gemm_s = 2.0 * s * din * dout / peak
    # W is DMA'd once (constant index map); output is (S, d_out) instead of
    # the (S, d_in) the aggregation-only model charged
    extra_bytes = din * dout * dtype_bytes + s * (dout - din) * dtype_bytes
    return CostBreakdown(base.compute_s + gemm_s,
                         base.memory_s + max(extra_bytes, 0) / spec.hbm_bw,
                         base.overhead_s)
