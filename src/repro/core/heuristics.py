"""Runtime config selection (paper Fig. 5, right side).

Order of precedence:
  1. measured config from the wall-clock autotuner's :class:`PerfDB`
     (opt-in: ``tune=True`` / ``REPRO_AUTOTUNE=1``) — the paper's actual
     design point: the perf database is swept with real executions;
  2. generated rules (``_generated_rules.py``, produced by
     ``python -m repro.core.train_rules``) — the deployed O(ns) path;
  3. the hand-crafted static rule (Fig. 8's baseline) as fallback.
"""
from __future__ import annotations

import math
import warnings

from repro.core.config_space import OP_KEYS, KernelConfig, default_config

try:  # the generated module is committed, but keep the fallback honest
    from repro.core import _generated_rules
except ImportError:  # pragma: no cover
    _generated_rules = None


def select_config(idx_size: int, num_segments: int, feat: int, *,
                  op: str = "segment_reduce", tune: "bool | None" = None,
                  db=None, io_dtype: str = "float32") -> KernelConfig:
    """Pick ⟨schedule, S_b, N_b, M_b, K_c⟩ from O(1) features.

    ``tune=None`` defers to the ``REPRO_AUTOTUNE`` env var; ``tune=True``
    engages the measured tier explicitly (sweeping once per shape class,
    cached in the :class:`~repro.core.autotune.PerfDB` thereafter);
    ``tune=False`` pins the selection to the generated rules. ``db`` is an
    optional explicit PerfDB (tests / hermetic CI). ``io_dtype`` selects the
    precision shelf of the measured tier — lowered-precision kernels have
    different bandwidth/compute balance, so bf16 sweeps are cached under
    their own PerfDB keys; the rule tiers are dtype-blind."""
    if op not in OP_KEYS:
        raise ValueError(f"unknown op {op!r}; registered: {OP_KEYS}")
    if tune is None:
        from repro.core.autotune import autotune_enabled
        tune = autotune_enabled()
    if tune:
        cfg = _tuned_config(op, idx_size, num_segments, feat, db, io_dtype)
        if cfg is not None:
            return cfg
    if _generated_rules is None:
        return default_config(feat)
    log2_size = math.log2(max(idx_size, 1))
    avg = idx_size / max(num_segments, 1)
    log2_avg = math.log2(max(avg, 2 ** -4))
    log2_feat = math.log2(max(feat, 1))
    return _generated_rules.select(log2_size, log2_avg, log2_feat)


def _tuned_config(op: str, idx_size: int, num_segments: int, feat: int,
                  db, io_dtype: str = "float32") -> "KernelConfig | None":
    """Measured tier: tune-or-lookup; never let a measurement failure take
    down selection — fall through to the rule tiers instead."""
    from repro.core import autotune
    try:
        return autotune.tune(op=op, idx_size=int(idx_size),
                             num_segments=int(num_segments), feat=int(feat),
                             db=db, io_dtype=io_dtype).config
    except Exception as exc:  # pragma: no cover - defensive
        warnings.warn(f"autotune failed for op={op!r} ({exc!r}); "
                      "falling back to generated rules", RuntimeWarning)
        return None


def hand_crafted_config(idx_size: int, num_segments: int,
                        feat: int) -> KernelConfig:
    """The engineering-experience baseline of Fig. 8 (explicitly kept for
    the ablation benchmark)."""
    return default_config(feat)
