"""Runtime config selection (paper Fig. 5, right side).

Order of precedence:
  1. generated rules (``_generated_rules.py``, produced by
     ``python -m repro.core.train_rules``) — the deployed path;
  2. the hand-crafted static rule (Fig. 8's baseline) as fallback.
"""
from __future__ import annotations

import math

from repro.core.config_space import KernelConfig, default_config

try:  # the generated module is committed, but keep the fallback honest
    from repro.core import _generated_rules
except ImportError:  # pragma: no cover
    _generated_rules = None


def select_config(idx_size: int, num_segments: int, feat: int) -> KernelConfig:
    """Pick ⟨schedule, S_b, N_b, M_b, K_c⟩ from O(1) features."""
    if _generated_rules is None:
        return default_config(feat)
    log2_size = math.log2(max(idx_size, 1))
    avg = idx_size / max(num_segments, 1)
    log2_avg = math.log2(max(avg, 2 ** -4))
    log2_feat = math.log2(max(feat, 1))
    return _generated_rules.select(log2_size, log2_avg, log2_feat)


def hand_crafted_config(idx_size: int, num_segments: int,
                        feat: int) -> KernelConfig:
    """The engineering-experience baseline of Fig. 8 (explicitly kept for
    the ablation benchmark)."""
    return default_config(feat)
