"""Precomputed reduction plans (GeoT §III-C data-awareness, amortized).

A :class:`SegmentPlan` captures, *once per graph*, everything the Pallas
segment-reduction kernels otherwise derive on every call:

  * ``chunk_first`` / ``chunk_count`` — the per-output-block chunk range over
    the padded input-row space (the scalar-prefetched schedule metadata);
  * a **tight** ``max_chunks`` — the maximum number of input chunks actually
    owned by any output block. The plan-less path must assume the worst case
    (``m_pad // m_b``: one block owns every row), so the kernel grid's chunk
    dimension is O(M / m_b); with a plan it is O(actual skew);
  * degree statistics of the segment index (for the data-aware heuristic /
    decision-tree config selection, paper Fig. 5);
  * the selected :class:`~repro.core.config_space.KernelConfig`.

Plans are registered pytrees: the chunk arrays are leaves (device arrays,
jit/vmap/grad-transparent) while sizes, the config, and the statistics are
static aux data — so a plan threads through ``jax.jit`` boundaries without
retriggering compilation as long as the *schedule* is unchanged.

Build a plan with :func:`make_plan` (raw sorted index) or
:func:`make_graph_plan` (``edge_index`` convention of the GNN stack), then
pass it to ``segment_reduce`` / ``index_segment_reduce`` /
``index_weight_segment_reduce`` via ``plan=``. FASTEN (ICS'24) measures that
exactly this amortization — metadata built once, reused across layers and
training steps — is where fused segment ops win end-to-end; see
``docs/plans.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config_space import KernelConfig

__all__ = ["SegmentStats", "SegmentPlan", "PartitionedPlan", "RelationPlan",
           "make_plan", "make_graph_plan", "make_partitioned_plan",
           "make_relation_plan"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class SegmentStats:
    """O(|V|) degree statistics of a sorted segment index (static metadata)."""
    num_rows: int            # M = |E| (index length)
    num_segments: int        # S (output rows)
    live_segments: int       # segments with >= 1 row (gapped ids shrink this)
    max_degree: int          # heaviest segment
    avg_degree: float        # M / max(live_segments, 1)
    std_degree: float        # over live segments

    @property
    def skew(self) -> float:
        """max/avg degree — the load-imbalance the tight grid exploits."""
        return self.max_degree / max(self.avg_degree, 1e-9)


def segment_stats(idx: np.ndarray, num_segments: int) -> SegmentStats:
    idx = np.asarray(idx)
    m = int(idx.size)
    if m == 0:
        return SegmentStats(0, num_segments, 0, 0, 0.0, 0.0)
    deg = np.bincount(idx, minlength=num_segments)
    live = deg[deg > 0]
    return SegmentStats(
        num_rows=m,
        num_segments=num_segments,
        live_segments=int(live.size),
        max_degree=int(deg.max()),
        avg_degree=float(m / max(live.size, 1)),
        std_degree=float(live.std()) if live.size else 0.0,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """Precomputed schedule for one (sorted idx, num_segments) instance.

    Leaves: ``chunk_first`` / ``chunk_count`` (int32, shape (out_blocks,)).
    Aux (static): sizes, the tight ``max_chunks``, the selected ``config``,
    and :class:`SegmentStats`.
    """
    chunk_first: jax.Array
    chunk_count: jax.Array
    num_rows: int
    num_segments: int
    max_chunks: int          # tight: max(chunk_count), >= 1
    config: KernelConfig
    stats: SegmentStats

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.chunk_first, self.chunk_count)
        aux = (self.num_rows, self.num_segments, self.max_chunks,
               self.config, self.stats)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        chunk_first, chunk_count = children
        num_rows, num_segments, max_chunks, config, stats = aux
        return cls(chunk_first, chunk_count, num_rows, num_segments,
                   max_chunks, config, stats)

    # -- introspection ------------------------------------------------------
    @property
    def worst_case_chunks(self) -> int:
        """The chunk-grid bound the plan-less kernel must assume."""
        return _round_up(max(self.num_rows, 1), self.config.m_b) // self.config.m_b

    @property
    def grid_savings(self) -> float:
        """worst-case / tight chunk-dim ratio (>= 1; higher = more skew won)."""
        return self.worst_case_chunks / max(self.max_chunks, 1)

    def pin_worst_case(self) -> "SegmentPlan":
        """The same plan with ``max_chunks`` pinned to the shape-static
        worst case — the canonicalization every bucket-reuse path (serving
        templates, per-bucket train steps, sampled batches) applies so
        that plans for *different* graphs padded to one (M, S) shape share
        a treedef and never retrace the executable. Returns ``self`` when
        already pinned; the tight bound is recoverable only by replanning
        (it is data, not shape)."""
        if self.max_chunks == self.worst_case_chunks:
            return self
        return dataclasses.replace(self, max_chunks=self.worst_case_chunks)

    def validate(self, num_rows: int, num_segments: int) -> None:
        """Trace-time consistency check against the arrays of an op call."""
        if num_rows != self.num_rows or num_segments != self.num_segments:
            raise ValueError(
                f"SegmentPlan built for (M={self.num_rows}, "
                f"S={self.num_segments}) used with (M={num_rows}, "
                f"S={num_segments}); rebuild the plan for this graph.")


def make_plan(idx, num_segments: int, feat: int = 128,
              config: Optional[KernelConfig] = None,
              tune: Optional[bool] = None) -> SegmentPlan:
    """Build a :class:`SegmentPlan` from a *concrete* sorted segment index.

    ``idx`` must be host-available (numpy or committed jax array) — plans are
    built once per graph outside jit, then reused inside it. ``feat`` is the
    representative feature width fed to the config heuristic (use the widest
    layer width; only the selected config depends on it, not correctness).

    ``tune=True`` engages the wall-clock autotuner as the top selection tier
    (measured sweep, cached per shape class in the
    :class:`~repro.core.autotune.PerfDB`); ``tune=None`` defers to the
    ``REPRO_AUTOTUNE`` env var. Plan construction is the natural place to
    pay the one-off tuning cost: it already runs once per graph, outside jit.
    """
    idx_np = np.asarray(idx).astype(np.int32)
    if idx_np.ndim != 1:
        raise ValueError(f"idx must be 1-D, got shape {idx_np.shape}")
    if idx_np.size and np.any(idx_np[1:] < idx_np[:-1]):
        raise ValueError("idx must be sorted non-decreasing")
    stats = segment_stats(idx_np, num_segments)

    if config is None:
        from repro.core.heuristics import select_config
        # data-aware selection: the *live* segment count drives avg degree,
        # so gapped ids (batched / masked graphs) do not dilute the feature
        config = select_config(max(int(idx_np.size), 1),
                               max(stats.live_segments, 1), feat, tune=tune)

    m = int(idx_np.size)
    s_b, m_b = config.s_b, config.m_b
    m_pad = _round_up(max(m, 1), m_b)
    idxp = np.full((m_pad,), num_segments, np.int32)
    idxp[:m] = idx_np

    # the kernel's own metadata helper, evaluated concretely on the host —
    # one formula, so plans can never drift from the per-call path
    from repro.kernels.segment_reduce import chunk_metadata
    chunk_first, chunk_count = chunk_metadata(idxp, num_segments, s_b, m_b,
                                              m_pad)
    chunk_count_np = np.asarray(chunk_count)
    max_chunks = max(1, int(chunk_count_np.max())) if chunk_count_np.size else 1
    return SegmentPlan(
        chunk_first=jnp.asarray(chunk_first),
        chunk_count=jnp.asarray(chunk_count),
        num_rows=m,
        num_segments=int(num_segments),
        max_chunks=max_chunks,
        config=config,
        stats=stats,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionedPlan:
    """Per-shard :class:`SegmentPlan` metadata with **stacked** leaves, so
    the whole plan rides ``shard_map`` with ``PartitionSpec("shard")``.

    All shards share one static program: a common ``config``, a common
    padded row count (``num_rows = edges_per_shard``), the *global* segment
    space (``num_segments = |V|``), and one ``max_chunks`` — the max over
    every shard's tight bound (shard_map traces a single kernel grid).
    ``stats`` describe the *global* index, feeding the same cost-model
    decisions (transform/aggregate reordering) as a single-device plan.
    """
    chunk_first: jax.Array   # (num_shards, out_blocks) int32
    chunk_count: jax.Array   # (num_shards, out_blocks) int32
    num_shards: int
    num_rows: int            # E_pad: padded rows per shard
    num_segments: int        # V: the global output space every shard targets
    max_chunks: int          # max over shards' tight bounds, >= 1
    config: KernelConfig
    stats: SegmentStats      # of the global (unpartitioned) index

    def tree_flatten(self):
        children = (self.chunk_first, self.chunk_count)
        aux = (self.num_shards, self.num_rows, self.num_segments,
               self.max_chunks, self.config, self.stats)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def local_plan(self, chunk_first, chunk_count) -> SegmentPlan:
        """The one-shard :class:`SegmentPlan` seen inside ``shard_map``
        (``chunk_first``/``chunk_count``: this shard's (1, out_blocks) or
        (out_blocks,) slices of the stacked leaves)."""
        if chunk_first.ndim == 2:
            chunk_first, chunk_count = chunk_first[0], chunk_count[0]
        return SegmentPlan(chunk_first, chunk_count, self.num_rows,
                           self.num_segments, self.max_chunks, self.config,
                           self.stats)


def make_partitioned_plan(pg, feat: int = 128,
                          config: Optional[KernelConfig] = None,
                          tune: Optional[bool] = None) -> PartitionedPlan:
    """Build one :class:`PartitionedPlan` for a
    :class:`~repro.data.partition.PartitionedGraph`.

    The config is selected once from the per-shard workload (each kernel
    launch reduces ``edges_per_shard`` rows into the global segment space);
    the chunk metadata is evaluated per shard over its padded local dst
    index — padding slots carry ``dst = num_nodes`` and drop out of every
    output window, the same convention :func:`make_plan` uses for row
    padding."""
    dst = np.asarray(pg.dst_global)              # (S, E_pad), pad = V
    valid = np.asarray(pg.edge_valid)
    v = int(pg.num_nodes)
    stats = segment_stats(np.sort(dst[valid]).astype(np.int32), v)

    if config is None:
        from repro.core.heuristics import select_config
        live_per_shard = max(
            max((int(np.unique(dst[s][valid[s]]).size)
                 for s in range(pg.num_shards)), default=0), 1)
        config = select_config(max(int(pg.edges_per_shard), 1),
                               live_per_shard, feat, tune=tune)

    s_b, m_b = config.s_b, config.m_b
    m_pad = _round_up(max(int(pg.edges_per_shard), 1), m_b)
    from repro.kernels.segment_reduce import chunk_metadata
    cf_list, cc_list, max_chunks = [], [], 1
    for s in range(pg.num_shards):
        idxp = np.full((m_pad,), v, np.int32)
        idxp[:dst.shape[1]] = dst[s]
        cf, cc = chunk_metadata(idxp, v, s_b, m_b, m_pad)
        cc_np = np.asarray(cc)
        if cc_np.size:
            max_chunks = max(max_chunks, int(cc_np.max()))
        cf_list.append(np.asarray(cf))
        cc_list.append(cc_np)
    return PartitionedPlan(
        chunk_first=jnp.asarray(np.stack(cf_list)),
        chunk_count=jnp.asarray(np.stack(cc_list)),
        num_shards=int(pg.num_shards),
        num_rows=int(pg.edges_per_shard),
        num_segments=v,
        max_chunks=max_chunks,
        config=config,
        stats=stats,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RelationPlan:
    """Precomputed schedule for one grouped-matmul instance (the typed-edge
    analogue of :class:`SegmentPlan`): which relation groups each M_b row
    block of the grouped ``segment_matmul`` grid overlaps, evaluated once
    per typed graph on the host instead of per call at trace time.

    Leaves: ``offsets`` (R+1,), ``first_group`` / ``group_count``
    (int32, (m_blocks,)) — the scalar-prefetch operands of
    :func:`repro.kernels.segment_matmul.segment_matmul_pallas`.
    Aux (static): sizes, the tight ``max_groups`` (max groups any row block
    actually overlaps — the plan-less kernel must assume ``min(R, M_b+1)``),
    the selected ``config``, and :class:`SegmentStats` over the relation
    sizes (skew of the type histogram drives diagnostics and autotuning
    features exactly as degree skew does for the reduces).
    """
    offsets: jax.Array       # (num_groups + 1,) int32 row offsets
    first_group: jax.Array   # (m_blocks,) int32
    group_count: jax.Array   # (m_blocks,) int32
    num_rows: int            # M: rows of X the metadata was built for
    num_groups: int          # R: relation count
    max_groups: int          # tight: max(group_count), >= 1
    config: KernelConfig
    stats: SegmentStats      # over the relation-size histogram

    def tree_flatten(self):
        children = (self.offsets, self.first_group, self.group_count)
        aux = (self.num_rows, self.num_groups, self.max_groups,
               self.config, self.stats)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def worst_case_groups(self) -> int:
        """The group-grid bound the plan-less kernel must assume."""
        return min(self.num_groups, self.config.m_b + 1)

    @property
    def grid_savings(self) -> float:
        """worst-case / tight group-dim ratio (>= 1)."""
        return self.worst_case_groups / max(self.max_groups, 1)

    def validate(self, num_rows: int, num_groups: int) -> None:
        """Trace-time consistency check against the arrays of an op call."""
        if num_rows != self.num_rows or num_groups != self.num_groups:
            raise ValueError(
                f"RelationPlan built for (M={self.num_rows}, "
                f"R={self.num_groups}) used with (M={num_rows}, "
                f"R={num_groups}); rebuild the plan for this typed graph.")


def make_relation_plan(group_sizes, num_rows: Optional[int] = None,
                       feat: int = 128,
                       config: Optional[KernelConfig] = None,
                       tune: Optional[bool] = None) -> RelationPlan:
    """Build a :class:`RelationPlan` from *concrete* per-relation row counts.

    ``group_sizes`` (R,) must be host-available (numpy or committed jax
    array) with non-negative entries; ``num_rows`` defaults to their sum
    (pass the padded row count when X carries trailing out-of-range rows —
    they belong to no group and the metadata drops them, the same
    convention as :func:`make_plan`'s row padding). ``feat`` is the
    representative output width N fed to the config heuristic. ``tune``
    follows the :func:`make_plan` semantics (measured sweep via the
    PerfDB; ``None`` defers to ``REPRO_AUTOTUNE``)."""
    sizes = np.asarray(group_sizes).astype(np.int64)
    if sizes.ndim != 1 or sizes.size == 0:
        raise ValueError(
            f"group_sizes must be 1-D and non-empty, got shape {sizes.shape}")
    if np.any(sizes < 0):
        raise ValueError("group_sizes must be non-negative")
    total = int(sizes.sum())
    m = total if num_rows is None else int(num_rows)
    if m < total:
        raise ValueError(f"num_rows={m} < sum(group_sizes)={total}")
    # the relation-size histogram is a degenerate sorted segment index:
    # reuse the same statistics machinery as the reduces
    stats = segment_stats(np.repeat(np.arange(sizes.size), sizes), sizes.size)

    if config is None:
        from repro.core.heuristics import select_config
        config = select_config(max(m, 1), max(int(sizes.size), 1), feat,
                               op="grouped_segment_matmul", tune=tune)

    # the kernel's own metadata helper, evaluated concretely on the host —
    # one formula, so plans can never drift from the per-call path
    from repro.kernels.segment_matmul import group_metadata
    offsets, fg, gc = group_metadata(sizes.astype(np.int32), m, config.m_b)
    gc_np = np.asarray(gc)
    max_groups = max(1, int(gc_np.max())) if gc_np.size else 1
    return RelationPlan(
        offsets=jnp.asarray(offsets),
        first_group=jnp.asarray(fg),
        group_count=jnp.asarray(gc),
        num_rows=m,
        num_groups=int(sizes.size),
        max_groups=max_groups,
        config=config,
        stats=stats,
    )


def make_graph_plan(edge_index, num_nodes: int, feat: int = 128,
                    config: Optional[KernelConfig] = None,
                    tune: Optional[bool] = None) -> SegmentPlan:
    """Plan for GNN aggregation over ``edge_index`` (2, E) with
    ``edge_index[1]`` (destinations) sorted non-decreasing — the convention
    of :mod:`repro.models.gnn`. One plan serves every layer of a model and
    every training step on the same graph. ``tune=True`` selects the config
    from a measured sweep (see :func:`make_plan`)."""
    edge_index = np.asarray(edge_index)
    if edge_index.ndim != 2 or edge_index.shape[0] != 2:
        raise ValueError(f"edge_index must be (2, E), got {edge_index.shape}")
    return make_plan(edge_index[1], num_nodes, feat=feat, config=config,
                     tune=tune)
