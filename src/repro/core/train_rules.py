"""End-to-end rule training pipeline (paper Fig. 5):

    datasets → augment → offline benchmark sweep → performance database
    → Top-1 per key → multi-output decision tree (SR + PR) → codegen
    → ``_generated_rules.py``

Two sources for the database:
  * analytical (default) — the v5e roofline cost model sweeps the pruned
    space over the augmented Table-II datasets (runs anywhere, no timing);
  * measured — ``--from-perfdb <path>`` reads the wall-clock sweeps that
    :func:`repro.core.autotune.tune` persisted, i.e. the paper's actual
    pipeline (real executions → database → tree → codegen).

Run:  PYTHONPATH=src python -m repro.core.train_rules
      PYTHONPATH=src python -m repro.core.train_rules --from-perfdb ~/.cache/repro-perfdb
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List, Optional, Sequence

from repro.core import codegen, costmodel, perfdb
from repro.core.config_space import KernelConfig
from repro.core.decision_tree import MultiOutputDecisionTree
from repro.core.features import InputFeatures


def fit_schedule_rule(records):
    """Fit the SR-vs-PR rule on F from the database.

    The paper finds F > 4 ⇒ SR empirically on A100 (Fig. 4b, a memory-
    coalescing effect). On TPU the trade-off moves: the PR one-hot matmul
    adds S_b MACs/element, which rides *under* the bf16 roofline knee
    (~240 FLOP/byte) — the MXU does the parallel reduction "for free" while
    the kernel stays memory-bound, so PR can dominate at every F. We fit
    the threshold that maximises agreement with the database instead of
    porting the GPU constant (DESIGN.md §2, EXPERIMENTS.md §Bench-Fig8)."""
    best_by_key: dict = {}
    for r in records:
        cur = best_by_key.get(r.features)
        if cur is None or r.gflops > cur.gflops:
            best_by_key[r.features] = r
    from collections import defaultdict
    wins = defaultdict(lambda: [0, 0])          # f → [sr_wins, pr_wins]
    for feats, rec in best_by_key.items():
        wins[feats[2]][0 if rec.schedule == "SR" else 1] += 1
    fs = sorted(wins)
    total = sum(sum(v) for v in wins.values())
    # candidate thresholds: SR iff log2_feat >= t
    candidates = [float("-inf")] + [f + 1e-9 for f in fs] + [float("inf")]
    best_thr, best_acc = float("inf"), -1.0
    for t in candidates:
        acc = sum((v[0] if f >= t else v[1])
                  for f, v in wins.items()) / max(total, 1)
        if acc > best_acc:
            best_thr, best_acc = t, acc
    if best_thr == float("inf"):
        return "False", best_thr                 # PR everywhere (TPU finding)
    if best_thr == float("-inf"):
        return "True", best_thr
    return f"log2_feat >= {float(best_thr)!r}", float(best_thr)


def records_from_perfdb(path=None,
                        op: str = "segment_reduce"
                        ) -> List[perfdb.PerfRecord]:
    """Convert persisted wall-clock sweeps into :class:`PerfRecord` rows.

    Every measured (config, µs) pair becomes a record; GFlops is the useful
    work of the shape class over the measured time, so "higher is better"
    Top-1 selection works identically on measured and analytical rows."""
    from repro.core.autotune import PerfDB
    db = PerfDB(path)
    records: List[perfdb.PerfRecord] = []
    for entry in db.load().values():
        if entry.get("op") != op:
            continue
        m, s, f = entry["idx_size"], entry["num_segments"], entry["feat"]
        fv = tuple(InputFeatures(m, s, f).as_vector())
        flops = costmodel.useful_flops(m, f)
        for t in entry["timings"]:
            cfg = KernelConfig(*t["config"])
            us = max(float(t["us"]), 1e-9)
            gflops = flops / us / 1e3            # flops / (µs·1e-6) / 1e9
            records.append(perfdb.PerfRecord(fv, cfg.schedule,
                                             cfg.astuple(), gflops))
    return records


def train(out_path: Optional[pathlib.Path] = None, augment_factor: int = 60,
          max_depth: int = 5, verbose: bool = True,
          records: Optional[Sequence[perfdb.PerfRecord]] = None,
          source: str = "analytical"):
    if records is None:
        records = perfdb.build_perfdb(augment_factor=augment_factor)
    if verbose:
        print(f"perfdb[{source}]: {len(records)} measurements over "
              f"{len({r.features for r in records})} keys", file=sys.stderr)

    trees = {}
    for sched in ("SR", "PR"):
        x, y = perfdb.top1_training_set(records, sched)
        if x.size == 0:
            raise ValueError(
                f"no {sched} records in the database — a measured perfdb "
                "needs sweeps covering both schedules (tune() interleaves "
                "them by default; raise max_configs if you capped it)")
        # measured databases can be tiny (a handful of shape classes from
        # CI); scale the leaf floor down so the tree still splits
        leaf = max(1, min(8, x.shape[0] // 4))
        tree = MultiOutputDecisionTree(max_depth=max_depth,
                                       min_samples_leaf=leaf,
                                       min_samples_split=2 * leaf).fit(x, y)
        trees[sched] = tree
        if verbose:
            print(f"{sched}: {x.shape[0]} keys, depth={tree.depth()}, "
                  f"leaves={tree.num_leaves()}", file=sys.stderr)

    rule, thr = fit_schedule_rule(records)
    src = codegen.generate_rules_source(trees["SR"], trees["PR"],
                                        InputFeatures.names(),
                                        schedule_rule=rule)
    if out_path is None:
        out_path = pathlib.Path(__file__).parent / "_generated_rules.py"
    out_path.write_text(src)
    if verbose:
        print(f"wrote {out_path} (schedule rule: {rule})", file=sys.stderr)
    return trees, records


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Distill kernel-config rules from a performance database")
    ap.add_argument("--from-perfdb", metavar="PATH", default=None,
                    help="retrain from the measured wall-clock PerfDB at "
                         "PATH (dir or perfdb.json) instead of the "
                         "analytical cost model")
    ap.add_argument("--out", default=None,
                    help="output module path (default: _generated_rules.py "
                         "next to this file)")
    ap.add_argument("--augment-factor", type=int, default=60,
                    help="dataset augmentation factor for the analytical "
                         "sweep (paper: ×60)")
    ap.add_argument("--max-depth", type=int, default=5)
    args = ap.parse_args(argv)

    records = None
    source = "analytical"
    if args.from_perfdb is not None:
        records = records_from_perfdb(args.from_perfdb)
        source = f"measured:{args.from_perfdb}"
        if not records:
            ap.error(f"no measured segment_reduce sweeps found under "
                     f"{args.from_perfdb} — run the autotuner first "
                     "(e.g. make_plan(..., tune=True) or "
                     "benchmarks.bench_segment_reduce --smoke --ablation)")
    out = pathlib.Path(args.out) if args.out else None
    train(out_path=out, augment_factor=args.augment_factor,
          max_depth=args.max_depth, records=records, source=source)


if __name__ == "__main__":
    main()
