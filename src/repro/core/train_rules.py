"""End-to-end rule training pipeline (paper Fig. 5):

    datasets → augment → offline benchmark sweep → performance database
    → Top-1 per key → multi-output decision tree (SR + PR) → codegen
    → ``_generated_rules.py``

Run:  PYTHONPATH=src python -m repro.core.train_rules
"""
from __future__ import annotations

import pathlib
import sys

import numpy as np

from repro.core import codegen, perfdb
from repro.core.decision_tree import MultiOutputDecisionTree
from repro.core.features import InputFeatures


def fit_schedule_rule(records):
    """Fit the SR-vs-PR rule on F from the database.

    The paper finds F > 4 ⇒ SR empirically on A100 (Fig. 4b, a memory-
    coalescing effect). On TPU the trade-off moves: the PR one-hot matmul
    adds S_b MACs/element, which rides *under* the bf16 roofline knee
    (~240 FLOP/byte) — the MXU does the parallel reduction "for free" while
    the kernel stays memory-bound, so PR can dominate at every F. We fit
    the threshold that maximises agreement with the database instead of
    porting the GPU constant (DESIGN.md §2, EXPERIMENTS.md §Bench-Fig8)."""
    best_by_key: dict = {}
    for r in records:
        cur = best_by_key.get(r.features)
        if cur is None or r.gflops > cur.gflops:
            best_by_key[r.features] = r
    from collections import defaultdict
    wins = defaultdict(lambda: [0, 0])          # f → [sr_wins, pr_wins]
    for feats, rec in best_by_key.items():
        wins[feats[2]][0 if rec.schedule == "SR" else 1] += 1
    fs = sorted(wins)
    total = sum(sum(v) for v in wins.values())
    # candidate thresholds: SR iff log2_feat >= t
    candidates = [float("-inf")] + [f + 1e-9 for f in fs] + [float("inf")]
    best_thr, best_acc = float("inf"), -1.0
    for t in candidates:
        acc = sum((v[0] if f >= t else v[1])
                  for f, v in wins.items()) / max(total, 1)
        if acc > best_acc:
            best_thr, best_acc = t, acc
    if best_thr == float("inf"):
        return "False", best_thr                 # PR everywhere (TPU finding)
    if best_thr == float("-inf"):
        return "True", best_thr
    return f"log2_feat >= {float(best_thr)!r}", float(best_thr)


def train(out_path: pathlib.Path | None = None, augment_factor: int = 60,
          max_depth: int = 5, verbose: bool = True):
    records = perfdb.build_perfdb(augment_factor=augment_factor)
    if verbose:
        print(f"perfdb: {len(records)} measurements over "
              f"{len({r.features for r in records})} keys", file=sys.stderr)

    trees = {}
    for sched in ("SR", "PR"):
        x, y = perfdb.top1_training_set(records, sched)
        tree = MultiOutputDecisionTree(max_depth=max_depth,
                                       min_samples_leaf=8).fit(x, y)
        trees[sched] = tree
        if verbose:
            print(f"{sched}: {x.shape[0]} keys, depth={tree.depth()}, "
                  f"leaves={tree.num_leaves()}", file=sys.stderr)

    rule, thr = fit_schedule_rule(records)
    src = codegen.generate_rules_source(trees["SR"], trees["PR"],
                                        InputFeatures.names(),
                                        schedule_rule=rule)
    if out_path is None:
        out_path = pathlib.Path(__file__).parent / "_generated_rules.py"
    out_path.write_text(src)
    if verbose:
        print(f"wrote {out_path} (schedule rule: {rule})", file=sys.stderr)
    return trees, records


if __name__ == "__main__":
    train()
