"""Wall-clock autotuner + persistent performance database (paper §III-C).

This closes the measurement loop the analytical pipeline left open:
``repro.core.perfdb`` scores configs with the v5e roofline model, whereas the
paper's perf database is *measured* — the pruned config space is swept with
real kernel executions and the winners are what the decision-tree rules are
distilled from (Fig. 5). Here:

* :func:`tune` sweeps the pruned lattice (``config_space.enumerate_configs``)
  by timing the actual kernels — Pallas interpret on CPU, Mosaic on TPU —
  with warmup + ``jax.block_until_ready`` and a median-of-k timer over
  deterministic synthetic inputs (seeded; CI-stable);
* :class:`PerfDB` persists every sweep as JSON under ``~/.cache/repro-perfdb``
  (override with ``REPRO_PERFDB_PATH``), keyed by
  ``backend / op / quantized InputFeatures`` — a (device, shape-class) is
  tuned **once** and the measured config is reused forever;
* the cached winner becomes the *top tier* of the selection precedence
  (:func:`repro.core.heuristics.select_config`):

      explicit ``config=``  >  measured (``tune=True`` / ``REPRO_AUTOTUNE=1``)
      >  generated decision-tree rules  >  hand-crafted static rule

* ``python -m repro.core.train_rules --from-perfdb <path>`` re-distills
  ``_generated_rules.py`` from the measured records, replacing the
  analytical evaluate_fn with wall-clock truth.

Environment knobs: ``REPRO_AUTOTUNE`` (enable the measured tier globally),
``REPRO_PERFDB_PATH`` (cache directory or ``*.json`` file),
``REPRO_AUTOTUNE_MAX_CONFIGS`` / ``REPRO_AUTOTUNE_REPS`` (sweep budget).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import tempfile
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.config_space import KernelConfig, all_configs
from repro.core.features import InputFeatures

__all__ = ["PerfDB", "TuneResult", "tune", "autotune_enabled", "perf_key",
           "quantize_features"]

DB_VERSION = 1
DEFAULT_MAX_CONFIGS = 24
DEFAULT_REPS = 5
DEFAULT_WARMUP = 2
DEFAULT_SEED = 0                 # deterministic synthetic inputs (de-flake)
_QUANT_STEP = 0.5                # log2-space bin width for shape classes


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "") not in ("", "0", "false", "False")


def autotune_enabled() -> bool:
    """True when ``REPRO_AUTOTUNE=1`` turns on the measured tier globally."""
    return _env_flag("REPRO_AUTOTUNE")


# ---------------------------------------------------------------------------
# shape-class keys
# ---------------------------------------------------------------------------

def quantize_features(feats: InputFeatures,
                      step: float = _QUANT_STEP) -> Tuple[float, ...]:
    """Quantize the log2 feature vector to ``step``-wide bins.

    Shapes within the same bin share one tuned config — the paper's
    augmentation (×60 noised/scaled variants per dataset) exists precisely
    because nearby shapes want the same schedule; binning is the inverse
    move: nearby shapes *reuse* one measurement."""
    vec = feats.as_vector()
    # + 0.0 normalizes IEEE -0.0 to +0.0 so one bin maps to one cache key
    return tuple(float(np.round(v / step) * step + 0.0) for v in vec)


def perf_key(backend: str, op: str, feats: InputFeatures) -> str:
    """``backend / op [@io-dtype shelf] / quantized shape class``.

    The io dtype is a separate *shelf*, not a tree feature: fp32 keys keep
    their historical format (warm caches stay warm) and lowered-precision
    sweeps land next to them under ``op@b2`` without retraining the
    decision tree's 3-D feature vector."""
    q = quantize_features(feats)
    shelf = "" if feats.dtype_bytes == 4 else f"@b{feats.dtype_bytes}"
    return f"{backend}/{op}{shelf}/" + ",".join(f"{v:g}" for v in q)


# ---------------------------------------------------------------------------
# persistent database
# ---------------------------------------------------------------------------

class PerfDB:
    """On-disk JSON cache of measured sweeps, one entry per shape class.

    The whole sweep is stored (config → median µs), not just the winner, so
    ``train_rules --from-perfdb`` can retrain the decision tree from the same
    records and the ablation benchmark can read baseline-config timings
    without re-measuring."""

    def __init__(self, path: "str | os.PathLike | None" = None):
        if path is None:
            path = os.environ.get("REPRO_PERFDB_PATH") or os.path.join(
                os.path.expanduser("~"), ".cache", "repro-perfdb")
        p = pathlib.Path(path)
        self.file = p if p.suffix == ".json" else p / "perfdb.json"
        self._entries: Optional[Dict[str, dict]] = None

    # -- I/O ---------------------------------------------------------------
    def load(self) -> Dict[str, dict]:
        if self._entries is None:
            try:
                with open(self.file) as f:
                    doc = json.load(f)
                self._entries = (doc.get("entries", {})
                                 if doc.get("version") == DB_VERSION else {})
            except (OSError, ValueError):
                self._entries = {}
        return self._entries

    def _save(self) -> None:
        self.file.parent.mkdir(parents=True, exist_ok=True)
        # merge over the current on-disk state so concurrent writers only
        # ever lose per-key races, never whole entries written by others
        on_disk: Dict[str, dict] = {}
        try:
            with open(self.file) as f:
                doc = json.load(f)
            if doc.get("version") == DB_VERSION:
                on_disk = doc.get("entries", {})
        except (OSError, ValueError):
            pass
        on_disk.update(self._entries)
        self._entries = on_disk
        doc = {"version": DB_VERSION, "entries": self._entries}
        # atomic replace: concurrent CI jobs never observe a torn file
        fd, tmp = tempfile.mkstemp(dir=self.file.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self.file)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- access ------------------------------------------------------------
    def get(self, key: str) -> Optional[dict]:
        return self.load().get(key)

    def put(self, key: str, entry: dict) -> None:
        self.load()[key] = entry
        self._save()

    def __len__(self) -> int:
        return len(self.load())

    def keys(self):
        return self.load().keys()


@functools.lru_cache(maxsize=8)
def _default_db(path_key: str) -> PerfDB:
    """Process-wide PerfDB per path (entries parsed once, not per op call)."""
    return PerfDB(path_key or None)


# ---------------------------------------------------------------------------
# measurement adapters (one per op)
# ---------------------------------------------------------------------------
# Each adapter builds deterministic synthetic inputs for a shape class and
# returns ``run(cfg) -> zero-arg jitted callable``; the tuner times it.

def _synth(idx_size: int, num_segments: int, feat: int, seed: int,
           dtype=np.float32):
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.integers(0, max(num_segments, 1),
                               size=idx_size)).astype(np.int32)
    x = rng.standard_normal((idx_size, feat)).astype(np.float32)
    if np.dtype(dtype) != np.float32:
        import jax.numpy as jnp
        x = np.asarray(jnp.asarray(x).astype(dtype))
    return rng, idx, x


def _cast(arr, dtype):
    """Cast a synthetic fp32 numpy array to the sweep's io dtype."""
    import jax.numpy as jnp
    a = jnp.asarray(arr)
    return a if np.dtype(dtype) == np.float32 else a.astype(dtype)


def _runner_segment_reduce(idx_size, num_segments, feat, interpret, seed,
                           dtype=np.float32):
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    _, idx, x = _synth(idx_size, num_segments, feat, seed, dtype)
    xj, idxj = jnp.asarray(x), jnp.asarray(idx)

    def run(cfg: KernelConfig):
        return lambda: kops.segment_reduce(xj, idxj, num_segments,
                                           reduce="sum", config=cfg,
                                           interpret=interpret)
    return run


def _runner_gather_segment_reduce(idx_size, num_segments, feat, interpret,
                                  seed, reduce: str = "sum",
                                  dtype=np.float32):
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    rng, seg, _ = _synth(idx_size, num_segments, feat, seed)
    h = _cast(rng.standard_normal(
        (max(num_segments, 1), feat)).astype(np.float32), dtype)
    gather_idx = jnp.asarray(rng.integers(
        0, max(num_segments, 1), size=idx_size).astype(np.int32))
    segj = jnp.asarray(seg)

    def run(cfg: KernelConfig):
        return lambda: kops.gather_segment_reduce(h, gather_idx, segj,
                                                  num_segments, reduce=reduce,
                                                  config=cfg,
                                                  interpret=interpret)
    return run


def _runner_segment_softmax(idx_size, num_segments, feat, interpret, seed,
                            dtype=np.float32):
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    rng, seg, _ = _synth(idx_size, num_segments, feat, seed)
    x = _cast(rng.standard_normal(
        (idx_size, max(feat, 1))).astype(np.float32), dtype)
    segj = jnp.asarray(seg)

    def run(cfg: KernelConfig):
        return lambda: kops.segment_softmax(x, segj, num_segments, config=cfg,
                                            interpret=interpret)
    return run


def _runner_segment_matmul(idx_size, num_segments, feat, interpret, seed,
                           dtype=np.float32):
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    rng = np.random.default_rng(seed)
    e = max(num_segments, 1)
    sizes = np.full((e,), idx_size // e, np.int32)
    sizes[: idx_size - int(sizes.sum())] += 1
    x = _cast(rng.standard_normal((idx_size, feat)).astype(np.float32), dtype)
    w = _cast(rng.standard_normal((e, feat, feat)).astype(np.float32), dtype)
    gs = jnp.asarray(sizes)

    def run(cfg: KernelConfig):
        return lambda: kops.segment_matmul(x, gs, w, config=cfg,
                                           interpret=interpret)
    return run


def _runner_sddmm(idx_size, num_segments, feat, interpret, seed,
                  dtype=np.float32):
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    rng = np.random.default_rng(seed)
    r = max(num_segments, 1)
    a = _cast(rng.standard_normal((r, feat)).astype(np.float32), dtype)
    b = _cast(rng.standard_normal((r, feat)).astype(np.float32), dtype)
    row = jnp.asarray(rng.integers(0, r, size=idx_size).astype(np.int32))
    col = jnp.asarray(rng.integers(0, r, size=idx_size).astype(np.int32))

    def run(cfg: KernelConfig):
        return lambda: kops.sddmm(a, b, row, col, config=cfg,
                                  interpret=interpret)
    return run


def _runner_grouped_segment_matmul(idx_size, num_segments, feat, interpret,
                                   seed, dtype=np.float32):
    """The typed-edge profile of the grouped GEMM: zipf-skewed group sizes
    (most relations tiny, a few dominant — empty groups included), unlike
    :func:`_runner_segment_matmul`'s balanced MoE split. Same kernel,
    separately keyed PerfDB entries."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    rng = np.random.default_rng(seed)
    e = max(num_segments, 1)
    w_rel = np.minimum(rng.zipf(1.2, size=e).astype(np.float64),
                       max(idx_size / 2.0, 1.0))
    sizes = rng.multinomial(idx_size, w_rel / w_rel.sum()).astype(np.int32)
    x = _cast(rng.standard_normal((idx_size, feat)).astype(np.float32), dtype)
    w = _cast(rng.standard_normal((e, feat, feat)).astype(np.float32), dtype)
    gs = jnp.asarray(sizes)

    def run(cfg: KernelConfig):
        return lambda: kops.segment_matmul(x, gs, w, config=cfg,
                                           interpret=interpret)
    return run


def _runner_fused_transform_reduce(idx_size, num_segments, feat, interpret,
                                   seed, dtype=np.float32):
    """The one-launch SpMM+GEMM: gather → in-kernel (d_in, d_out) transform →
    reduce. Swept with a square weight (d_out = feat) like the matmul
    runners."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    rng, seg, _ = _synth(idx_size, num_segments, feat, seed)
    h = _cast(rng.standard_normal(
        (max(num_segments, 1), feat)).astype(np.float32), dtype)
    w = _cast(rng.standard_normal((feat, feat)).astype(np.float32), dtype)
    gather_idx = jnp.asarray(rng.integers(
        0, max(num_segments, 1), size=idx_size).astype(np.int32))
    segj = jnp.asarray(seg)

    def run(cfg: KernelConfig):
        return lambda: kops.fused_transform_reduce(
            h, w, gather_idx, segj, num_segments, reduce="sum",
            config=cfg, interpret=interpret)
    return run


_OPS: Dict[str, Callable] = {
    "segment_reduce": _runner_segment_reduce,
    "gather_segment_reduce": _runner_gather_segment_reduce,
    "gather_segment_reduce_mean": functools.partial(
        _runner_gather_segment_reduce, reduce="mean"),
    "gather_segment_reduce_max": functools.partial(
        _runner_gather_segment_reduce, reduce="max"),
    "segment_softmax": _runner_segment_softmax,
    "segment_matmul": _runner_segment_matmul,
    "grouped_segment_matmul": _runner_grouped_segment_matmul,
    "sddmm": _runner_sddmm,
    "fused_transform_reduce": _runner_fused_transform_reduce,
}

# ops that consume only a projection of the config sweep the projected space
# (deduped), not the full lattice
_PROJECTED_OPS = ("segment_matmul", "grouped_segment_matmul", "sddmm")


def config_projection(op: str, cfg: KernelConfig) -> Tuple:
    """The slice of the config an op actually consumes (dedupe key)."""
    if op in _PROJECTED_OPS:
        return ("m_b", cfg.m_b, "n_b", cfg.n_b)
    if op == "segment_softmax":
        # the softmax walk ignores schedule/N_b/K_c (heads are one lane tile)
        return ("s_b", cfg.s_b, "m_b", cfg.m_b)
    if op == "gather_segment_reduce_max":
        # max forces the SR walk, so PR lattice points alias their SR twin
        return ("SR", cfg.s_b, cfg.n_b, cfg.m_b, 1)
    if op == "fused_transform_reduce":
        # stages full-width d_in rows (no N_b feature tiling) and always
        # accumulates via the full one-hot matmul; only ⟨S_b, M_b⟩ matter
        return ("fused", cfg.s_b, cfg.m_b)
    return cfg.astuple()


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def _median_us(fn: Callable[[], object], reps: int, warmup: int) -> float:
    """Median-of-k wall clock of a jitted zero-arg callable, µs.

    Warmup absorbs compilation; ``block_until_ready`` pins the async
    dispatch; the median (not mean/min) is the de-flake guard the CI
    regression gate depends on."""
    import jax
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    ts: List[float] = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------

def _candidates(op: str, idx_size: int, num_segments: int, feat: int,
                max_configs: int,
                extra: Sequence[KernelConfig]) -> List[KernelConfig]:
    """Pruned-lattice sweep order: heuristic seeds first, then an
    even spread of the lattice (schedule-interleaved so both SR and PR are
    always represented), deduped by the op's config projection and capped.

    Seeding with the generated-rules and hand-crafted picks guarantees the
    tuned winner is never *worse* than either baseline on the measured
    workload — argmin over a superset."""
    from repro.core.heuristics import hand_crafted_config, select_config
    seeds = [select_config(idx_size, num_segments, feat, tune=False),
             hand_crafted_config(idx_size, num_segments, feat)]
    seeds.extend(extra)

    lattice = all_configs(feat)
    sr = [c for c in lattice if c.schedule == "SR"]
    pr = [c for c in lattice if c.schedule == "PR"]
    budget = max(max_configs - len(seeds), 2)
    sr_sel = sr[:: max(1, len(sr) // max(budget // 2, 1))]
    pr_sel = pr[:: max(1, len(pr) // max(budget - budget // 2, 1))]
    interleaved: List[KernelConfig] = []
    for i in range(max(len(sr_sel), len(pr_sel))):
        if i < len(sr_sel):
            interleaved.append(sr_sel[i])
        if i < len(pr_sel):
            interleaved.append(pr_sel[i])

    out: List[KernelConfig] = []
    seen = set()
    for cfg in list(seeds) + interleaved:
        pk = config_projection(op, cfg)
        if pk in seen:
            continue
        seen.add(pk)
        out.append(cfg)
        if len(out) >= max_configs:
            break
    return out


# ---------------------------------------------------------------------------
# tune
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one :func:`tune` call (fresh sweep or cache hit)."""
    op: str
    backend: str
    key: str
    config: KernelConfig                    # the measured winner
    timings: Dict[Tuple, float]             # projection -> median µs
    timings_performed: int                  # 0 on a warm-cache hit
    cache_hit: bool

    def time_of(self, cfg: KernelConfig) -> Optional[float]:
        """Measured µs of ``cfg`` in this sweep (None if it wasn't swept)."""
        return self.timings.get(config_projection(self.op, cfg))


def _entry_to_result(op: str, backend: str, key: str,
                     entry: dict) -> TuneResult:
    timings = {config_projection(op, KernelConfig(*t["config"])): t["us"]
               for t in entry["timings"]}
    return TuneResult(op=op, backend=backend, key=key,
                      config=KernelConfig(*entry["best"]),
                      timings=timings, timings_performed=0, cache_hit=True)


def tune(op: str = "segment_reduce", *, idx_size: int, num_segments: int,
         feat: int, db: Optional[PerfDB] = None,
         max_configs: Optional[int] = None, reps: Optional[int] = None,
         warmup: Optional[int] = None, interpret: Optional[bool] = None,
         extra_configs: Sequence[KernelConfig] = (), force: bool = False,
         seed: int = DEFAULT_SEED, io_dtype: str = "float32",
         measure_fn: Optional[Callable[[KernelConfig], float]] = None,
         ) -> TuneResult:
    """Measure the pruned config lattice for one (op, shape class); cache.

    Consults the :class:`PerfDB` first — a warm cache returns with
    ``timings_performed == 0`` (no kernel executions at all). On a miss,
    every candidate is timed (median-of-``reps`` with ``warmup`` discarded
    iterations over seed-deterministic synthetic inputs) and the sweep is
    persisted. ``measure_fn`` swaps the wall-clock timer for a callable
    ``cfg -> µs`` (tests; analytical what-ifs).
    """
    import jax

    if op not in _OPS:
        raise ValueError(f"unknown op {op!r}; tunable: {sorted(_OPS)}")
    backend = jax.default_backend()
    if interpret is None and measure_fn is None:
        # same resolution as the real op calls (REPRO_PALLAS_INTERPRET
        # included) — the sweep must measure the mode that will run
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    if interpret and backend != "cpu":
        backend += "+interp"        # never serve interpret sweeps to Mosaic
    from repro.core.config_space import io_dtype_bytes
    feats = InputFeatures(int(idx_size), int(num_segments), int(feat),
                          dtype_bytes=io_dtype_bytes(io_dtype))
    key = perf_key(backend, op, feats)
    if db is None:
        # one parsed snapshot per path for the life of the process — a
        # REPRO_AUTOTUNE=1 hot loop must not re-read the JSON per op call
        db = _default_db(os.environ.get("REPRO_PERFDB_PATH", ""))

    from repro import obs

    if not force:
        entry = db.get(key)
        if entry is not None:
            obs.record_tune(op, cache_hit=True, key=key, backend=backend)
            return _entry_to_result(op, backend, key, entry)

    if max_configs is None:
        max_configs = int(os.environ.get("REPRO_AUTOTUNE_MAX_CONFIGS",
                                         str(DEFAULT_MAX_CONFIGS)))
    reps = (int(os.environ.get("REPRO_AUTOTUNE_REPS", str(DEFAULT_REPS)))
            if reps is None else reps)
    warmup = DEFAULT_WARMUP if warmup is None else warmup

    cands = _candidates(op, int(idx_size), int(num_segments), int(feat),
                        max_configs, extra_configs)
    if measure_fn is None:
        run = _OPS[op](int(idx_size), int(num_segments), int(feat),
                       interpret, seed, dtype=io_dtype)

        def measure_fn(cfg: KernelConfig) -> float:
            return _median_us(run(cfg), reps, warmup)

    swept: List[Tuple[KernelConfig, float]] = []
    with obs.span("autotune.tune", op=op, key=key,
                  candidates=len(cands)):
        for cfg in cands:
            swept.append((cfg, float(measure_fn(cfg))))

    best_cfg, _ = min(swept, key=lambda cu: cu[1])
    entry = {
        "op": op,
        "backend": backend,
        "features": list(quantize_features(feats)),
        "idx_size": int(idx_size),
        "num_segments": int(num_segments),
        "feat": int(feat),
        "io_dtype": io_dtype,
        "reps": reps,
        "warmup": warmup,
        "seed": seed,
        "best": list(best_cfg.astuple()),
        "timings": [{"config": list(c.astuple()), "us": u}
                    for c, u in swept],
    }
    db.put(key, entry)
    obs.record_tune(op, cache_hit=False, timings=len(swept), key=key,
                    backend=backend, best=list(best_cfg.astuple()))
    timings = {config_projection(op, c): u for c, u in swept}
    return TuneResult(op=op, backend=backend, key=key, config=best_cfg,
                      timings=timings, timings_performed=len(swept),
                      cache_hit=False)
