"""GeoT core: tensor-centric segment reduction for geometric deep learning.

Public API (paper §II-B, §IV):
    segment_reduce, index_segment_reduce, index_weight_segment_reduce,
    segment_softmax, segment_matmul, grouped_segment_matmul, sddmm, gather
"""
from repro.core.autotune import PerfDB, TuneResult, tune
from repro.core.config_space import KernelConfig, all_configs, default_config
from repro.core.features import InputFeatures, extract_features
from repro.core.heuristics import hand_crafted_config, select_config
from repro.core.plan import (
    RelationPlan,
    SegmentPlan,
    SegmentStats,
    make_graph_plan,
    make_plan,
    make_relation_plan,
)
from repro.core.ops import (
    gather,
    grouped_segment_matmul,
    index_segment_reduce,
    index_weight_segment_reduce,
    sddmm,
    segment_matmul,
    segment_reduce,
    segment_softmax,
)
from repro.core.mp import choose_order, mp, mp_transform, mp_typed

__all__ = [
    "mp", "mp_transform", "mp_typed", "choose_order",
    "KernelConfig", "all_configs", "default_config",
    "InputFeatures", "extract_features",
    "select_config", "hand_crafted_config",
    "PerfDB", "TuneResult", "tune",
    "SegmentPlan", "SegmentStats", "make_plan", "make_graph_plan",
    "RelationPlan", "make_relation_plan",
    "segment_reduce", "index_segment_reduce", "index_weight_segment_reduce",
    "segment_softmax", "segment_matmul", "grouped_segment_matmul", "sddmm",
    "gather",
]
