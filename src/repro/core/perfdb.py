"""Performance database (paper §III-C, Fig. 5).

The paper gathers 51 PyG datasets, augments them by noising/scaling to 3060,
sweeps the pruned config space per (dataset, F) offline on an A100, and keeps
the Top-1 config per key.  We reproduce the pipeline with the same dataset
statistics (Table II included verbatim) and the same augmentation factor; the
"offline benchmark" on this CPU-only container is the analytical v5e roofline
model (DESIGN.md §7) — swap ``evaluate_fn`` to a wall-clock callable on real
hardware and nothing else changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.config_space import KernelConfig, all_configs
from repro.core.features import InputFeatures

# Table II of the paper (name, |V|, |E|)
TABLE_II = [
    ("citeseer", 3_327, 9_104),
    ("cora", 2_708, 10_556),
    ("ppi", 2_245, 61_318),
    ("pubmed", 19_717, 88_648),
    ("amazon-photo", 7_650, 238_162),
    ("flickr", 89_250, 899_756),
    ("ogbn-arxiv", 169_343, 1_166_243),
    ("ogbl-collab", 235_868, 1_285_465),
    ("reddit2", 232_965, 23_213_838),
]

FEATURE_SIZES = (1, 2, 4, 8, 16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class DatasetStats:
    name: str
    num_nodes: int
    num_edges: int

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)


def base_datasets(n_base: int = 51, seed: int = 0) -> List[DatasetStats]:
    """Table II + synthetic graphs spanning the PyG-collection regime
    (|V| ∈ [1e3, 5e5], avg degree ∈ [1.5, 120], log-uniform)."""
    rng = np.random.default_rng(seed)
    out = [DatasetStats(*row) for row in TABLE_II]
    while len(out) < n_base:
        v = int(10 ** rng.uniform(3.0, 5.7))
        deg = 10 ** rng.uniform(np.log10(1.5), np.log10(120.0))
        out.append(DatasetStats(f"synth{len(out)}", v, int(v * deg)))
    return out[:n_base]


def augment(datasets: Sequence[DatasetStats], factor: int = 60,
            seed: int = 1) -> List[DatasetStats]:
    """Noise + scale augmentation (paper: 51 → 3060, i.e. ×60)."""
    rng = np.random.default_rng(seed)
    out: List[DatasetStats] = []
    for ds in datasets:
        for k in range(factor):
            scale = 2.0 ** rng.uniform(-2.0, 2.0)
            noise = rng.uniform(0.85, 1.15)
            v = max(64, int(ds.num_nodes * scale))
            e = max(v, int(ds.num_edges * scale * noise))
            out.append(DatasetStats(f"{ds.name}/aug{k}", v, e))
    return out


@dataclasses.dataclass(frozen=True)
class PerfRecord:
    """One row of the performance database (Fig. 5: key → GFlops)."""
    features: Tuple[float, ...]     # InputFeatures.as_vector()
    schedule: str
    config: Tuple                   # KernelConfig.astuple()
    gflops: float


def default_evaluate(m: int, s: int, n: int, cfg: KernelConfig) -> float:
    """GFlops under the analytical model (higher is better)."""
    cost = costmodel.segment_reduce_cost(m, s, n, cfg)
    return cost.gflops(costmodel.useful_flops(m, n))


def build_perfdb(datasets: Iterable[DatasetStats] | None = None,
                 feature_sizes: Sequence[int] = FEATURE_SIZES,
                 evaluate_fn: Callable[[int, int, int, KernelConfig], float]
                 = default_evaluate,
                 augment_factor: int = 60) -> List[PerfRecord]:
    """Sweep the pruned space per (dataset × F); keep every measurement."""
    if datasets is None:
        datasets = augment(base_datasets(), factor=augment_factor)
    records: List[PerfRecord] = []
    for ds in datasets:
        for f in feature_sizes:
            feats = InputFeatures(ds.num_edges, ds.num_nodes, f)
            fv = tuple(feats.as_vector())
            for cfg in all_configs(feat_dim=f):
                g = evaluate_fn(ds.num_edges, ds.num_nodes, f, cfg)
                records.append(PerfRecord(fv, cfg.schedule, cfg.astuple(), g))
    return records


def top1_training_set(records: Sequence[PerfRecord], schedule: str):
    """Top-1 selection rule (paper §III-C): per unique feature key keep the
    best config of the given schedule. Returns (X features, Y configs)."""
    best: dict = {}
    for r in records:
        if r.schedule != schedule:
            continue
        cur = best.get(r.features)
        if cur is None or r.gflops > cur.gflops:
            best[r.features] = r
    xs, ys = [], []
    for feats, rec in sorted(best.items()):
        xs.append(feats)
        _, s_b, n_b, m_b, k_c = rec.config
        ys.append((s_b, n_b, m_b, k_c))
    return np.asarray(xs, np.float64), np.asarray(ys, np.float64)


def snap_config(schedule: str, raw: np.ndarray,
                feat_dim: int | None = None) -> KernelConfig:
    """Snap a (possibly fractional) tree prediction onto the pruned lattice
    of valid configs (nearest in log2 space, VMEM-feasible).

    Degenerate predictions (zeros, NaN, ±inf — e.g. a tree fitted on a
    near-empty measured PerfDB) are clamped to 1 before the log, so the
    result is always a valid lattice point and never NaN-poisoned."""
    cands = [c for c in all_configs(feat_dim) if c.schedule == schedule]
    raw = np.asarray(raw, np.float64)
    raw = np.where(np.isnan(raw), 1.0, raw)     # NaN → smallest lattice point
    raw = np.clip(raw, 1.0, 2.0 ** 30)          # zeros/negatives/±inf bounded
    target = np.log2(raw)

    def dist(c: KernelConfig) -> float:
        vec = np.log2(np.array([c.s_b, c.n_b, c.m_b, max(c.k_c, 1)]))
        return float(((vec - target) ** 2).sum())

    return min(cands, key=dist)
