"""Unified message-passing primitive (the tf_geometric-style map-reduce API
every GNN layer routes through).

Two entry points:

* :func:`mp` — gather-from-source, reduce-into-destination over a sorted
  ``edge_index``. One call, every aggregation: ``reduce`` ∈ {sum, mean, max}
  × {weighted, unweighted}, each a **single fused plan-aware kernel** on the
  ``pallas`` path (see :mod:`repro.kernels.gather_segment_reduce` — the
  (|E|, F) message tensor never materializes).

* :func:`mp_transform` — message passing composed with a dense transform
  ``W``, with a **three-way** schedule decision applied per layer:

      aggregate(X) @ W        (aggregate-first)   SpMM width = d_in
      aggregate(X @ W)        (transform-first)   SpMM width = d_out
      fused(X, W)             (fused)             SpMM+GEMM, ONE launch

  The two launch orders differ in SpMM width (aggregate-first wins when
  d_in < d_out, both rounded up to the 128-lane tile). The ``fused`` arm
  (:mod:`repro.kernels.fused_transform_reduce`) runs the dense transform
  *inside* the gather-reduce launch: the (S, d_in) aggregate never
  round-trips HBM and the second launch's overhead disappears — available
  on the ``pallas`` path for linear reduces whose (d_in, d_out) weight
  tile fits VMEM (:func:`repro.kernels.fused_transform_reduce.fusable`).
  :func:`choose_order` decides from the v5e cost model
  (:func:`repro.core.costmodel.spmm_cost` /
  :func:`repro.core.costmodel.fused_transform_reduce_cost`) fed with the
  plan's degree statistics (skew inflates the heaviest block's chunk
  count). Reordering is only valid for *linear* reduces (sum / mean,
  weighted or not — they commute with ``W``); ``max`` pins transform-first.

``reduce="max"`` fills empty-neighbourhood rows with 0 (the PyG convention
for model code) rather than the segment_max identity -inf; use the core ops
directly if the identity matters.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import ops as geot
from repro.core.config_space import KernelConfig

__all__ = ["mp", "mp_transform", "mp_typed", "choose_order", "resolve_order"]

_LINEAR_REDUCES = ("sum", "mean")


def resolve_order(reduce: str, order: str, d_in: int, d_out: int, *,
                  plan=None, num_edges=None, num_nodes=None,
                  config=None, allow_fused: bool = False,
                  dtype=None) -> str:
    """Validate and resolve the transform/aggregate order for one layer —
    the single source of truth shared by :func:`mp_transform` and the
    sharded :func:`repro.core.dist_mp.mp_transform_sharded`.

    Non-linear reduces do not commute with ``W`` and pin transform-first;
    ``"auto"`` asks the cost model (:func:`choose_order`). ``allow_fused``
    admits the one-launch SpMM+GEMM arm (the pallas single-device path sets
    it; the sharded path keeps it False — its collective merge sits
    *between* aggregate and transform, so per-shard partial aggregates
    must surface). An explicit ``order="fused"`` still requires a linear
    reduce and an ``allow_fused`` caller."""
    if order not in ("auto", "aggregate_first", "transform_first", "fused"):
        raise ValueError(f"unknown order: {order!r}")
    if reduce not in _LINEAR_REDUCES:
        if order in ("aggregate_first", "fused"):
            raise ValueError(
                f"reduce={reduce!r} does not commute with the transform; "
                f"{order} would compute a different function")
        return "transform_first"
    if order == "fused" and not allow_fused:
        raise ValueError(
            "order='fused' needs the one-launch pallas path "
            "(impl='pallas', single device)")
    if order == "auto":
        return choose_order(d_in, d_out, plan=plan, num_edges=num_edges,
                            num_nodes=num_nodes, config=config,
                            allow_fused=allow_fused, dtype=dtype)
    return order


def mp(x, edge_index, num_nodes: int, *, reduce: str = "sum",
       edge_weight=None, plan=None, impl: str = "ref",
       config: Optional[KernelConfig] = None):
    """Message passing: Y[d] = reduce_{(s,d) ∈ E} (w_e ·) X[s].

    ``edge_index``: (2, E) with ``edge_index[1]`` (destinations) sorted
    non-decreasing; ``plan``: SegmentPlan over the destinations, shared by
    every layer of a model (and by the custom-VJP backward passes)."""
    if reduce not in ("sum", "mean", "max"):
        raise ValueError(f"unknown reduce: {reduce!r}")
    src, dst = edge_index[0], edge_index[1]
    if edge_weight is None:
        y = geot.index_segment_reduce(x, src, dst, num_nodes, reduce, impl,
                                      config, plan)
    else:
        y = geot.index_weight_segment_reduce(x, src, edge_weight, dst,
                                             num_nodes, reduce, impl, config,
                                             plan)
    if reduce == "max":
        # empty neighbourhoods come back as the segment_max identity -inf;
        # models want 0 there. Replace exactly -inf (not every non-finite
        # value) so legitimate +inf/NaN aggregates still surface downstream.
        y = jnp.where(y == -jnp.inf, jnp.zeros_like(y), y)
    return y


def mp_typed(x, w, edge_index, edge_type, num_nodes: int, *,
             type_perm=None, inv_type_perm=None, type_counts=None,
             reduce: str = "sum", edge_weight=None, plan=None, rplan=None,
             impl: str = "ref", config: Optional[KernelConfig] = None,
             tune: Optional[bool] = None):
    """Heterogeneous message passing — per-relation weight transforms as
    **one** grouped ``segment_matmul`` launch (FASTEN's critical operator),
    composed with the existing fused gather-reduce kernels:

        Y[d] = reduce_{(s,d,r) ∈ E} (w_e ·) X[s] @ W[r]

    ``edge_index``: (2, E) destination-sorted (the layout every plan-aware
    reduce requires); ``edge_type``: (E,) relation id per edge, aligned
    with the dst-sorted edges; ``w``: (R, d_in, d_out) one transform per
    relation.

    The two layouts are reconciled with one precomputed permutation: a
    *stable* argsort of ``edge_type`` yields (type, dst)-sorted rows — the
    contiguous groups the grouped matmul needs — and its inverse is fused
    into the reduce's gather operand, so the un-permute costs no extra
    launch. Per layer: one grouped matmul + one fused gather-reduce.

    ``type_perm`` / ``inv_type_perm`` / ``type_counts``: the permutation
    triple, precomputed by :class:`repro.data.graphs.TypedGraph` (derived
    here from ``edge_type`` when omitted). ``plan``: SegmentPlan over the
    destinations; ``rplan``: :class:`repro.core.plan.RelationPlan` over
    the type segments (feeds the grouped kernel's scalar-prefetch
    metadata). ``(plan/rplan, config, tune)`` follow the precedence rule
    of ``docs/plans.md``."""
    if reduce not in ("sum", "mean", "max"):
        raise ValueError(f"unknown reduce: {reduce!r}")
    src, dst = edge_index[0], edge_index[1]
    num_types = int(w.shape[0])
    if type_perm is None:
        type_perm = jnp.argsort(edge_type, stable=True)
    if type_counts is None:
        type_counts = jnp.bincount(edge_type, length=num_types)
    if inv_type_perm is None:
        inv_type_perm = (jnp.zeros_like(type_perm)
                         .at[type_perm]
                         .set(jnp.arange(type_perm.shape[0],
                                         dtype=type_perm.dtype)))
    # gather sources in (type, dst) order → grouped transform (ONE launch)
    msg = geot.gather(x, jnp.take(src, type_perm))
    msg = geot.grouped_segment_matmul(msg, type_counts, w, impl, None,
                                      rplan, tune)
    # fused un-permute + aggregate: the reduce's gather operand IS the
    # inverse permutation, so rows come back in dst order for free
    if edge_weight is None:
        y = geot.index_segment_reduce(msg, inv_type_perm, dst, num_nodes,
                                      reduce, impl, config, plan, tune)
    else:
        y = geot.index_weight_segment_reduce(msg, inv_type_perm, edge_weight,
                                             dst, num_nodes, reduce, impl,
                                             config, plan, tune)
    if reduce == "max":
        y = jnp.where(y == -jnp.inf, jnp.zeros_like(y), y)
    return y


def choose_order(d_in: int, d_out: int, *, plan=None,
                 num_edges: Optional[int] = None,
                 num_nodes: Optional[int] = None,
                 config: Optional[KernelConfig] = None,
                 allow_fused: bool = False, dtype=None) -> str:
    """FLOP/roofline decision: ``"aggregate_first"``, ``"transform_first"``
    or (when ``allow_fused``) ``"fused"``.

    Two-launch orders differ in SpMM width (``d_in`` vs ``d_out``); with
    the fused arm in the race the |V|·d_in·d_out dense matmul no longer
    cancels, so each candidate is costed end to end — the fused arm skips
    the (S, d_in) HBM round-trip and the second launch entirely, but only
    qualifies when its VMEM working set fits
    (:func:`repro.kernels.fused_transform_reduce.fusable` at ``dtype``).
    With a ``plan``, |E|, |V|, the selected config, and the degree skew all
    come from its precomputed statistics; otherwise
    ``num_edges``/``num_nodes`` must be given."""
    from repro.core import costmodel

    if plan is not None:
        m, s = plan.stats.num_rows, plan.stats.num_segments
        skew = plan.stats.skew
        cfg = config or plan.config
    else:
        if num_edges is None or num_nodes is None:
            raise ValueError("choose_order needs a plan or "
                             "num_edges + num_nodes")
        m, s, skew = int(num_edges), int(num_nodes), 1.0
        cfg = config
    if cfg is None:
        from repro.core.heuristics import select_config
        cfg = select_config(max(m, 1), max(s, 1), max(d_in, d_out))
    from repro.core.config_space import io_dtype_bytes
    db = io_dtype_bytes(dtype) if dtype is not None else 4
    dense = costmodel.dense_matmul_cost(s, d_in, d_out, db).total_s
    # insertion order is the tie-break (min keeps the first minimum):
    # transform-first is the conventional order, aggregate-first must beat
    # it strictly, and the fused arm must beat both strictly
    t = {
        "transform_first":
            costmodel.spmm_cost(m, s, d_out, cfg, db, skew=skew).total_s
            + dense,
        "aggregate_first":
            costmodel.spmm_cost(m, s, d_in, cfg, db, skew=skew).total_s
            + dense,
    }
    if allow_fused:
        from repro.kernels.fused_transform_reduce import fusable
        if fusable(d_in, d_out, dtype or "float32", cfg):
            t["fused"] = costmodel.fused_transform_reduce_cost(
                m, s, d_in, d_out, cfg, db, skew=skew).total_s
    return min(t, key=t.get)


def mp_transform(x, w, edge_index, num_nodes: int, *, reduce: str = "sum",
                 edge_weight=None, plan=None, impl: str = "ref",
                 config: Optional[KernelConfig] = None, order: str = "auto"):
    """Message passing fused with a dense transform: aggregate(X·W),
    aggregate(X)·W, or the one-launch fused SpMM+GEMM, whichever the cost
    model prefers (``order="auto"``).

    ``order`` ∈ {"auto", "aggregate_first", "transform_first", "fused"} —
    pin it for ablation benchmarks (``"fused"`` needs ``impl="pallas"``
    and a linear reduce; an unfusable explicit pin raises from the
    kernel's VMEM check). Non-linear reduces (``max``) do not commute
    with ``W`` and always run transform-first."""
    order = resolve_order(reduce, order, int(x.shape[-1]),
                          int(w.shape[-1]), plan=plan,
                          num_edges=int(edge_index.shape[-1]),
                          num_nodes=num_nodes, config=config,
                          allow_fused=(impl == "pallas"), dtype=x.dtype)
    if order == "fused":
        src, dst = edge_index[0], edge_index[1]
        return geot.fused_transform_reduce(x, w, src, edge_weight, dst,
                                           num_nodes, reduce, impl, config,
                                           plan)
    if order == "aggregate_first":
        agg = mp(x, edge_index, num_nodes, reduce=reduce,
                 edge_weight=edge_weight, plan=plan, impl=impl, config=config)
        return agg @ w
    return mp(x @ w, edge_index, num_nodes, reduce=reduce,
              edge_weight=edge_weight, plan=plan, impl=impl, config=config)
