"""GeoT core public API: tensor-centric segment reduction (paper §II-B, §IV).

All ops take *plain dense tensors + an index vector* (format-agnostic, §IV).
``Idx`` is required to be sorted non-decreasing, as guaranteed by GNN
frameworks (paper §IV) and by our MoE dispatch (sort by expert id).

Every op is jit-able and differentiable.  Autograd (paper §VI "future work",
implemented here as a beyond-paper extension) uses the duality:

    d(segment_reduce)/dX  = gather       (Y_bar[idx])
    d(gather)/dH          = segment_reduce (scatter-add of cotangents)

The ``impl`` argument selects the backend:
  * ``"ref"``     — pure-jnp oracle (XLA scatter/gather),
  * ``"blocked"`` — the GeoT-TPU blocked algorithm expressed in jnp
                    (the algorithmic skeleton of the Pallas kernel, runs on
                    any backend; used for CPU wall-clock benchmarking),
  * ``"pallas"``  — the Pallas TPU kernel (interpret=True on CPU).
``config``: ``None`` → data-aware generated rules pick it (paper §III-C);
or an explicit :class:`~repro.core.config_space.KernelConfig`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config_space import KernelConfig

__all__ = [
    "segment_reduce",
    "index_segment_reduce",
    "index_weight_segment_reduce",
    "fused_transform_reduce",
    "segment_softmax",
    "segment_matmul",
    "grouped_segment_matmul",
    "sddmm",
    "gather",
]

# Precision contract (the dtype axis, docs/message_passing.md §Precision):
# every op carries its inputs' io dtype end-to-end — bf16 in, bf16 out —
# while all reductions accumulate in fp32 (kernel accumulators/scratch and
# the jnp reference paths alike). The custom VJPs follow the same rule:
# gradient scatter-adds and segment-sums run in fp32 and the finished
# cotangent is cast back to the primal's dtype (:func:`_accum_cast`).


def _f32(a):
    return a.astype(jnp.float32)


def _accum_cast(acc, like):
    """Cast an fp32 gradient accumulation back to the primal's io dtype."""
    return acc.astype(like.dtype)


# ---------------------------------------------------------------------------
# Reference semantics (pure jnp oracles)
# ---------------------------------------------------------------------------

def _segment_reduce_ref(x, idx, num_segments: int, reduce: str):
    if reduce == "sum":
        return jax.ops.segment_sum(x, idx, num_segments, indices_are_sorted=True)
    if reduce == "mean":
        s = jax.ops.segment_sum(x, idx, num_segments, indices_are_sorted=True)
        cnt = jax.ops.segment_sum(
            jnp.ones((x.shape[0],), x.dtype), idx, num_segments, indices_are_sorted=True
        )
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if reduce == "max":
        return jax.ops.segment_max(x, idx, num_segments, indices_are_sorted=True)
    raise ValueError(f"unknown reduce: {reduce}")


# ---------------------------------------------------------------------------
# Blocked algorithm (GeoT-TPU skeleton in jnp) — used for CPU benchmarking
# and as an executable spec of the Pallas kernel's tiling (paper §III-A/B).
# ---------------------------------------------------------------------------

def _segment_reduce_blocked(x, idx, num_segments: int, reduce: str,
                            config: KernelConfig):
    """Blocked segment reduction: PR schedule = one-hot matmul per chunk
    (MXU analogue), SR schedule = per-chunk masked accumulate (VPU analogue).

    Pure jnp; identical tiling to the Pallas kernel so its CPU wall-clock
    tracks the kernel's algorithmic behaviour."""
    if reduce != "sum":
        # mean/max are routed through sum + postprocess / ref (paper §VI:
        # generalizing the reduction type does not change the schedule).
        if reduce == "mean":
            s = _segment_reduce_blocked(x, idx, num_segments, "sum", config)
            ones = jnp.ones((x.shape[0], 1), x.dtype)
            cnt = _segment_reduce_blocked(ones, idx, num_segments, "sum", config)
            return s / jnp.maximum(cnt, 1.0)
        return _segment_reduce_ref(x, idx, num_segments, reduce)

    m, n = x.shape
    mb = config.m_b
    num_chunks = (m + mb - 1) // mb
    m_pad = num_chunks * mb
    xp = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    # padding rows map to segment `num_segments` (dropped at the end)
    idxp = jnp.pad(idx, (0, m_pad - m), constant_values=num_segments)

    xc = xp.reshape(num_chunks, mb, n)
    ic = idxp.reshape(num_chunks, mb)

    def chunk_rank(icb):
        """Local segment *rank* within a chunk (robust to gapped ids):
        rank[i] = #distinct segment ids in icb[:i+1] - 1 ∈ [0, mb)."""
        bnd = jnp.concatenate(
            [jnp.ones((1,), bool), icb[1:] != icb[:-1]])
        rank = jnp.cumsum(bnd.astype(jnp.int32)) - 1
        # seg id owning each rank slot; unused slots → num_segments (dropped)
        seg_ids = jnp.full((mb,), num_segments, icb.dtype).at[rank].set(icb)
        return rank, seg_ids

    if config.schedule == "PR":
        # One-hot matmul per chunk (MXU analogue): rows reduce in parallel
        # across the systolic array; segment boundaries are enforced by the
        # one-hot structure (the analogue of shuffle invalidation).
        def chunk_partial(xcb, icb):
            rank, seg_ids = chunk_rank(icb)
            onehot = (rank[:, None] == jnp.arange(mb)[None, :]).astype(x.dtype)
            part = onehot.T @ xcb                   # (mb, n) partial sums
            return part, seg_ids

        parts, segs = jax.vmap(chunk_partial)(xc, ic)
        parts = parts.reshape(num_chunks * mb, n)
        segs = jnp.clip(segs.reshape(num_chunks * mb), 0, num_segments)
        # combine: strictly fewer live rows than inputs whenever avg degree>1
        y = jax.ops.segment_sum(parts, segs, num_segments + 1,
                                indices_are_sorted=False)
        return y[:num_segments]

    # SR schedule: sequential accumulation down each chunk, expressed as a
    # chunk-local prefix sum with flushes at segment boundaries
    # (cumsum[end_of_rank] − cumsum[before start_of_rank]). This is the
    # jnp rendering of the TPU VPU walk: accumulate row-by-row, emit at
    # boundaries — O(M·N) adds, no matmul (unlike PR).
    def chunk_partial_sr(xcb, icb):
        rank, seg_ids = chunk_rank(icb)
        cs = jnp.cumsum(xcb.astype(jnp.float32), axis=0)
        rows = jnp.arange(mb, dtype=jnp.int32)
        ends = jnp.full((mb,), -1, jnp.int32).at[rank].max(rows)
        starts = jnp.full((mb,), mb - 1, jnp.int32).at[rank].min(rows)
        upper = cs[jnp.clip(ends, 0, mb - 1)]
        lower = jnp.where((starts > 0)[:, None],
                          cs[jnp.clip(starts - 1, 0, mb - 1)], 0.0)
        part = jnp.where((ends >= 0)[:, None], upper - lower, 0.0)
        return part.astype(x.dtype), seg_ids

    parts, segs = jax.vmap(chunk_partial_sr)(xc, ic)
    parts = parts.reshape(num_chunks * mb, n)
    segs = jnp.clip(segs.reshape(num_chunks * mb), 0, num_segments)
    y = jax.ops.segment_sum(parts, segs, num_segments + 1,
                            indices_are_sorted=False)
    return y[:num_segments]


# ---------------------------------------------------------------------------
# Public ops with custom VJPs
# ---------------------------------------------------------------------------

def _account_unfused(op: str) -> None:
    # trace-time fusion accounting (see repro.kernels.ops): any aggregation
    # that runs as jnp segment ops instead of a fused kernel launch
    from repro.kernels import ops as kops
    kops.account("unfused", op)


def _dispatch_segment_reduce(x, idx, num_segments, reduce, impl, config,
                             plan=None, account=True, tune=None):
    # ``account=False``: the public index_* ops already recorded this
    # aggregation — don't double-count the inner dispatch
    if impl == "ref":
        if account:
            _account_unfused(f"segment_reduce_{reduce}:ref")
        return _segment_reduce_ref(x, idx, num_segments, reduce)
    if impl == "blocked":
        if account:
            _account_unfused(f"segment_reduce_{reduce}:blocked")
        cfg = (config or (plan.config if plan is not None else None)
               or _auto_config(idx, num_segments, x.shape[-1], tune=tune))
        return _segment_reduce_blocked(x, idx, num_segments, reduce, cfg)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.segment_reduce(x, idx, num_segments, reduce=reduce,
                                   config=config, plan=plan, tune=tune)
    raise ValueError(f"unknown impl: {impl}")


def _auto_config(idx, num_segments, feat, op: str = "segment_reduce",
                 tune=None) -> KernelConfig:
    from repro.core.heuristics import select_config
    return select_config(int(idx.shape[0]), int(num_segments), int(feat),
                         op=op, tune=tune)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 7))
def segment_reduce(x, idx, num_segments: int, reduce: str = "sum",
                   impl: str = "ref", config: Optional[KernelConfig] = None,
                   plan=None, tune: Optional[bool] = None):
    """Y[s, :] = reduce_{i : idx[i] == s} X[i, :]   (paper Fig. 2).

    idx must be sorted non-decreasing. Differentiable (sum/mean/max).
    ``plan``: precomputed :class:`repro.core.plan.SegmentPlan` over ``idx``;
    supplies the config and, for ``impl="pallas"``, the chunk metadata and a
    tight grid bound (built once per graph, reused across calls).
    ``(plan=, config=, tune=)`` follow the one precedence rule of
    ``docs/plans.md``: plan > config > tune > heuristics."""
    return _dispatch_segment_reduce(x, idx, num_segments, reduce, impl,
                                    config, plan, tune=tune)


def _segment_reduce_fwd(x, idx, num_segments, reduce, impl, config, plan=None,
                        tune=None):
    y = _dispatch_segment_reduce(x, idx, num_segments, reduce, impl, config,
                                 plan, tune=tune)
    if reduce == "max":
        res = (idx, x, y)
    elif reduce == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(idx, dtype=x.dtype), idx,
                                  num_segments, indices_are_sorted=True)
        res = (idx, cnt)
    else:
        res = (idx,)
    return y, res


def _take0(a, idx):
    """Gather rows of ``a`` by a segment index, dropping out-of-range ids.

    Rows with ``idx >= num_segments`` (the padding convention of
    :mod:`repro.data.partition` and of the kernels' own row padding) are
    dropped by every forward scatter; the backward gathers must mirror
    that — ``jnp.take``'s default out-of-bounds mode fills NaN, which
    would leak into real rows through the scatter-add."""
    return jnp.take(a, idx, axis=0, mode="fill", fill_value=0)


def _split_ties(y_bar, winner, idx, num_segments):
    """Max backward: divide each output's cotangent by its winner count so
    tied rows (duplicate edges / equal messages) share — not multiply —
    the gradient. Σ over the segment stays y_bar, a valid subgradient."""
    nwin = jax.ops.segment_sum(winner, idx, num_segments,
                               indices_are_sorted=True)
    return y_bar / jnp.maximum(nwin, 1.0)


def _segment_reduce_bwd(num_segments, reduce, impl, config, tune, res, y_bar):
    if reduce == "sum":
        (idx,) = res
        return (_take0(y_bar, idx), None, None)
    if reduce == "mean":
        idx, cnt = res
        scale = 1.0 / jnp.maximum(cnt, 1.0)
        return (_take0(y_bar * scale[:, None], idx), None, None)
    idx, x, y = res
    winner = (x == _take0(y, idx)).astype(y_bar.dtype)
    g = _take0(_split_ties(y_bar, winner, idx, num_segments), idx)
    return (winner * g, None, None)


segment_reduce.defvjp(_segment_reduce_fwd, _segment_reduce_bwd)


def gather(h, idx):
    """Row gather (the message step of Listing 2). Differentiable with a
    GeoT-backed VJP: d(gather) = scatter-add = sort + segment_reduce."""
    return _gather(h, idx)


@jax.custom_vjp
def _gather(h, idx):
    return jnp.take(h, idx, axis=0)


def _gather_fwd(h, idx):
    return jnp.take(h, idx, axis=0), (idx, h.shape[0])


def _gather_bwd(res, g):
    idx, num_rows = res
    # sort-then-segment-reduce: GeoT's own primitive implements its VJP;
    # the scatter-add accumulates fp32 and casts back to the io dtype
    order = jnp.argsort(idx)
    dh = _segment_reduce_ref(_f32(jnp.take(g, order, axis=0)),
                             jnp.take(idx, order), num_rows, "sum")
    return (_accum_cast(dh, g), None)


_gather.defvjp(_gather_fwd, _gather_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 8))
def index_segment_reduce(h, gather_idx, seg_idx, num_segments: int,
                         reduce: str = "sum", impl: str = "ref",
                         config: Optional[KernelConfig] = None, plan=None,
                         tune: Optional[bool] = None):
    """Fused message+aggregate (paper Listing 2, §IV):

        Y[s] = reduce_{i: seg_idx[i]==s} H[gather_idx[i]]

    Equivalent to ``segment_reduce(H[gather_idx], seg_idx)`` but fused so the
    (|E|, N) message tensor never hits DRAM (format-agnostic SpMM with unit
    weights). ``plan``: precomputed SegmentPlan over ``seg_idx``."""
    if impl == "pallas":
        # one fused launch for every reduce — sum, mean (count lives inside
        # the kernel), max (SR running-maximum walk); see
        # kernels/gather_segment_reduce.py
        from repro.kernels import ops as kops
        return kops.gather_segment_reduce(h, gather_idx, seg_idx,
                                          num_segments, reduce=reduce,
                                          config=config, plan=plan, tune=tune)
    _account_unfused(f"index_segment_reduce_{reduce}:{impl}")
    msg = jnp.take(h, gather_idx, axis=0)
    return _dispatch_segment_reduce(msg, seg_idx, num_segments, reduce,
                                    "ref" if impl == "ref" else impl, config,
                                    plan, account=False, tune=tune)


def _isr_fwd(h, gather_idx, seg_idx, num_segments, reduce, impl, config,
             plan=None, tune=None):
    y = index_segment_reduce(h, gather_idx, seg_idx, num_segments, reduce,
                             impl, config, plan, tune)
    return y, (h, gather_idx, seg_idx, y)


def _isr_bwd(num_segments, reduce, impl, config, tune, res, y_bar):
    h, gather_idx, seg_idx, y = res
    if reduce == "sum":
        g_edges = _take0(y_bar, seg_idx)
    elif reduce == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(seg_idx, dtype=y_bar.dtype),
                                  seg_idx, num_segments, indices_are_sorted=True)
        g_edges = _take0(y_bar / jnp.maximum(cnt, 1.0)[:, None], seg_idx)
    else:  # max: winner rows share the cotangent (equal split over ties)
        msg = jnp.take(h, gather_idx, axis=0)
        winner = (msg == _take0(y, seg_idx)).astype(y_bar.dtype)
        g_edges = winner * _take0(
            _split_ties(y_bar, winner, seg_idx, num_segments), seg_idx)
    dh = jnp.zeros(h.shape, jnp.float32).at[gather_idx].add(_f32(g_edges))
    return (_accum_cast(dh, h), None, None, None)


index_segment_reduce.defvjp(_isr_fwd, _isr_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 9))
def index_weight_segment_reduce(h, gather_idx, weight, seg_idx,
                                num_segments: int, reduce: str = "sum",
                                impl: str = "ref",
                                config: Optional[KernelConfig] = None,
                                plan=None, tune: Optional[bool] = None):
    """Weighted fused message+aggregate (paper §IV):

        Y[s] = reduce_{i: seg_idx[i]==s} w[i] * H[gather_idx[i]]

    With ``reduce="sum"`` and (seg_idx, gather_idx, w) a sorted COO sparse
    matrix A, this is Y = A @ H — cuSPARSE's workload, format-agnostic.
    ``mean``/``max`` reduce over the weighted messages (mean divides by the
    row count, the reference-oracle semantics). ``plan``: precomputed
    SegmentPlan over ``seg_idx``."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.gather_segment_reduce(h, gather_idx, seg_idx, num_segments,
                                          weight=weight, reduce=reduce,
                                          config=config, plan=plan, tune=tune)
    _account_unfused(f"index_weight_segment_reduce_{reduce}:{impl}")
    msg = jnp.take(h, gather_idx, axis=0) * weight[:, None].astype(h.dtype)
    return _dispatch_segment_reduce(msg, seg_idx, num_segments, reduce,
                                    "ref" if impl == "ref" else impl, config,
                                    plan, account=False, tune=tune)


def _iwsr_fwd(h, gather_idx, weight, seg_idx, num_segments, reduce, impl,
              config, plan=None, tune=None):
    y = index_weight_segment_reduce(h, gather_idx, weight, seg_idx,
                                    num_segments, reduce, impl, config, plan,
                                    tune)
    # only max's winner mask reads y back — don't pin an (S, N) residual
    # through the backward pass of the common sum/mean paths
    return y, (h, gather_idx, weight, seg_idx,
               y if reduce == "max" else None)


def _iwsr_bwd(num_segments, reduce, impl, config, tune, res, y_bar):
    h, gather_idx, weight, seg_idx, y = res
    # d(msg) with msg[i] = w[i]·H[g[i]]: per-reduce cotangent routed to edges
    if reduce == "sum":
        g_msg = _take0(y_bar, seg_idx)
    elif reduce == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(seg_idx, dtype=y_bar.dtype),
                                  seg_idx, num_segments,
                                  indices_are_sorted=True)
        g_msg = _take0(y_bar / jnp.maximum(cnt, 1.0)[:, None], seg_idx)
    else:  # max: winner rows share the cotangent (equal split over ties)
        # the winner recompute must mirror the forward's arithmetic exactly,
        # or low-precision runs silently zero the mask: the pallas kernel
        # multiplies in f32 and casts the result, the jnp paths cast the
        # weight to h.dtype first and multiply in h.dtype
        if impl == "pallas":
            msg = (jnp.take(h, gather_idx, axis=0).astype(jnp.float32)
                   * weight[:, None].astype(jnp.float32)).astype(y.dtype)
        else:
            msg = (jnp.take(h, gather_idx, axis=0)
                   * weight[:, None].astype(h.dtype))
        winner = (msg == _take0(y, seg_idx)).astype(y_bar.dtype)
        g_msg = winner * _take0(
            _split_ties(y_bar, winner, seg_idx, num_segments), seg_idx)
    dh = jnp.zeros(h.shape, jnp.float32).at[gather_idx].add(
        _f32(g_msg) * _f32(weight)[:, None])
    # dW = SDDMM: per-edge dot of gathered rows (paper §VI)
    dw = jnp.sum(_f32(jnp.take(h, gather_idx, axis=0)) * _f32(g_msg),
                 axis=-1).astype(weight.dtype)
    return (_accum_cast(dh, h), None, dw, None, None)


index_weight_segment_reduce.defvjp(_iwsr_fwd, _iwsr_bwd)


def _ftr_aggregate(h, gather_idx, weight, seg_idx, num_segments, reduce,
                   impl, config, plan, tune):
    """The Agg(H) half of the fused op (recomputed by the backward for dW):
    plain or weighted gather-reduce through the existing dispatchers."""
    if weight is None:
        return index_segment_reduce(h, gather_idx, seg_idx, num_segments,
                                    reduce, impl, config, plan, tune)
    return index_weight_segment_reduce(h, gather_idx, weight, seg_idx,
                                       num_segments, reduce, impl, config,
                                       plan, tune)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 10))
def fused_transform_reduce(h, w, gather_idx, weight, seg_idx,
                           num_segments: int, reduce: str = "sum",
                           impl: str = "ref",
                           config: Optional[KernelConfig] = None, plan=None,
                           tune: Optional[bool] = None):
    """Fully-fused transform-aggregate (SpMM+GEMM in one launch):

        Y[s] = ( reduce_{i: seg_idx[i]==s} w_e[i] · H[gather_idx[i]] ) @ W

    Linear reduces only (sum / mean) — the dense transform distributes over
    the reduction, which is what lets ``impl="pallas"`` aggregate at width
    d_in and transform per output block inside one kernel
    (:mod:`repro.kernels.fused_transform_reduce`) without ever
    materializing the (|E|, d) edge tensor or the (S, d_in) aggregate.
    ``weight=None`` for the unweighted form. Differentiable in H, W, and
    weight; gradients accumulate fp32 and are cast back to the io dtype:

        dW = Agg(H)ᵀ @ Ȳ            (one recomputed aggregation launch)
        dH = scatter-add of w_e[i] · (Ȳ @ Wᵀ)[seg_idx[i]]
        dw_e[i] = <H[gather_idx[i]], (Ȳ @ Wᵀ)[seg_idx[i]]>   (SDDMM)
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.fused_transform_reduce(h, w, gather_idx, seg_idx,
                                           num_segments, weight=weight,
                                           reduce=reduce, config=config,
                                           plan=plan, tune=tune)
    _account_unfused(f"fused_transform_reduce_{reduce}:{impl}")
    agg = _ftr_aggregate(h, gather_idx, weight, seg_idx, num_segments,
                         reduce, impl, config, plan, tune)
    return jnp.dot(agg, w, preferred_element_type=jnp.float32).astype(h.dtype)


def _ftr_fwd(h, w, gather_idx, weight, seg_idx, num_segments, reduce, impl,
             config, plan=None, tune=None):
    y = fused_transform_reduce(h, w, gather_idx, weight, seg_idx,
                               num_segments, reduce, impl, config, plan, tune)
    return y, (h, w, gather_idx, weight, seg_idx, plan)


def _ftr_bwd(num_segments, reduce, impl, config, tune, res, y_bar):
    h, w, gather_idx, weight, seg_idx, plan = res
    # dW: recompute the (S, d_in) aggregate (one launch — the forward never
    # materialized it, that's the point) and contract fp32 against Ȳ
    agg = _ftr_aggregate(h, gather_idx, weight, seg_idx, num_segments,
                         reduce, impl, config, plan, tune)
    dw = jnp.dot(_f32(agg).T, _f32(y_bar)).astype(w.dtype)
    # route Ȳ back through the transform, then through the aggregation
    g = jnp.dot(_f32(y_bar), _f32(w).T)                  # (S, d_in) fp32
    if reduce == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(seg_idx, dtype=jnp.float32), seg_idx, num_segments,
            indices_are_sorted=True)
        g = g / jnp.maximum(cnt, 1.0)[:, None]
    g_edges = _take0(g, seg_idx)                         # (E, d_in) fp32
    wt_f32 = None if weight is None else _f32(weight)
    scaled = g_edges if weight is None else g_edges * wt_f32[:, None]
    dh = _accum_cast(
        jnp.zeros(h.shape, jnp.float32).at[gather_idx].add(scaled), h)
    dwt = None
    if weight is not None:
        dwt = jnp.sum(_f32(jnp.take(h, gather_idx, axis=0)) * g_edges,
                      axis=-1).astype(weight.dtype)
    return (dh, dw, None, dwt, None, None)


fused_transform_reduce.defvjp(_ftr_fwd, _ftr_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 7))
def sddmm(h_out, h_in, row_idx, col_idx, impl: str = "ref",
          config: Optional[KernelConfig] = None, plan=None,
          tune: Optional[bool] = None):
    """Sampled dense-dense matmul: per-edge dot products (paper §VI).
    out[i] = <h_out[row_idx[i]], h_in[col_idx[i]]>.

    ``impl="pallas"`` runs the blocked gather kernel; the ``(plan=,
    config=, tune=)`` trio follows the one precedence rule of
    ``docs/plans.md`` (a SegmentPlan contributes only its config — SDDMM
    is a pure gather and reads no chunk metadata)."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.sddmm(h_out, h_in, row_idx, col_idx, config=config,
                          plan=plan, tune=tune)
    return jnp.sum(jnp.take(h_out, row_idx, axis=0) *
                   jnp.take(h_in, col_idx, axis=0), axis=-1)


def _sddmm_fwd(h_out, h_in, row_idx, col_idx, impl, config, plan=None,
               tune=None):
    y = sddmm(h_out, h_in, row_idx, col_idx, impl, config, plan, tune)
    return y, (h_out, h_in, row_idx, col_idx)


def _sddmm_bwd(impl, config, tune, res, g):
    h_out, h_in, row_idx, col_idx = res
    # d<a_r, b_c>/da_r = g·b_c and symmetrically for b: two scatter-adds,
    # fp32-accumulated and cast back to the operands' io dtype
    da = jnp.zeros(h_out.shape, jnp.float32).at[row_idx].add(
        _f32(g)[:, None] * _f32(jnp.take(h_in, col_idx, axis=0)))
    db = jnp.zeros(h_in.shape, jnp.float32).at[col_idx].add(
        _f32(g)[:, None] * _f32(jnp.take(h_out, row_idx, axis=0)))
    return (_accum_cast(da, h_out), _accum_cast(db, h_in), None, None, None)


sddmm.defvjp(_sddmm_fwd, _sddmm_bwd)


def _segment_softmax_ref(x, idx, num_segments: int):
    """Three-pass jnp oracle: segment_max → exp → segment_sum → normalize."""
    m = jax.ops.segment_max(x, idx, num_segments, indices_are_sorted=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(x - jnp.take(m, idx, axis=0))
    z = jax.ops.segment_sum(e, idx, num_segments, indices_are_sorted=True)
    return e / jnp.take(jnp.maximum(z, 1e-20), idx, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 6))
def segment_softmax(x, idx, num_segments: int, impl: str = "ref",
                    config: Optional[KernelConfig] = None, plan=None,
                    tune: Optional[bool] = None):
    """Softmax within segments (GAT-style attention over sorted edges).

    ``x``: (M,) or (M, H) logits — heads share the segment structure.
    ``impl="pallas"`` runs the fused plan-aware kernel (one launch, online
    max/sum-exp — see :mod:`repro.kernels.segment_softmax`); ``"ref"`` /
    ``"blocked"`` use the three-pass jnp formulation. ``plan``: precomputed
    SegmentPlan over ``idx``."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.segment_softmax(x, idx, num_segments, config=config,
                                    plan=plan, tune=tune)
    _account_unfused(f"segment_softmax:{impl}")
    return _segment_softmax_ref(x, idx, num_segments)


def _ssm_fwd(x, idx, num_segments, impl, config, plan=None, tune=None):
    p = segment_softmax(x, idx, num_segments, impl, config, plan, tune)
    return p, (p, idx)


def _ssm_bwd(num_segments, impl, config, tune, res, g):
    p, idx = res
    # d softmax: p ⊙ (g − Σ_{segment} p·g), the per-segment Jacobian action;
    # the segment-sum and the Jacobian product run fp32, cast back after
    t = jax.ops.segment_sum(_f32(p * g), idx, num_segments,
                            indices_are_sorted=True)
    return (_accum_cast(_f32(p) * (_f32(g) - _take0(t, idx)), p), None, None)


segment_softmax.defvjp(_ssm_fwd, _ssm_bwd)


def _gsm_dispatch(x, group_sizes, w, impl, config, plan, tune):
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.segment_matmul(x, group_sizes, w, config=config,
                                   plan=plan, tune=tune)
    _account_unfused(f"grouped_segment_matmul:{impl}")
    return jax.lax.ragged_dot(x, w, group_sizes)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 6))
def grouped_segment_matmul(x, group_sizes, w, impl: str = "ref",
                           config: Optional[KernelConfig] = None, plan=None,
                           tune: Optional[bool] = None):
    """Grouped GEMM over contiguous row groups (FASTEN's critical
    heterogeneous-GNN operator; also the MoE expert hot path):

        out[rows of group e] = X[rows of group e] @ W[e]

    x: (M, K) sorted so rows of the same group are contiguous;
    group_sizes: (E,) int32 rows per group (sum ≤ M); w: (E, K, N).
    Rows beyond ``sum(group_sizes)`` (padding) produce zeros and receive
    zero gradient — the out-of-range drop convention every reduce's
    backward follows (:func:`_take0`).

    ``plan``: a :class:`repro.core.plan.RelationPlan` — for
    ``impl="pallas"`` its precomputed block/group metadata feeds the
    kernel's scalar-prefetch operands and its tight ``max_groups`` bounds
    the grid. Differentiable in x and w with a custom VJP:

        dX = grouped_segment_matmul(dY, sizes, Wᵀ)   (one grouped launch)
        dW[e] = X[rows e]ᵀ @ dY[rows e]              (segment-summed outer)
    """
    return _gsm_dispatch(x, group_sizes, w, impl, config, plan, tune)


def _gsm_fwd(x, group_sizes, w, impl, config, plan=None, tune=None):
    y = _gsm_dispatch(x, group_sizes, w, impl, config, plan, tune)
    return y, (x, group_sizes, w, plan)


def _gsm_bwd(impl, config, tune, res, y_bar):
    x, group_sizes, w, plan = res
    y_bar = y_bar.astype(x.dtype)
    # dX: the transposed grouped matmul reuses the plan — its block/group
    # metadata depends only on (group_sizes, num_rows, m_b), all unchanged;
    # the kernel re-clamps n_b to the transposed feature dim.
    dx = _gsm_dispatch(y_bar, group_sizes, w.transpose(0, 2, 1), impl,
                       config, plan, tune)
    # dW: per-group Xᵀ dY as a segment-sum of row outer products. Rows past
    # sum(group_sizes) are clipped into the last group but masked to zero —
    # out-of-range rows contribute no gradient.
    m = x.shape[0]
    e = group_sizes.shape[0]
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes.astype(jnp.int32))])
    rows = jnp.arange(m, dtype=jnp.int32)
    gid = jnp.clip(jnp.searchsorted(offsets, rows, side="right") - 1,
                   0, e - 1)
    valid = (rows < offsets[-1]).astype(jnp.float32)
    outer = ((_f32(x) * valid[:, None])[:, :, None] *
             _f32(y_bar)[:, None, :]).reshape(m, x.shape[1] * y_bar.shape[1])
    dw = jax.ops.segment_sum(outer, gid, e, indices_are_sorted=True)
    return (dx, None, dw.reshape(w.shape).astype(w.dtype), None)


grouped_segment_matmul.defvjp(_gsm_fwd, _gsm_bwd)


def segment_matmul(x, group_sizes, w, impl: str = "ref",
                   config: Optional[KernelConfig] = None, plan=None,
                   tune: Optional[bool] = None):
    """Grouped GEMM over contiguous segments — alias of
    :func:`grouped_segment_matmul` kept for the original MoE call sites
    (identical semantics, VJP, and kwarg trio)."""
    return grouped_segment_matmul(x, group_sizes, w, impl, config, plan,
                                  tune)
