"""Pure-numpy multi-output decision-tree regressor (paper §III-C).

The paper uses sklearn's multi-output ``DecisionTreeRegressor`` (depth ≤ 5)
so the *whole configuration set* ⟨T_N, T_M, M_t, N_t, G_t⟩ is selected
jointly rather than per-parameter.  sklearn is not available in this
container, so we implement CART with variance-reduction splits summed over
the output dimensions — the same algorithm — in numpy.  The fitted tree is
consumed by :mod:`repro.core.codegen`, which emits branch-free if/else rules
(the analogue of the paper's generated kernel-config ``.so``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class _Node:
    # internal node
    feature: int = -1
    threshold: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    # leaf payload (multi-output mean)
    value: Optional[np.ndarray] = None

    @property
    def is_leaf(self) -> bool:
        return self.value is not None


class MultiOutputDecisionTree:
    """CART regressor, multi-output, variance-reduction criterion."""

    def __init__(self, max_depth: int = 5, min_samples_leaf: int = 8,
                 min_samples_split: int = 16):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.min_samples_split = min_samples_split
        self.root: Optional[_Node] = None
        self.n_features_ = 0
        self.n_outputs_ = 0

    # -- fitting ----------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray) -> "MultiOutputDecisionTree":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        if y.ndim == 1:
            y = y[:, None]
        self.n_features_ = x.shape[1]
        self.n_outputs_ = y.shape[1]
        self.root = self._build(x, y, depth=0)
        return self

    def _build(self, x: np.ndarray, y: np.ndarray, depth: int) -> _Node:
        n = x.shape[0]
        if (depth >= self.max_depth or n < self.min_samples_split
                or self._pure(y)):
            return _Node(value=y.mean(axis=0))
        feat, thr, gain = self._best_split(x, y)
        if feat < 0 or gain <= 1e-12:
            return _Node(value=y.mean(axis=0))
        mask = x[:, feat] <= thr
        if mask.sum() < self.min_samples_leaf or (~mask).sum() < self.min_samples_leaf:
            return _Node(value=y.mean(axis=0))
        return _Node(feature=feat, threshold=thr,
                     left=self._build(x[mask], y[mask], depth + 1),
                     right=self._build(x[~mask], y[~mask], depth + 1))

    @staticmethod
    def _pure(y: np.ndarray) -> bool:
        return bool(np.all(y.var(axis=0) < 1e-12))

    def _best_split(self, x: np.ndarray, y: np.ndarray):
        n = x.shape[0]
        parent_sse = float(((y - y.mean(axis=0)) ** 2).sum())
        best = (-1, 0.0, 0.0)
        for f in range(self.n_features_):
            order = np.argsort(x[:, f], kind="stable")
            xs, ys = x[order, f], y[order]
            # cumulative sums for O(n) split evaluation across all outputs
            csum = np.cumsum(ys, axis=0)
            csq = np.cumsum(ys ** 2, axis=0)
            tot_sum, tot_sq = csum[-1], csq[-1]
            for i in range(self.min_samples_leaf - 1,
                           n - self.min_samples_leaf):
                if xs[i] == xs[i + 1]:
                    continue
                nl = i + 1
                nr = n - nl
                sse_l = float((csq[i] - csum[i] ** 2 / nl).sum())
                sse_r = float(((tot_sq - csq[i])
                               - (tot_sum - csum[i]) ** 2 / nr).sum())
                gain = parent_sse - (sse_l + sse_r)
                if gain > best[2]:
                    best = (f, float((xs[i] + xs[i + 1]) / 2.0), gain)
        return best

    # -- inference --------------------------------------------------------
    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float64)
        single = x.ndim == 1
        if single:
            x = x[None]
        out = np.stack([self._predict_one(row) for row in x])
        return out[0] if single else out

    def _predict_one(self, row: np.ndarray) -> np.ndarray:
        node = self.root
        assert node is not None, "tree not fitted"
        while not node.is_leaf:
            node = node.left if row[node.feature] <= node.threshold else node.right
        return node.value

    # -- introspection ----------------------------------------------------
    def num_leaves(self) -> int:
        def count(n: Optional[_Node]) -> int:
            if n is None:
                return 0
            return 1 if n.is_leaf else count(n.left) + count(n.right)
        return count(self.root)

    def depth(self) -> int:
        def d(n: Optional[_Node]) -> int:
            if n is None or n.is_leaf:
                return 0
            return 1 + max(d(n.left), d(n.right))
        return d(self.root)
