"""Sharded, fault-tolerant checkpointing.

Layout:  <dir>/step_<N>/ {leaf files *.npy + MANIFEST.json}
Atomicity: leaves are written into a ``.tmp-step_<N>`` directory, the
manifest is written last, then the directory is atomically renamed —
a crash mid-save can never produce a directory that ``latest_step`` will
pick up.  ``save_async`` snapshots to host memory synchronously (so the
training loop can donate buffers) and writes on a background thread.

Elastic restore: leaves are loaded to host then ``jax.device_put`` with the
*target* sharding — restoring onto a different mesh shape than the one that
saved is supported (tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path).replace("/", "_")


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def save(tree: Any, directory: str | os.PathLike, step: int,
         keep: Optional[int] = None) -> pathlib.Path:
    """Synchronous atomic save. Returns the final step directory."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp-step_{step}"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        key = _leaf_key(path)
        fname = hashlib.sha1(key.encode()).hexdigest()[:20] + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "key": key, "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    if keep is not None:
        _retain(directory, keep)
    return final


_PENDING: list[threading.Thread] = []


def save_async(tree: Any, directory, step: int,
               keep: Optional[int] = None) -> threading.Thread:
    """Snapshot to host now, write in the background."""
    host_tree = jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)
    th = threading.Thread(target=save, args=(host_tree, directory, step, keep),
                          daemon=True)
    th.start()
    _PENDING.append(th)
    return th


def wait_pending():
    for th in _PENDING:
        th.join()
    _PENDING.clear()


def latest_step(directory, at_or_before: Optional[int] = None) -> Optional[int]:
    """Newest complete checkpoint step, or None.

    ``at_or_before`` bounds the answer: the newest step ``<=`` it. The
    failure-recovery path needs this — restoring a checkpoint *newer*
    than the failed step (stale steps from an earlier run sharing the
    directory) would jump the loop past its failure point with foreign
    state."""
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for child in directory.iterdir():
        m = _STEP_RE.match(child.name)
        if m and (child / "MANIFEST.json").exists():
            s = int(m.group(1))
            if at_or_before is None or s <= at_or_before:
                steps.append(s)
    return max(steps) if steps else None


def restore(target_tree: Any, directory, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree``.

    shardings: optional pytree (same structure or prefix) of
    jax.sharding.Sharding — enables elastic restore onto a new mesh."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = directory / f"step_{step}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    by_key = {l["key"]: l for l in manifest["leaves"]}

    leaves, treedef = _flatten(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if len(shard_leaves) == 1:
            shard_leaves = shard_leaves * len(leaves)

    out = []
    for i, (path, leaf) in enumerate(leaves):
        key = _leaf_key(path)
        if key not in by_key:
            raise KeyError(f"checkpoint {d} missing leaf {key}")
        arr = np.load(d / by_key[key]["file"])
        want = np.dtype(by_key[key]["dtype"])
        if arr.dtype != want:
            # np.load round-trips extension dtypes (bfloat16, …) as raw
            # void bytes — reinterpret via the manifest dtype
            arr = arr.view(want) if arr.dtype.kind == "V" else arr.astype(want)
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != target {expect}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr.astype(
                getattr(leaf, "dtype", arr.dtype))))
    return jax.tree_util.tree_unflatten(treedef, out)


def _retain(directory: pathlib.Path, keep: int):
    steps = sorted(
        int(_STEP_RE.match(c.name).group(1))
        for c in directory.iterdir()
        if _STEP_RE.match(c.name) and (c / "MANIFEST.json").exists())
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step_{s}", ignore_errors=True)
