"""repro — GeoT reproduction: tensor-centric segment reduction for GNNs
(JAX/Pallas on TPU; interpret mode on CPU).

One curated import surface over the layered packages, so examples and
downstream code stop deep-importing module paths:

    import repro

    g = repro.synth_typed_graph("demo", 1024, 8192, num_relations=6)
    plan = g.make_plan()                       # fused-reduce schedule
    rplan = g.make_relation_plan()             # grouped-matmul schedule
    params = repro.gnn_init(key, "rgcn", 32, 64, 16, num_relations=6)
    logits = repro.gnn_forward(params, "rgcn", x, edge_index, g.num_nodes,
                               impl="pallas", plan=plan, rplan=rplan,
                               edge_type=g.edge_type)

Layers underneath (deep imports remain supported):
    repro.core    — ops + plans + config selection/autotune
    repro.kernels — the Pallas kernels and their jit'd wrappers
    repro.data    — graph synthesis, batching/padding, partitioning
    repro.models  — GNN/MoE/LM model zoo
    repro.serve   — GNN inference serving engine
    repro.train   — DatasetProvider → Task → Trainer orchestration

Training is one call (see :mod:`repro.train` / ``docs/training.md``):

    data = repro.GraphEpochProvider()
    task = repro.NodeClassification.from_provider(data, model="gcn")
    result = repro.fit(task, data, repro.TrainerConfig(steps=100))

Telemetry rides along everywhere (see ``docs/observability.md``):
``repro.obs`` is the metrics registry / tracing-span / attribution
subsystem every engine, cache, pipeline and trainer reports into —
``print(repro.obs.report())`` after any of the above summarizes what
ran, what compiled, and why.
"""
from repro import obs
from repro.core.config_space import KernelConfig
from repro.core.mp import choose_order, mp, mp_transform, mp_typed
from repro.core.ops import (
    gather,
    grouped_segment_matmul,
    index_segment_reduce,
    index_weight_segment_reduce,
    sddmm,
    segment_matmul,
    segment_reduce,
    segment_softmax,
)
from repro.core.plan import (
    RelationPlan,
    SegmentPlan,
    make_graph_plan,
    make_plan,
    make_relation_plan,
)
from repro.data.graphs import (
    Graph,
    TypedGraph,
    batch_graphs,
    dataset,
    pad_graph,
    synth_graph,
    synth_typed_graph,
)
from repro.data.pipeline import PrefetchPipeline, SampledBatchProducer
from repro.data.sampling import (
    NeighborSampler,
    ShardedGraphStore,
    Subgraph,
    save_graph_shards,
)
from repro.models.gnn import MODELS, TYPED_MODELS
from repro.models.gnn import forward as gnn_forward
from repro.models.gnn import init as gnn_init
from repro.serve import GNNServer
from repro.train import (
    DatasetProvider,
    GraphEpochProvider,
    NodeClassification,
    SampledNodeProvider,
    Task,
    Trainer,
    TrainerConfig,
    TrainState,
    fit,
)

__all__ = [
    # graphs
    "Graph", "TypedGraph", "synth_graph", "synth_typed_graph", "dataset",
    "batch_graphs", "pad_graph",
    # plans + config
    "SegmentPlan", "RelationPlan", "make_plan", "make_graph_plan",
    "make_relation_plan", "KernelConfig",
    # segment-reduction op family
    "segment_reduce", "index_segment_reduce", "index_weight_segment_reduce",
    "segment_softmax", "segment_matmul", "grouped_segment_matmul", "sddmm",
    "gather",
    # message passing
    "mp", "mp_transform", "mp_typed", "choose_order",
    # sampling + out-of-core pipeline
    "NeighborSampler", "Subgraph", "ShardedGraphStore", "save_graph_shards",
    "SampledBatchProducer", "PrefetchPipeline",
    # models + serving
    "MODELS", "TYPED_MODELS", "gnn_init", "gnn_forward", "GNNServer",
    # training orchestration
    "DatasetProvider", "GraphEpochProvider", "SampledNodeProvider", "Task",
    "NodeClassification", "Trainer", "TrainerConfig", "TrainState", "fit",
    # telemetry
    "obs",
]
