"""AdamW with configurable state precision (fp32 / bf16 / int8-quantized
moments) — the optimizer-memory lever for the trillion-parameter cells.

int8 states use per-tensor absmax scaling (blockwise refinement noted in
DESIGN.md); the quantization error is re-absorbed every step since moments
are reconstructed, updated in fp32, and re-quantized.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # float32 | bfloat16 | int8


class QTensor(NamedTuple):
    """int8 payload + fp32 absmax scale (per tensor)."""
    q: jax.Array
    scale: jax.Array


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    return QTensor(jnp.round(x / scale).astype(jnp.int8), scale)


def _dequantize(qt: QTensor):
    return qt.q.astype(jnp.float32) * qt.scale


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def _encode(x, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.dtype(dtype))


def _decode(x, dtype: str):
    if dtype == "int8":
        return _dequantize(x)
    return x.astype(jnp.float32)


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: _encode(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params)
    zeros2 = jax.tree_util.tree_map(
        lambda p: _encode(jnp.zeros(p.shape, jnp.float32), cfg.state_dtype),
        params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros2)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state: AdamWState, params, cfg: AdamWConfig,
           lr_scale=1.0):
    """Returns (new_params, new_state, metrics). Trees may be P-trees (the
    math applies leaf-wise to raw arrays)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale
    sd = cfg.state_dtype
    is_q = lambda x: isinstance(x, QTensor)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * _decode(mu, sd) + (1.0 - cfg.b1) * g
        nu = cfg.b2 * _decode(nu, sd) + (1.0 - cfg.b2) * jnp.square(g)
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, _encode(mu, sd), _encode(nu, sd)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_mu = treedef.flatten_up_to(state.mu) if sd == "int8" else \
        jax.tree_util.tree_leaves(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu) if sd == "int8" else \
        jax.tree_util.tree_leaves(state.nu)
    flat_p = jax.tree_util.tree_leaves(params)
    out = [upd(g, m, n, p) for g, m, n, p
           in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
