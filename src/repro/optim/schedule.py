"""LR schedules (warmup + cosine decay), addressable by name via
:func:`get` — every schedule shares the ``(step, warmup_steps,
total_steps)`` signature and returns a multiplicative scale on the
optimizer's base LR."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    """Multiplicative LR scale in [min_ratio, 1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, warmup_steps: int = 0, total_steps: int = 0):
    """Flat scale 1 after the linear warmup (``total_steps`` unused)."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    return jnp.where(step < warmup_steps, warm, jnp.ones_like(step))


_SCHEDULES = {"warmup_cosine": warmup_cosine, "constant": constant}


def get(name: str):
    """Resolve a schedule by name (the ``TrainerConfig.lr_schedule`` knob)."""
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(f"unknown LR schedule {name!r}; "
                         f"known: {sorted(_SCHEDULES)}") from None
