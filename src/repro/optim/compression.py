"""Error-feedback int8 gradient compression (cross-pod hop).

1-bit/8-bit Adam-style EF-compression: the quantization residual is carried
in an error-feedback buffer and re-injected next step, so the compressed
all-reduce is unbiased in the long run.  Used by
``repro.distributed.collectives.compressed_psum`` for the pod axis (DCI is
the thin link — 8× fewer bytes cross-pod), and unit-tested standalone.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # () fp32 absmax scale


def compress(x, error_feedback):
    """(x + ef) → int8; returns (compressed, new_ef)."""
    v = x.astype(jnp.float32) + error_feedback
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    new_ef = v - q.astype(jnp.float32) * scale
    return Compressed(q, scale), new_ef


def decompress(c: Compressed):
    return c.q.astype(jnp.float32) * c.scale


def init_error_feedback(tree):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def compress_tree(grads, ef_tree) -> Tuple:
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_ef = treedef.flatten_up_to(ef_tree)
    pairs = [compress(g, e) for g, e in zip(flat_g, flat_ef)]
    comp = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    new_ef = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return comp, new_ef


def decompress_tree(comp):
    return jax.tree_util.tree_map(
        decompress, comp, is_leaf=lambda x: isinstance(x, Compressed))
