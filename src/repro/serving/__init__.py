"""Deprecation shim: ``repro.serving`` moved into :mod:`repro.serve`.

The seed-era LM continuous-batching scheduler
(``repro.serving.scheduler``) now lives at :mod:`repro.serve.lm`; the GNN
inference engine is :mod:`repro.serve.engine`. The two near-identical
package names confused imports for five PRs — this one raises so the
stale path fails loudly instead of silently shadowing."""

raise ImportError(
    "repro.serving was retired: the LM continuous-batching scheduler "
    "moved to repro.serve.lm (from repro.serve.lm import "
    "ContinuousBatcher, Request); the GNN serving engine is repro.serve "
    "(from repro.serve import GNNServer).")
