"""The ``Task`` leg of the orchestration protocol: what the model is and
what its loss means, decoupled from where batches come from (providers)
and how the loop runs (the trainer).

A task implements three methods (the :class:`Task` protocol):

  * ``init(rng) -> params``
  * ``prepare(batch, *, plan=None, config=None, tune=None, mesh=None)
    -> (arrays, static)`` — split a provider batch into the *traced*
    pytree (``arrays``: features, indices, the plan) and a hashable
    *static signature* (``static``: the shape bucket). The trainer keys
    its jitted-executable cache on ``static`` and feeds ``arrays``
    through it — so ``prepare`` is where the compile discipline is won
    or lost.
  * ``loss(params, arrays, static, rng, *, mesh=None) -> (loss, metrics)``
    — pure, differentiable; runs inside the jitted step.

An optional ``build_step(trainer_cfg, mesh, static)`` hook lets a task
supply its own complete ``(state, arrays) -> (state, metrics)`` step
(returning None defers to the trainer's generic one) — how the LM task
revives the pjit build-step pattern of :mod:`repro.distributed.step`
when a parallelism mesh is given.

Plan canonicalization (:class:`NodeClassification`): a
:class:`~repro.core.plan.SegmentPlan`'s *static aux* (kernel config,
tight ``max_chunks``, degree stats) is per-graph — two same-shape graphs
each bringing their own plan would retrace the step, exactly the problem
:mod:`repro.serve.plan_cache` solves for serving. Training borrows the
same move at graph granularity: the first graph of a bucket fixes the
bucket's canonical config + stats, ``max_chunks`` is pinned to the
bucket-static worst case, and every later same-bucket plan swaps only
its chunk-metadata *leaves* under that aux — same treedef, zero
retraces (``Trainer.traces`` asserts it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.data.graphs import TypedGraph
from repro.models import gnn
from repro.train.trainer import TrainState

__all__ = ["Task", "GraphStatic", "NodeClassification", "LMStatic", "LMTask"]


@runtime_checkable
class Task(Protocol):
    """Structural protocol — any object with these three methods trains."""

    def init(self, rng) -> Any:                        # pragma: no cover
        ...

    def prepare(self, batch, *, plan=None, config=None, tune=None,
                mesh=None) -> tuple:                   # pragma: no cover
        ...

    def loss(self, params, arrays, static, rng, *,
             mesh=None) -> tuple:                      # pragma: no cover
        ...


class GraphStatic(NamedTuple):
    """Hashable shape bucket of a graph batch — the executable-cache key.
    ``shards`` is 0 single-device, else the mesh size. ``sampled`` marks
    mini-batches from the out-of-core pipeline: their arrays carry a
    ``label_mask`` the loss must honor, so they may not share an
    executable with a same-shape full-graph batch (different treedef)."""
    model: str
    num_nodes: int
    num_edges: int
    typed: bool
    shards: int
    sampled: bool = False


@dataclasses.dataclass
class NodeClassification:
    """Full-graph node classification on :mod:`repro.models.gnn` (paper
    §V-F): cross-entropy over per-node logits, accuracy as the metric.

    Works for every model family — homogeneous (``gcn``/``gin``/``sage``/
    ``gat``) on :class:`~repro.data.graphs.Graph` batches and relational
    (``rgcn``/``rgat``) on :class:`~repro.data.graphs.TypedGraph` ones
    (which additionally ride their permutation triple and a canonicalized
    :class:`~repro.core.plan.RelationPlan`).

    ``mesh=`` (via the trainer) partitions each graph over the mesh once
    (memoized) and trains through :mod:`repro.core.dist_mp` — typed
    families stay single-shard, like the layers themselves. Note the
    one-trace-per-bucket guarantee is single-device: a partition's node
    ranges are degree-balanced per graph and ride the pytree treedef, so
    sharded training compiles once per (bucket, partition layout).
    """
    model: str = "gcn"
    d_in: int = 32
    hidden: int = 64
    num_classes: int = 16
    num_layers: int = 3
    heads: int = 1
    num_relations: int = 4
    impl: str = "pallas"

    def __post_init__(self):
        self._dev: dict = {}       # id(g) -> (g, device arrays)
        self._parts: dict = {}     # (id(g), shards) -> (g, part)
        self._pplans: dict = {}    # (id(g), shards, feat, key) -> pplan
        self._buckets: dict = {}   # (static, config, tune) -> canonical aux

    @classmethod
    def from_provider(cls, provider, model: str = "gcn", **kw):
        """Size the task off a provider's metadata (feat / classes /
        relations) — the common wiring of examples and tests."""
        kw.setdefault("num_relations", max(provider.num_relations, 1))
        return cls(model=model, d_in=provider.feat,
                   num_classes=provider.num_classes, **kw)

    @property
    def plan_feat(self) -> int:
        """Representative feature width for config selection: the widest
        layer width, as :func:`repro.models.gnn.make_model_plan` uses."""
        return max(self.d_in, self.hidden, self.num_classes)

    # -- protocol ------------------------------------------------------------

    def init(self, rng):
        return gnn.init(rng, self.model, self.d_in, self.hidden,
                        self.num_classes, self.num_layers, heads=self.heads,
                        num_relations=self.num_relations)

    def prepare(self, batch, *, plan=None, config=None, tune=None, mesh=None):
        from repro.data.pipeline import SampledBatch
        if isinstance(batch, SampledBatch):
            return self._prepare_sampled(batch, plan=plan, mesh=mesh)
        g = batch
        typed = isinstance(g, TypedGraph)
        if typed != (self.model in gnn.TYPED_MODELS):
            raise ValueError(
                f"model {self.model!r} and batch graph type disagree: "
                f"typed={typed} (use a GraphEpochProvider(typed=...) that "
                "matches the model family)")
        shards = int(mesh.devices.size) if mesh is not None else 0
        if typed and shards:
            raise NotImplementedError("typed layers are single-shard for now")
        static = GraphStatic(self.model, g.num_nodes, g.num_edges, typed,
                             shards)
        arrays = dict(self._device_arrays(g))
        if shards:
            part, pplan = self._partitioned(g, shards, config, tune)
            arrays["partition"] = part
            arrays["plan"] = plan if plan is not None else pplan
        else:
            arrays["plan"] = (plan if plan is not None
                              else self._bucket_plan(g, static, config, tune))
            if typed:
                arrays["rplan"] = self._bucket_rplan(g, static, config, tune)
        return arrays, static

    def loss(self, params, arrays, static, rng, *, mesh=None):
        logits = gnn.forward(
            params, static.model, arrays["x"], arrays["edge_index"],
            static.num_nodes, arrays.get("deg_inv_sqrt"), self.impl,
            arrays.get("plan"), mesh=mesh,
            partition=arrays.get("partition"),
            edge_type=arrays.get("edge_type"),
            type_perm=arrays.get("type_perm"),
            inv_type_perm=arrays.get("inv_type_perm"),
            type_counts=arrays.get("type_counts"),
            rplan=arrays.get("rplan"))
        labels = arrays["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        mask = arrays.get("label_mask")
        if mask is None:
            return jnp.mean(logz - gold), {"accuracy": jnp.mean(correct)}
        # sampled mini-batch: only the seed rows carry full (exact or
        # fanout-complete) neighborhoods — supervising padded/neighbor
        # rows would train on truncated aggregations and drop-id noise
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return (jnp.sum(mask * (logz - gold)) / denom,
                {"accuracy": jnp.sum(mask * correct) / denom})

    def _prepare_sampled(self, batch, *, plan=None, mesh=None):
        """Sampled mini-batches arrive device-ready: the pipeline's
        producer already padded to a bucket, stamped the plan under the
        bucket entry's canonical aux, and issued the host→device copies.
        No memoization here — every batch is a fresh object (the ``_dev``
        id-keyed memo would leak), and none is needed: all the per-shape
        work was paid once, in the shared :class:`~repro.serve.plan_cache.
        PlanCache`."""
        if mesh is not None:
            raise NotImplementedError(
                "sampled mini-batches are single-device for now (shard the "
                "sampler by seed range instead)")
        if self.model in gnn.TYPED_MODELS:
            raise ValueError(
                f"model {self.model!r} is relational; the neighbor sampler "
                "emits homogeneous subgraphs")
        static = GraphStatic(self.model, batch.bucket.num_nodes,
                             batch.bucket.num_edges, False, 0, sampled=True)
        arrays = dict(batch.arrays)
        arrays["plan"] = plan if plan is not None else batch.plan
        return arrays, static

    # -- memoized per-graph state -------------------------------------------

    def _device_arrays(self, g) -> dict:
        hit = self._dev.get(id(g))
        if hit is not None and hit[0] is g:
            return hit[1]
        arrays = {"x": jnp.asarray(g.x),
                  "edge_index": jnp.asarray(g.edge_index),
                  "labels": jnp.asarray(g.labels),
                  "deg_inv_sqrt": jnp.asarray(g.deg_inv_sqrt)}
        if isinstance(g, TypedGraph):
            arrays.update(edge_type=jnp.asarray(g.edge_type),
                          type_perm=jnp.asarray(g.type_perm),
                          inv_type_perm=jnp.asarray(g.inv_type_perm),
                          type_counts=jnp.asarray(g.type_counts))
        # pin g in the memo: id() is only unique among live objects
        self._dev[id(g)] = (g, arrays)
        return arrays

    def _bucket_plan(self, g, static: GraphStatic, config, tune):
        """This graph's plan leaves under the bucket's canonical aux (see
        the module docstring) — same treedef for every graph in the
        bucket, so the step executable never retraces."""
        bkey = ("seg", static, config, tune)
        canon = self._buckets.get(bkey)
        if canon is None:
            p0 = g.make_plan(self.plan_feat, config=config, tune=tune)
            canon = self._buckets[bkey] = (p0.config, p0.stats)
        cfg, stats = canon
        p = g.make_plan(self.plan_feat, config=cfg)       # memoized on g
        return dataclasses.replace(p.pin_worst_case(), stats=stats)

    def _bucket_rplan(self, g, static: GraphStatic, config, tune):
        bkey = ("rel", static, config, tune)
        canon = self._buckets.get(bkey)
        if canon is None:
            r0 = g.make_relation_plan(self.plan_feat, config=config,
                                      tune=tune)
            canon = self._buckets[bkey] = (r0.config, r0.stats)
        cfg, stats = canon
        r = g.make_relation_plan(self.plan_feat, config=cfg)
        return dataclasses.replace(r, max_groups=r.worst_case_groups,
                                   stats=stats)

    def _partitioned(self, g, shards: int, config, tune):
        pkey = (id(g), shards)
        hit = self._parts.get(pkey)
        if hit is not None and hit[0] is g:
            part = hit[1]
        else:
            part = g.partition(shards)
            self._parts[pkey] = (g, part)
        plkey = (id(g), shards, self.plan_feat, config, tune)
        pplan = self._pplans.get(plkey)
        if pplan is None:
            pplan = part.make_plan(feat=self.plan_feat, config=config,
                                   tune=tune)
            self._pplans[plkey] = pplan
        return part, pplan


# ---------------------------------------------------------------------------
# the LM task — the seed's launch/train.py wiring behind the same protocol
# ---------------------------------------------------------------------------

class LMStatic(NamedTuple):
    batch: int
    seq: int


@dataclasses.dataclass
class LMTask:
    """Next-token LM training (:func:`repro.models.lm.loss_fn`) as a Task.

    Single-device it trains through the trainer's generic jitted step.
    With ``mesh=`` its :meth:`build_step` revives
    :func:`repro.distributed.step.build_train_step` — the pjit path with
    param/optimizer/batch shardings from the mesh's
    :class:`~repro.distributed.sharding.ParallelPlan` — behind the same
    ``(state, arrays) -> (state, metrics)`` surface, so
    ``repro.train.fit`` is the one entry point either way. (The pjit
    step keeps its own warmup-cosine schedule; ``TrainerConfig.
    lr_schedule`` applies to the generic step only.)

    The ``(plan=, config=, tune=)`` trio is accepted for protocol
    uniformity but has no effect: token batches carry no segment plans.
    """
    cfg: Any                         # repro.models.config.ModelConfig
    remat_policy: str = "none"
    moe_impl: str = "capacity"
    aux_weight: float = 0.01

    def init(self, rng):
        from repro.models import lm
        return lm.init(rng, self.cfg)

    def prepare(self, batch, *, plan=None, config=None, tune=None, mesh=None):
        arrays = {k: jnp.asarray(v) for k, v in batch.items()}
        b, s = arrays["tokens"].shape
        return arrays, LMStatic(int(b), int(s))

    def loss(self, params, arrays, static, rng, *, mesh=None):
        from repro.models import lm
        return lm.loss_fn(params, self.cfg, arrays,
                          remat_policy=self.remat_policy,
                          moe_impl=self.moe_impl, aux_weight=self.aux_weight)

    def build_step(self, trainer_cfg, mesh, static: LMStatic):
        if mesh is None:
            return None
        from repro.distributed import sharding as shd
        from repro.distributed import step as steplib
        plan = shd.ParallelPlan.for_mesh(mesh)
        ts = steplib.TrainStepConfig(
            opt=trainer_cfg.opt, warmup_steps=trainer_cfg.warmup_steps,
            total_steps=trainer_cfg.steps, remat_policy=self.remat_policy,
            moe_impl=self.moe_impl)
        fn, shardings_for = steplib.build_train_step(self.cfg, mesh, plan, ts)
        box: dict = {}

        def step(state: TrainState, arrays):
            if not box:
                # shardings need concrete params/opt trees — resolved
                # lazily on first call, then reused (outputs already land
                # sharded, so later device_puts are no-ops)
                shapes = {"tokens": (static.batch, static.seq),
                          "labels": (static.batch, static.seq)}
                in_sh, _ = shardings_for(state.params, state.opt_state,
                                         shapes)
                box["in_sh"] = in_sh
                box["jit"] = jax.jit(fn, in_shardings=in_sh)
            in_sh = box["in_sh"]
            params = jax.device_put(state.params, in_sh[0])
            opt = jax.device_put(state.opt_state, in_sh[1])
            batch = {k: jax.device_put(v, in_sh[2][k])
                     for k, v in arrays.items()}
            new_p, new_o, metrics = box["jit"](params, opt, batch, state.step)
            return (TrainState(new_p, new_o, state.step + 1, state.rng),
                    metrics)

        return step
