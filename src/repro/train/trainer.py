"""The ``Trainer`` leg of the orchestration protocol — assembles the
optimizer (:mod:`repro.optim.adamw` + :mod:`repro.optim.schedule`), one
**jitted plan-reusing train step per shape bucket**, periodic checkpointing
with resume, and the fault-tolerance machinery
(:class:`~repro.distributed.fault_tolerance.ResilientLoop` +
``StragglerMonitor`` / ``StepWatchdog``) around the loop.

Compile discipline — the property the whole library exists for: the task's
``prepare`` maps each batch to a hashable *static signature* (its shape
bucket); the trainer jits exactly one step executable per signature, and
the batch's :class:`~repro.core.plan.SegmentPlan` rides into it **as a
pytree argument** — chunk-metadata leaves vary per graph, the static aux
(kernel config, grid bound) is part of the treedef — so re-invocation on
the same bucket never retraces. A trace-time side-effect counter
(``Trainer.traces``) audits it: after any number of steps,
``traces == len(buckets)``.

Resume semantics: :class:`TrainState` (params + optimizer state + step +
PRNG key) is the unit of checkpointing. ``fit(resume=True)`` restores the
latest complete checkpoint in ``ckpt_dir`` and continues from its step;
because providers are deterministic in the step index and the PRNG key is
part of the state, the resumed loss trajectory is bit-identical to an
uninterrupted run (tests/test_train.py locks this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.checkpoint import checkpoint as ckpt
from repro.obs import span
from repro.distributed.fault_tolerance import (ResilientLoop,
                                               ResilientLoopConfig)
from repro.optim import adamw, schedule

__all__ = ["TrainState", "TrainerConfig", "FitResult", "Trainer", "fit"]


class TrainState(NamedTuple):
    """Everything a resumed run needs — one checkpointable pytree."""
    params: Any
    opt_state: adamw.AdamWState
    step: jax.Array               # () int32 — the *next* step to run
    rng: jax.Array                # PRNG key; folded with step per step


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    """Loop + optimizer + fault-tolerance knobs (one frozen config)."""
    steps: int = 100
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup_steps: int = 10
    lr_schedule: str = "warmup_cosine"    # see repro.optim.schedule.get
    seed: int = 0
    # checkpointing (None ⇒ no checkpoints, no resume)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    # fault tolerance (threaded into ResilientLoopConfig)
    max_restarts: int = 3
    step_timeout_s: Optional[float] = None
    straggler_factor: float = 3.0
    log_every: int = 0


class FitResult(NamedTuple):
    state: TrainState
    losses: list                  # per-step losses, in step order
    start_step: int               # first step this fit actually ran
    traces: int                   # train-step traces (compiles) so far
    buckets: tuple                # static signatures seen (one exe each)
    events: tuple                 # ResilientLoop event log


class Trainer:
    """``Trainer(task, data, cfg).fit()`` — see the module docstring.

    ``task``: the :class:`~repro.train.task.Task` protocol — ``init(rng)``,
    ``prepare(batch, *, plan, config, tune, mesh)`` →
    ``(arrays, static)``, and ``loss(params, arrays, static, rng, mesh=)``
    → ``(loss, metrics)``. A task may also offer ``build_step(trainer_cfg,
    mesh, static)`` returning a ready ``(state, arrays) -> (state,
    metrics)`` callable (or None to use the generic step) — the hook that
    revives :mod:`repro.distributed.step`'s build-step pattern for tasks
    with their own sharded step (the LM pjit path).

    ``(plan=, config=, tune=)`` follow the library-wide precedence
    (``docs/plans.md``): an explicit ``plan=`` is authoritative for every
    batch (single-shape data), else ``config=`` pins the kernel config the
    per-graph planning selects, else ``tune=`` engages the measured
    autotuner tier, else the generated rules decide. ``mesh=`` (a 1-D
    device mesh) reroutes graph aggregations through
    :mod:`repro.core.dist_mp` — the task partitions each batch and the
    same fused kernels run per shard.
    """

    def __init__(self, task, data, cfg: Optional[TrainerConfig] = None, *,
                 mesh=None, plan=None, config=None, tune=None):
        self.task = task
        self.data = data
        self.cfg = cfg if cfg is not None else TrainerConfig()
        self.mesh = mesh
        self.plan = plan
        self.config = config
        self.tune = tune
        self._execs: dict = {}        # static signature -> jitted step
        # telemetry: per-trainer accounting in the repro.obs registry
        # (vital — `traces` works with observability disabled)
        reg = obs.get_registry()
        self._labels = {"trainer": obs.next_id("trainer")}
        self._m_steps = reg.counter("train.steps", ("trainer",), vital=True)
        self._m_traces = reg.counter("train.traces", ("trainer",),
                                     vital=True)
        self._m_steps.touch(**self._labels)
        self._m_traces.touch(**self._labels)
        self._traced_statics: set = set()   # signatures already compiled

    def _note_trace(self, static) -> None:
        """Trace-time side effect: fires once per compile, never on
        re-invocation — it IS the trace counter ``traces`` reports. Each
        firing leaves an attribution record naming the static signature
        and whether it was a fresh bucket or an unexpected retrace."""
        cause = ("new_bucket" if static not in self._traced_statics
                 else "retrace")
        self._traced_statics.add(static)
        self._m_traces.inc(**self._labels)
        obs.record_compile("train.step", cause,
                           trainer=self._labels["trainer"],
                           static=repr(static))

    # -- state ---------------------------------------------------------------

    def init_state(self) -> TrainState:
        root = jax.random.PRNGKey(self.cfg.seed)
        k_init, k_state = jax.random.split(root)
        params = self.task.init(k_init)
        return TrainState(params, adamw.init(params, self.cfg.opt),
                          jnp.zeros((), jnp.int32), k_state)

    @property
    def traces(self) -> int:
        """Train-step traces so far — the compile counter. After warmup
        this equals ``len(self.buckets)``: one trace per shape bucket."""
        return int(self._m_traces.value(**self._labels))

    @property
    def buckets(self) -> tuple:
        return tuple(self._execs)

    # -- step construction ---------------------------------------------------

    def _build_step(self, static) -> Callable:
        builder = getattr(self.task, "build_step", None)
        if builder is not None:
            custom = builder(self.cfg, self.mesh, static)
            if custom is not None:
                return custom

        task, cfg, mesh = self.task, self.cfg, self.mesh
        lr_scale_fn = schedule.get(cfg.lr_schedule)

        def step(state: TrainState, arrays):
            self._note_trace(static)
            rng = jax.random.fold_in(state.rng, state.step)

            def loss(p):
                return task.loss(p, arrays, static, rng, mesh=mesh)

            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(state.params)
            lr_scale = lr_scale_fn(state.step, cfg.warmup_steps, cfg.steps)
            new_p, new_o, om = adamw.update(grads, state.opt_state,
                                            state.params, cfg.opt, lr_scale)
            return (TrainState(new_p, new_o, state.step + 1, state.rng),
                    dict(metrics, loss=l, **om))

        return jax.jit(step)

    def _executable(self, static) -> Callable:
        exe = self._execs.get(static)
        if exe is None:
            exe = self._execs[static] = self._build_step(static)
        return exe

    # -- the loop ------------------------------------------------------------

    def fit(self, *, resume: bool = False, state: Optional[TrainState] = None,
            metrics_cb: Optional[Callable] = None) -> FitResult:
        """Run the training loop to ``cfg.steps`` total steps.

        ``resume=True`` restores the latest complete checkpoint in
        ``cfg.ckpt_dir`` (no-op when none exists yet) and continues from
        its step. ``state=`` overrides the initial state (mutually
        exclusive with ``resume``)."""
        cfg = self.cfg
        if resume and state is not None:
            raise ValueError("pass either resume=True or state=, not both")
        if resume and not cfg.ckpt_dir:
            raise ValueError("resume=True needs TrainerConfig.ckpt_dir")
        if state is None:
            state = self.init_state()
        start = 0
        if resume:
            latest = ckpt.latest_step(cfg.ckpt_dir)
            if latest is not None:
                state = ckpt.restore(state, cfg.ckpt_dir, step=latest)
                start = latest

        history: dict = {}            # step -> loss (replay overwrites)

        def step_fn(st, step):
            with span("train.step", trainer=self._labels["trainer"],
                      step=int(step)) as root:
                with span("train.sample", step=int(step)):
                    batch = self.data.batch(step)
                with span("train.prepare"):
                    arrays, static = self.task.prepare(
                        batch, plan=self.plan, config=self.config,
                        tune=self.tune, mesh=self.mesh)
                root.set(static=repr(static))
                compiled = static in self._traced_statics
                exe = self._executable(static)
                with span("train.execute" if compiled else "train.compile",
                          static=repr(static)):
                    st, metrics = exe(st, arrays)
                self._m_steps.inc(**self._labels)
                loss = float(metrics["loss"])
                history[step] = loss
                if cfg.log_every and step % cfg.log_every == 0:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"traces {self.traces}", flush=True)
                return st, metrics

        loop = ResilientLoop(
            ResilientLoopConfig(
                cfg.ckpt_dir or "", ckpt_every=cfg.ckpt_every, keep=cfg.keep,
                max_restarts=cfg.max_restarts,
                step_timeout_s=cfg.step_timeout_s,
                straggler_factor=cfg.straggler_factor),
            step_fn, state)
        final = loop.run(cfg.steps, start_step=start, metrics_cb=metrics_cb)
        losses = [history[s] for s in sorted(history)]
        return FitResult(state=final, losses=losses, start_step=start,
                         traces=self.traces, buckets=self.buckets,
                         events=tuple(loop.events))


def fit(task, data, trainer: Optional[TrainerConfig] = None, *,
        plan=None, config=None, tune=None, mesh=None, resume: bool = False,
        state: Optional[TrainState] = None,
        metrics_cb: Optional[Callable] = None) -> FitResult:
    """One-call training: ``repro.train.fit(task, data, trainer_cfg)``.

    The functional face of :class:`Trainer` — builds the trainer and runs
    :meth:`Trainer.fit`. ``(plan=, config=, tune=)`` carry the library's
    uniform precedence (plan > config > tune > heuristics) into every
    per-batch planning decision; ``mesh=`` runs graph aggregations sharded
    over :mod:`repro.core.dist_mp`."""
    t = Trainer(task, data, trainer, mesh=mesh, plan=plan, config=config,
                tune=tune)
    return t.fit(resume=resume, state=state, metrics_cb=metrics_cb)
