"""Training orchestration: ``DatasetProvider → Task → Trainer`` (the
TF-GNN runner protocol shape), unified behind :func:`repro.train.fit`.

    from repro import train

    data = train.GraphEpochProvider(shapes=((96, 384), (128, 512)))
    task = train.NodeClassification.from_provider(data, model="gcn")
    result = train.fit(task, data, train.TrainerConfig(steps=50))

The three legs are independently swappable: providers own deterministic
``batch(step)`` data (replay-exact after checkpoint restore), tasks own
model + loss behind ``init/prepare/loss``, and the trainer owns the
jitted plan-reusing step, AdamW + schedule, checkpoint/resume, and the
fault-tolerant loop. See ``docs/training.md``.
"""
from repro.train.providers import (DatasetProvider, GraphEpochProvider,
                                   SampledNodeProvider, TokenProvider)
from repro.train.task import (GraphStatic, LMStatic, LMTask,
                              NodeClassification, Task)
from repro.train.trainer import (FitResult, Trainer, TrainerConfig,
                                 TrainState, fit)

__all__ = [
    "DatasetProvider",
    "GraphEpochProvider",
    "SampledNodeProvider",
    "TokenProvider",
    "Task",
    "GraphStatic",
    "NodeClassification",
    "LMStatic",
    "LMTask",
    "Trainer",
    "TrainerConfig",
    "TrainState",
    "FitResult",
    "fit",
]
