"""Dataset providers — the ``DatasetProvider`` leg of the orchestration
protocol (``DatasetProvider → Task → Trainer``, the TF-GNN runner shape).

A provider owns *what the model trains on*; the contract is a single
method

    provider.batch(step: int) -> batch

that is **deterministic in the step index**: the same step always yields
the same batch, with no iterator state to carry through checkpoints. That
is the property the fault-tolerant loop
(:class:`repro.distributed.fault_tolerance.ResilientLoop`) relies on —
after a failure it restores the latest complete checkpoint and *replays*
the intervening steps, and replay is exact only when data is a pure
function of the step.

Graph providers additionally keep their epoch of graphs **as persistent
objects**, so the per-graph plan memo (:meth:`repro.data.graphs.Graph.
make_plan`) survives across steps: the chunk metadata and kernel-config
selection for a shape are paid once, and every later step (and every
jitted train-step re-invocation on that shape bucket) reuses them —
steps never re-plan.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

from repro.data.graphs import batch_graphs, synth_graph, synth_typed_graph
from repro.data.tokens import SyntheticTokens, TokenDatasetConfig


@runtime_checkable
class DatasetProvider(Protocol):
    """Anything with a deterministic ``batch(step)`` is a provider."""

    def batch(self, step: int) -> Any:                 # pragma: no cover
        ...


class GraphEpochProvider:
    """Synthetic graph epochs for node-classification training.

    Builds a fixed pool of power-law graphs at a few distinct ``(|V|, |E|)``
    shapes (``shapes``), optionally block-diagonally batched
    ``graphs_per_batch`` at a time (:func:`repro.data.graphs.batch_graphs`
    — one plan covers the whole batch), and cycles through the epoch
    deterministically: ``batch(step) = epoch[step % len(epoch)]``.

    Because the epoch members are constructed **once**, their plan memos
    persist: the trainer sees exactly ``len(shapes)`` distinct shape
    buckets, compiles one train step per bucket, and re-plans nothing.

    ``typed=True`` yields :class:`~repro.data.graphs.TypedGraph` members
    (zipf-skewed relation ids) for the relational families (RGCN/RGAT);
    typed graphs are not block-diagonally batched (``graphs_per_batch``
    must stay 1 — batching would drop the edge types).
    """

    def __init__(self, shapes=((96, 384), (128, 512)),
                 graphs_per_shape: int = 2, graphs_per_batch: int = 1,
                 feat: int = 32, num_classes: int = 16, typed: bool = False,
                 num_relations: int = 4, alpha: float = 1.3, seed: int = 0,
                 name: str = "train"):
        if typed and graphs_per_batch != 1:
            raise ValueError("typed graphs cannot be block-diagonally "
                             "batched (edge types would be dropped); use "
                             "graphs_per_batch=1")
        if graphs_per_shape % graphs_per_batch:
            raise ValueError("graphs_per_shape must be a multiple of "
                             "graphs_per_batch")
        self.feat = feat
        self.num_classes = num_classes
        self.num_relations = num_relations if typed else 0
        self.typed = typed
        epoch = []
        for si, (v, e) in enumerate(shapes):
            members = []
            for j in range(graphs_per_shape):
                s = seed * 9973 + si * 97 + j
                if typed:
                    members.append(synth_typed_graph(
                        f"{name}-{v}x{e}-{j}", v, e,
                        num_relations=num_relations, feat=feat,
                        num_classes=num_classes, alpha=alpha, seed=s))
                else:
                    members.append(synth_graph(
                        f"{name}-{v}x{e}-{j}", v, e, feat=feat,
                        num_classes=num_classes, alpha=alpha, seed=s))
            for k in range(0, len(members), graphs_per_batch):
                chunk = members[k:k + graphs_per_batch]
                epoch.append(chunk[0] if len(chunk) == 1
                             else batch_graphs(chunk))
        self._epoch = epoch

    def __len__(self) -> int:
        """Steps per epoch (distinct batches before the cycle repeats)."""
        return len(self._epoch)

    def batch(self, step: int):
        return self._epoch[step % len(self._epoch)]


class SampledNodeProvider:
    """Out-of-core node-classification batches: a
    :class:`~repro.data.sampling.NeighborSampler` behind the provider
    protocol, with the async prefetch pipeline
    (:class:`~repro.data.pipeline.PrefetchPipeline`) doing the host work
    off the critical path.

    ``batch(step)`` returns a device-ready
    :class:`~repro.data.pipeline.SampledBatch` —
    :class:`~repro.train.task.NodeClassification` recognizes it and trains
    on the seed rows only (``label_mask``). Determinism in the step index
    is inherited from the sampler (a batch is a pure function of
    ``(seed, step)``; prefetch threads change timing, never content), so
    checkpoint replay stays exact.

    ``num_classes`` defaults from the store's metadata; ``feat`` is the
    *input* feature width. Pass ``plan_feat`` (the model's widest layer —
    ``NodeClassification.plan_feat``) so producer-side config selection
    matches the task's. Call :meth:`close` (or use as a context manager)
    when done — the pipeline owns live threads."""

    def __init__(self, store_or_graph, *, fanouts=(8, 4), batch_size=64,
                 seed_nodes=None, exact=False, seed=0, plan_feat=128,
                 policy=None, cache=None, depth=2, num_threads=None,
                 device=None):
        from repro.data.pipeline import (PrefetchPipeline,
                                         SampledBatchProducer)
        from repro.data.sampling import InMemoryStore, NeighborSampler
        from repro.data.graphs import Graph
        if isinstance(store_or_graph, Graph):
            store_or_graph = InMemoryStore(store_or_graph)
        self.store = store_or_graph
        self.sampler = NeighborSampler(
            store_or_graph, fanouts, batch_size=batch_size,
            seed_nodes=seed_nodes, exact=exact, seed=seed)
        self.producer = SampledBatchProducer(
            self.sampler, feat=plan_feat, policy=policy, cache=cache,
            device=device)
        self.pipeline = PrefetchPipeline(self.producer, depth=depth,
                                         num_threads=num_threads)
        self.feat = int(self.store.feat)
        self.num_classes = int(self.store.num_classes)
        self.num_relations = 0
        self.typed = False

    def __len__(self) -> int:
        return len(self.sampler)

    def batch(self, step: int):
        return self.pipeline.batch(step)

    def stats(self) -> dict:
        d = self.pipeline.stats()
        d["cache"] = self.producer.cache.stats.as_dict()
        return d

    def close(self) -> None:
        self.pipeline.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class TokenProvider:
    """LM token batches — a provider-protocol wrapper over the
    deterministic :class:`repro.data.tokens.SyntheticTokens` pipeline
    (fixed Markov language; each batch is a pure function of
    ``(seed, step, host)``, so checkpoint replay is exact)."""

    def __init__(self, cfg: TokenDatasetConfig, host_id: int = 0,
                 num_hosts: int = 1):
        self.cfg = cfg
        self._ds = SyntheticTokens(cfg, host_id=host_id, num_hosts=num_hosts)

    def batch(self, step: int):
        return self._ds.batch(step)
