"""Fully-fused transform-aggregate Pallas kernel — SpMM+GEMM in one launch.

    Y[s, :] = ( reduce_{i: seg[i]==s} wt[i] · H[gidx[i], :] ) @ W

The step beyond ``mp_transform``'s reorder-only fusion: the per-layer dense
transform runs *inside* the gather-reduce launch, so neither the transformed
(|E|, d) edge tensor (transform-first) nor the aggregated (S, d_in) node
tensor (aggregate-first) ever exists in HBM. Linear reduces only
(sum / mean) — the transform distributes over the reduction, which is what
makes aggregating at width d_in and transforming per output block
mathematically identical to transform-then-aggregate.

Schedule (grid = (out_blocks, max_chunks), **no feature tiling**):

  * each chunk's H rows are DMA-gathered at full d_in width into VMEM
    staging — one copy per row instead of the ``n_tiles`` copies the
    width-tiled gather kernel issues, because the in-kernel GEMM needs the
    whole contraction dim resident anyway;
  * the PR one-hot matmul accumulates the chunk into an (S_b, d_in) fp32
    VMEM accumulator (same masking convention as ``gather_segment_reduce``);
  * at the block's last owned chunk the accumulator (mean-normalized if
    requested) hits the MXU against the VMEM-resident (d_in, d_out) weight
    tile and the (S_b, d_out) result is written out in the io dtype.

VMEM feasibility: W + accumulator + staging must fit (checked by
:func:`fusable`); past that bound callers fall back to the two-launch
``mp_transform`` path — ``core.mp.resolve_order`` consults the same
predicate.

Precision: io dtype in (H, W, wt, Y out), fp32 accumulate — the segment
accumulator is always fp32 and both matmuls run with
``preferred_element_type=float32``; for bf16 io the accumulator is cast to
bf16 once, immediately before the transform matmul (the MXU's native
operand width).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.config_space import VMEM_BYTES, KernelConfig, io_dtype_bytes
from repro.kernels.gather_segment_reduce import _gather_chunk
from repro.kernels.segment_reduce import _resolve_plan, _round_up, chunk_metadata


def fusable(d_in: int, d_out: int, dtype, config: KernelConfig,
            budget: int = VMEM_BYTES) -> bool:
    """Does one launch's VMEM working set fit? (W tile + fp32 accumulator +
    staging chunk + out block, double-buffer headroom on the staged chunk.)"""
    b = io_dtype_bytes(dtype)
    d_in_pad = _round_up(max(d_in, 1), 128)
    d_out_pad = _round_up(max(d_out, 1), 128)
    w_tile = d_in_pad * d_out_pad * b
    acc = config.s_b * d_in_pad * 4
    stage = 2 * config.m_b * d_in_pad * b
    out = config.s_b * d_out_pad * b
    return w_tile + acc + stage + out <= budget


def _body(cf_ref, cc_ref, gidx_ref, idx_ref, wt_ref, h_ref, wm_ref, o_ref,
          xbuf_ref, acc_ref, sem, *scratch, s_b: int, has_weight: bool,
          reduce: str):
    b, k = pl.program_id(0), pl.program_id(1)
    cnt_ref = scratch[0] if reduce == "mean" else None

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if reduce == "mean":
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(k < cc_ref[b])
    def _accumulate():
        _gather_chunk(gidx_ref, h_ref, xbuf_ref, sem, 0, xbuf_ref.shape[1])
        xg = xbuf_ref[...]
        if has_weight:
            xg = xg * wt_ref[0, :][:, None].astype(xg.dtype)
        seg = idx_ref[0, :]
        m_b = seg.shape[0]
        rel = seg - b * s_b
        cols = jax.lax.broadcasted_iota(jnp.int32, (m_b, s_b), 1)
        onehot = (rel[:, None] == cols).astype(xg.dtype)
        acc_ref[...] += jax.lax.dot_general(
            onehot, xg, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(acc_ref.dtype)
        if reduce == "mean":
            # one-hot column sums == per-segment row counts (padding rows
            # carry seg == num_segments and only ever land in the guard
            # rows the caller slices away — same convention as the gather
            # kernel's fused mean)
            cnt_ref[...] += jnp.sum(onehot.astype(jnp.float32),
                                    axis=0)[:, None]

    # in-kernel GEMM once per output block, after its last owned chunk
    # (blocks owning no chunks fire at k == 0 with a zero accumulator)
    @pl.when(k == jnp.maximum(cc_ref[b], 1) - 1)
    def _transform():
        agg = acc_ref[...]
        if reduce == "mean":
            agg = agg / jnp.maximum(cnt_ref[...], 1.0)
        o_ref[...] = jax.lax.dot_general(
            agg.astype(wm_ref.dtype), wm_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "config", "max_chunks", "interpret",
                     "has_weight", "reduce"),
)
def _fused_transform_reduce_impl(h, wm, gather_idx, seg_idx, weight,
                                 num_segments: int, config: KernelConfig,
                                 max_chunks: Optional[int], interpret: bool,
                                 has_weight: bool, reduce: str, plan=None):
    m = gather_idx.shape[0]
    v, d_in = h.shape
    d_out = wm.shape[1]
    s_b, m_b = config.s_b, config.m_b
    d_in_pad = _round_up(max(d_in, 1), 128)
    d_out_pad = _round_up(max(d_out, 1), 128)
    m_pad = _round_up(max(m, 1), m_b)
    s_pad = _round_up(num_segments, s_b)

    hp = jnp.pad(h, ((0, 1), (0, d_in_pad - d_in)))  # +1 guard row
    wmp = jnp.pad(wm, ((0, d_in_pad - d_in), (0, d_out_pad - d_out)))
    gidxp = jnp.pad(gather_idx.astype(jnp.int32), (0, m_pad - m),
                    constant_values=v)               # padding gathers guard row
    idxp = jnp.pad(seg_idx.astype(jnp.int32), (0, m_pad - m),
                   constant_values=num_segments)
    wtp = jnp.pad(weight, (0, m_pad - m))            # io dtype, like the gather
    gidx2d = gidxp.reshape(m_pad // m_b, m_b)
    idx2d = idxp.reshape(m_pad // m_b, m_b)
    wt2d = wtp.reshape(m_pad // m_b, m_b)

    if plan is not None:
        chunk_first, chunk_count = plan.chunk_first, plan.chunk_count
    else:
        chunk_first, chunk_count = chunk_metadata(idxp, num_segments, s_b,
                                                  m_b, m_pad)
    out_blocks = s_pad // s_b
    if max_chunks is None:
        max_chunks = m_pad // m_b

    def row_map(b, k, cf, cc):
        return (cf[b] + jnp.minimum(k, jnp.maximum(cc[b] - 1, 0)), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(out_blocks, max_chunks),
        in_specs=[
            pl.BlockSpec((1, m_b), row_map),                   # gather_idx
            pl.BlockSpec((1, m_b), row_map),                   # seg_idx
            pl.BlockSpec((1, m_b), row_map),                   # edge weight
            pl.BlockSpec(memory_space=pltpu.ANY),              # H (unblocked)
            pl.BlockSpec((d_in_pad, d_out_pad), lambda b, k, cf, cc: (0, 0)),
        ],
        out_specs=pl.BlockSpec((s_b, d_out_pad), lambda b, k, cf, cc: (b, 0)),
        scratch_shapes=(
            [pltpu.VMEM((m_b, d_in_pad), h.dtype),             # staged rows
             pltpu.VMEM((s_b, d_in_pad), jnp.float32),         # fp32 segment acc
             pltpu.SemaphoreType.DMA]
            + ([pltpu.VMEM((s_b, 1), jnp.float32)]             # mean counts
               if reduce == "mean" else [])),
    )
    out = pl.pallas_call(
        functools.partial(_body, s_b=s_b, has_weight=has_weight,
                          reduce=reduce),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_pad, d_out_pad), h.dtype),
        interpret=interpret,
    )(chunk_first, chunk_count, gidx2d, idx2d, wt2d, hp, wmp)
    return out[:num_segments, :d_out]


def fused_transform_reduce_pallas(h, w, gather_idx, seg_idx,
                                  num_segments: int, weight=None,
                                  reduce: str = "sum",
                                  config: Optional[KernelConfig] = None,
                                  max_chunks: Optional[int] = None,
                                  interpret: bool = False, plan=None):
    """One-launch Y = Agg(H)[gather/seg] @ W for reduce ∈ {sum, mean}
    (weighted or not). ``seg_idx`` must be sorted non-decreasing; ``plan``
    is the same :class:`~repro.core.plan.SegmentPlan` the gather-reduce
    kernels consume (identical chunk metadata)."""
    if reduce not in ("sum", "mean"):
        raise ValueError(f"fused transform-reduce is linear-only: "
                         f"reduce must be sum or mean, got {reduce!r}")
    config, max_chunks = _resolve_plan(plan, int(gather_idx.shape[0]),
                                       num_segments, config, max_chunks)
    if config is None:
        from repro.core.config_space import canonical_io_dtype
        from repro.core.heuristics import select_config
        config = select_config(int(gather_idx.shape[0]), num_segments,
                               int(h.shape[1]), op="fused_transform_reduce",
                               io_dtype=canonical_io_dtype(h.dtype))
    if not fusable(int(h.shape[1]), int(w.shape[1]), h.dtype, config):
        raise ValueError(
            f"(d_in={h.shape[1]}, d_out={w.shape[1]}) exceeds the fused "
            f"kernel's VMEM budget for config {config}; use the two-launch "
            f"mp_transform path (core.mp.resolve_order gates on "
            f"kernels.fused_transform_reduce.fusable)")
    has_weight = weight is not None
    if weight is None:
        weight = jnp.ones((gather_idx.shape[0],), h.dtype)
    return _fused_transform_reduce_impl(h, w, gather_idx, seg_idx, weight,
                                        num_segments, config, max_chunks,
                                        interpret, has_weight, reduce, plan)
