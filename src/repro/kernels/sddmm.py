"""SDDMM Pallas kernel — sampled dense-dense matmul (paper §VI).

    out[i] = < A[row_idx[i], :] , B[col_idx[i], :] >      i ∈ [0, M)

SDDMM is the backward of the fused SpMM (`index_weight_segment_reduce`'s
dW) — the op the paper names as the missing piece for training support.
TPU mapping: grid over edge chunks; both operand rows are DMA-gathered into
VMEM staging buffers (same per-row async-copy machinery as
gather_segment_reduce), then the per-edge dot is an elementwise multiply +
lane reduction on the VPU. No sortedness required (pure gather, no scatter).

Precision contract: **fp32-accumulate / input-dtype-out.** The per-edge dot
multiplies in fp32 and the feature-tile partials accumulate across the
sequential ``j`` grid dim in an fp32 output buffer (a real running sum —
unlike the grouped matmul's masked-disjoint accumulation it cannot be
narrowed); the (M,) result is cast to ``a.dtype`` on the way out, so bf16
operands get bf16 edge scores without ever accumulating in bf16.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_reduce import _round_up


def _body(ridx_ref, cidx_ref, a_ref, b_ref, o_ref, abuf_ref, bbuf_ref, sem,
          *, n_tiles: int):
    m_b = ridx_ref.shape[1]
    j = pl.program_id(1)

    def copy_row(i, _):
        r = ridx_ref[0, i]
        c = cidx_ref[0, i]
        n_b = abuf_ref.shape[1]
        cp = pltpu.make_async_copy(
            a_ref.at[pl.ds(r, 1), pl.ds(j * n_b, n_b)],
            abuf_ref.at[pl.ds(i, 1), :], sem)
        cp.start()
        cp.wait()
        cp = pltpu.make_async_copy(
            b_ref.at[pl.ds(c, 1), pl.ds(j * n_b, n_b)],
            bbuf_ref.at[pl.ds(i, 1), :], sem)
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, m_b, copy_row, 0, unroll=False)
    partial = jnp.sum(
        abuf_ref[...].astype(jnp.float32) * bbuf_ref[...].astype(jnp.float32),
        axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, :] += partial      # accumulate feature tiles (j sequential)


@functools.partial(jax.jit, static_argnames=("m_b", "n_b", "interpret"))
def sddmm_pallas(a, b, row_idx, col_idx, m_b: int = 256, n_b: int = 512,
                 interpret: bool = False):
    """a: (Ra, N); b: (Rb, N); row/col_idx: (M,) int32 → (M,) a.dtype
    (fp32-accumulated — see module docstring)."""
    m = row_idx.shape[0]
    n = a.shape[1]
    n_b = min(n_b, _round_up(max(n, 1), 128))
    m_pad = _round_up(max(m, 1), m_b)
    n_pad = _round_up(max(n, 1), n_b)

    ap = jnp.pad(a, ((0, 1), (0, n_pad - n)))     # +1 guard row
    bp = jnp.pad(b, ((0, 1), (0, n_pad - n)))
    ridx = jnp.pad(row_idx.astype(jnp.int32), (0, m_pad - m),
                   constant_values=a.shape[0]).reshape(m_pad // m_b, m_b)
    cidx = jnp.pad(col_idx.astype(jnp.int32), (0, m_pad - m),
                   constant_values=b.shape[0]).reshape(m_pad // m_b, m_b)
    n_tiles = n_pad // n_b

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(m_pad // m_b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, m_b), lambda i, j: (i, 0)),
            pl.BlockSpec((1, m_b), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, m_b), lambda i, j: (i, 0)),
        scratch_shapes=[pltpu.VMEM((m_b, n_b), a.dtype),
                        pltpu.VMEM((m_b, n_b), b.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    out = pl.pallas_call(
        functools.partial(_body, n_tiles=n_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_pad // m_b, m_b), jnp.float32),
        interpret=interpret,
    )(ridx, cidx, ap, bp)
    return out.reshape(m_pad)[:m].astype(a.dtype)
