"""Pallas TPU segment-reduction kernel (paper §III adapted to TPU).

Schedules (DESIGN.md §2):
  PR — "parallel reduction": per chunk, a one-hot matrix P (M_b × S_b) is
       built on the VPU and `out += Pᵀ @ X` runs on the MXU. The systolic
       array performs the cross-row reduction that warp shuffles perform on
       GPU; rows whose segment falls outside the output window produce
       all-zero P rows (the analogue of shuffle invalidation).
  SR — "sequential reduction": a scalar walk down the chunk with a (1, N_b)
       vector accumulator, flushing to the output block row at each segment
       boundary (dynamic-slice store). Sequential in M, vectorized in N.

Grid & tiling:
  grid = (out_blocks, n_tiles, max_chunks)   — chunk dim innermost.
  Each output block b owns segment ids [b·S_b, (b+1)·S_b). Because Idx is
  sorted, the input rows feeding block b form a contiguous range; the
  scalar-prefetched metadata (chunk_first, chunk_count) maps b to its chunk
  range. Chunks shared with a neighbouring block are re-read by both; the
  one-hot / window test masks out the foreign rows, so no atomics are needed
  (TPU grid steps are sequential — the structural replacement for
  atomicAdd, see DESIGN.md §2).

No shared-memory-style staging between "thread groups" is used, matching the
paper's design decision (§III-A).

Note on K_c (the G_t analogue): it parameterises the MXU contraction depth
per one-hot sub-matmul in the *cost model* (pipeline-fill efficiency,
repro.core.costmodel). Mosaic schedules the systolic pipeline internally, so
the kernel body issues the full-chunk dot and K_c is a model-level knob; on
GPU G_t is a launch parameter, on TPU its twin lives in the scheduler.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.config_space import KernelConfig


# ---------------------------------------------------------------------------
# metadata (jit-safe; only `max_chunks` must be static)
# ---------------------------------------------------------------------------

def chunk_metadata(idx, num_segments: int, s_b: int, m_b: int, m_pad: int):
    """Per-output-block chunk range over the padded row space.

    Returns (chunk_first, chunk_count) of shape (out_blocks,): block b reads
    input-row blocks [chunk_first[b], chunk_first[b] + chunk_count[b])."""
    out_blocks = (num_segments + s_b - 1) // s_b
    bounds = jnp.arange(out_blocks + 1, dtype=jnp.int32) * s_b
    # row range [lo_b, hi_b) of segment ids < bound — sorted Idx ⇒ searchsorted
    row_bound = jnp.searchsorted(idx, bounds, side="left").astype(jnp.int32)
    lo, hi = row_bound[:-1], row_bound[1:]
    chunk_first = lo // m_b
    last = jnp.maximum(hi - 1, lo) // m_b
    chunk_count = jnp.where(hi > lo, last - chunk_first + 1, 0).astype(jnp.int32)
    return chunk_first, chunk_count


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _pr_body(cf_ref, cc_ref, idx_ref, x_ref, o_ref, *, s_b: int, acc_dtype):
    b, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k < cc_ref[b])
    def _compute():
        seg = idx_ref[0, :]                          # (m_b,) int32
        rel = seg - b * s_b
        m_b = seg.shape[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, (m_b, s_b), 1)
        onehot = (rel[:, None] == cols).astype(x_ref.dtype)
        o_ref[...] += jax.lax.dot_general(
            onehot, x_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),   # contract rows
            preferred_element_type=acc_dtype,
        ).astype(o_ref.dtype)


def _sr_body(cf_ref, cc_ref, idx_ref, x_ref, o_ref, acc_ref, st_ref,
             *, s_b: int, reduce: str):
    b, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    # max identity is -inf, matching jax.ops.segment_max on empty segments
    init_val = -jnp.inf if reduce == "max" else 0.0

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init_val)
        st_ref[0] = -1                                # open-segment rel (-1 ⇒ closed)

    @pl.when(k < cc_ref[b])
    def _compute():
        seg = idx_ref[0, :]
        m_b = seg.shape[0]

        def flush():
            p = st_ref[0]
            row = o_ref[pl.ds(p, 1), :]
            if reduce == "max":
                o_ref[pl.ds(p, 1), :] = jnp.maximum(row, acc_ref[...])
            else:
                o_ref[pl.ds(p, 1), :] = row + acc_ref[...]

        def walk(i, _):
            r = seg[i] - b * s_b
            in_win = jnp.logical_and(r >= 0, r < s_b)
            opened = st_ref[0] >= 0

            # segment boundary (or leaving the window) ⇒ flush accumulator
            @pl.when(jnp.logical_and(opened, jnp.logical_or(~in_win, r != st_ref[0])))
            def _():
                flush()
                st_ref[0] = -1

            xrow = x_ref[pl.ds(i, 1), :].astype(acc_ref.dtype)

            @pl.when(jnp.logical_and(in_win, st_ref[0] == r))
            def _():  # continue open segment
                if reduce == "max":
                    acc_ref[...] = jnp.maximum(acc_ref[...], xrow)
                else:
                    acc_ref[...] += xrow

            @pl.when(jnp.logical_and(in_win, st_ref[0] != r))
            def _():  # open a new segment
                acc_ref[...] = xrow
                st_ref[0] = r

            return 0

        jax.lax.fori_loop(0, m_b, walk, 0, unroll=False)

        # end of this block's chunk range ⇒ flush the trailing open segment
        @pl.when(jnp.logical_and(k == cc_ref[b] - 1, st_ref[0] >= 0))
        def _():
            flush()
            st_ref[0] = -1


# ---------------------------------------------------------------------------
# pallas_call wrapper
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _resolve_plan(plan, num_rows: int, num_segments: int,
                  config: Optional[KernelConfig],
                  max_chunks: Optional[int]):
    """Merge an optional SegmentPlan into (config, max_chunks).

    The plan's config wins when none is given explicitly; an explicit config
    must agree on the tiling the metadata was built for (s_b, m_b)."""
    if plan is None:
        return config, max_chunks
    plan.validate(num_rows, num_segments)
    if config is None:
        config = plan.config
    elif (config.s_b, config.m_b) != (plan.config.s_b, plan.config.m_b):
        raise ValueError(
            f"explicit config (s_b={config.s_b}, m_b={config.m_b}) conflicts "
            f"with plan tiling (s_b={plan.config.s_b}, m_b={plan.config.m_b})")
    if max_chunks is None:
        max_chunks = plan.max_chunks
    return config, max_chunks


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "reduce", "config", "max_chunks",
                     "interpret"),
)
def segment_reduce_pallas(x, idx, num_segments: int, reduce: str = "sum",
                          config: Optional[KernelConfig] = None,
                          max_chunks: Optional[int] = None,
                          interpret: bool = False, plan=None):
    """Blocked segment reduction via pl.pallas_call.

    x: (M, N); idx: (M,) sorted int32; returns (num_segments, N) in x.dtype.
    ``max_chunks``: static bound on chunks per output block (worst case:
    all rows in one block). Tighten it for skewed inputs when known.
    ``plan``: a precomputed :class:`repro.core.plan.SegmentPlan` — supplies
    config, a tight ``max_chunks``, and the chunk metadata, skipping their
    per-call recomputation.
    """
    config, max_chunks = _resolve_plan(plan, int(x.shape[0]), num_segments,
                                       config, max_chunks)
    if config is None:
        from repro.core.heuristics import select_config
        config = select_config(int(x.shape[0]), num_segments, int(x.shape[1]))
    if reduce == "max" and config.schedule == "PR":
        config = KernelConfig("SR", config.s_b, config.n_b, config.m_b, 1)
    if reduce == "mean":
        s = segment_reduce_pallas(x, idx, num_segments, "sum", config,
                                  max_chunks, interpret, plan)
        cnt = jax.ops.segment_sum(jnp.ones((x.shape[0],), jnp.float32), idx,
                                  num_segments, indices_are_sorted=True)
        return (s.astype(jnp.float32)
                / jnp.maximum(cnt, 1.0)[:, None]).astype(x.dtype)

    m, n = x.shape
    s_b, n_b, m_b = config.s_b, config.n_b, config.m_b
    n_b = min(n_b, _round_up(max(n, 1), 128))
    m_pad = _round_up(max(m, 1), m_b)
    n_pad = _round_up(max(n, 1), n_b)
    s_pad = _round_up(num_segments, s_b)

    xp = jnp.pad(x, ((0, m_pad - m), (0, n_pad - n)))
    # padding rows get segment id = num_segments ⇒ outside every window
    idxp = jnp.pad(idx.astype(jnp.int32), (0, m_pad - m),
                   constant_values=num_segments)
    idx2d = idxp.reshape(m_pad // m_b, m_b)

    if plan is not None:
        chunk_first, chunk_count = plan.chunk_first, plan.chunk_count
    else:
        chunk_first, chunk_count = chunk_metadata(idxp, num_segments, s_b,
                                                  m_b, m_pad)
    out_blocks = s_pad // s_b
    n_tiles = n_pad // n_b
    if max_chunks is None:
        max_chunks = m_pad // m_b          # worst case: one block owns all rows

    acc_dtype = jnp.float32

    def x_map(b, j, k, cf, cc):
        return (cf[b] + jnp.minimum(k, jnp.maximum(cc[b] - 1, 0)), j)

    def idx_map(b, j, k, cf, cc):
        return (cf[b] + jnp.minimum(k, jnp.maximum(cc[b] - 1, 0)), 0)

    def o_map(b, j, k, cf, cc):
        return (b, j)

    common = dict(
        grid=(out_blocks, n_tiles, max_chunks),
        in_specs=[
            pl.BlockSpec((1, m_b), idx_map),
            pl.BlockSpec((m_b, n_b), x_map),
        ],
        out_specs=pl.BlockSpec((s_b, n_b), o_map),
    )

    if config.schedule == "PR":
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, **common)
        body = functools.partial(_pr_body, s_b=s_b, acc_dtype=acc_dtype)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, **common,
            scratch_shapes=[pltpu.VMEM((1, n_b), acc_dtype),
                            pltpu.SMEM((1,), jnp.int32)])
        body = functools.partial(_sr_body, s_b=s_b, reduce=reduce)

    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_pad, n_pad), acc_dtype),
        interpret=interpret,
    )(chunk_first, chunk_count, idx2d, xp)

    out = out[:num_segments, :n]
    if reduce == "max":
        # empty segments: match jax.ops.segment_max identity (-inf)
        return out.astype(x.dtype)
    return out.astype(x.dtype)
