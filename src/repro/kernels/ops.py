"""Jit'd public wrappers around the Pallas kernels.

On this CPU-only container the kernels execute with ``interpret=True``
(Pallas interpreter); on TPU hardware set ``REPRO_PALLAS_INTERPRET=0`` (or
pass ``interpret=False``) to compile via Mosaic.

Every wrapper accepts the same ``(plan=, config=, tune=)`` trio with one
precedence (paper §III-C + the measured tier of :mod:`repro.core.autotune`;
documented once in ``docs/plans.md``):

    ``plan``  >  explicit ``config=``  >  measured PerfDB entry
    (``tune=True`` / ``REPRO_AUTOTUNE=1``)  >  generated decision-tree
    rules  >  hand-crafted

A plan's schedule metadata is authoritative: an explicit config may refine
non-tiling dimensions but must agree with the plan's tiling (conflicts
raise); ``tune`` is only consulted when neither a plan nor a config pins
the choice. Resolution happens *here*, outside the jitted pallas_call
wrappers, so a wall-clock tuning sweep never runs at trace time.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import os
import threading
from typing import Optional

import jax

from repro.core.config_space import KernelConfig
from repro.kernels.gather_segment_reduce import gather_segment_reduce_pallas
from repro.kernels.segment_matmul import segment_matmul_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# fusion accounting — trace-time counters keyed "<kind>:<op>":
#   fused:    a fused Pallas kernel launch
#   unfused:  a jnp segment-op fallback replacing a fused aggregation
#   merge:    cross-shard halo algebra (e.g. the sharded softmax's (m, z)
#             statistics) — auxiliary segment ops that are part of the
#             collective merge, not a fallback of the aggregation itself
# Because the wrappers run at trace time, a jitted graph records each op
# site once; reset before tracing and read after to audit a path (e.g.
# assert the sharded message-passing path launches only fused kernels).
#
# Concurrency: the store is lock-guarded and scopes are contextvar-scoped
# per thread/context — a PrefetchPipeline producer thread tracing in the
# background can never leak its events into a consumer's fusion_scope()
# (each thread folds into its own innermost scope; threads without a
# scope fold into the process-global counter). Every event is also
# mirrored into the repro.obs metrics registry ("kernel.launches") so
# launch counts and fused-vs-unfused ratios land in the same telemetry
# dump as everything else.
# ---------------------------------------------------------------------------

_FUSION_LOCK = threading.Lock()
_FUSION_GLOBAL: collections.Counter = collections.Counter()
_FUSION_SCOPES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_fusion_scopes", default=())


def _fusion_sink() -> collections.Counter:
    scopes = _FUSION_SCOPES.get()
    return scopes[-1] if scopes else _FUSION_GLOBAL


_LAUNCH_METRIC = None


def _launch_metric():
    global _LAUNCH_METRIC
    if _LAUNCH_METRIC is None:
        from repro.obs import get_registry
        _LAUNCH_METRIC = get_registry().counter(
            "kernel.launches", labels=("kind", "op"),
            help="trace-time kernel launch accounting "
                 "(fused/unfused/merge)")
    return _LAUNCH_METRIC


def account(kind: str, op: str) -> None:
    """Record one ``kind`` ∈ {"fused", "unfused", "merge"} event on ``op``."""
    with _FUSION_LOCK:
        _fusion_sink()[f"{kind}:{op}"] += 1
    _launch_metric().inc(kind=kind, op=op)


def fusion_counts() -> dict:
    """Snapshot of the accounting counters (trace-time launch counts) —
    the innermost :func:`fusion_scope` of the calling thread, else the
    process-global store."""
    with _FUSION_LOCK:
        return dict(_fusion_sink())


def reset_fusion_counts() -> None:
    with _FUSION_LOCK:
        _fusion_sink().clear()


@contextlib.contextmanager
def fusion_scope():
    """Scoped fusion accounting: inside the block the counters start at
    zero and only record events of the block; on exit the scope's events
    are folded back into the enclosing counters, so global accounting
    still accumulates. Yields the scope's live Counter — read it at the
    end of the block (or via :func:`fusion_counts` inside it).

    This is what per-request accounting needs (e.g. the serving engine's
    per-request fusion audit): without a scope, every request's trace
    events pile onto one process-wide counter and no per-request
    attribution is possible. Scopes nest, and they are **contextvar-
    scoped**: a scope only captures events of its own thread/context, so
    concurrent producer threads (repro.data.pipeline) keep folding into
    the global store instead of interleaving into an unrelated scope."""
    inner = collections.Counter()
    outer_scopes = _FUSION_SCOPES.get()
    token = _FUSION_SCOPES.set(outer_scopes + (inner,))
    try:
        yield inner
    finally:
        _FUSION_SCOPES.reset(token)
        with _FUSION_LOCK:
            (outer_scopes[-1] if outer_scopes else _FUSION_GLOBAL
             ).update(inner)


def _resolve_config(config: Optional[KernelConfig], plan, idx_size: int,
                    num_segments: int, feat: int, op: str,
                    tune: Optional[bool] = None,
                    io_dtype=None) -> Optional[KernelConfig]:
    """Apply the selection precedence ahead of the jit boundary
    (plan > config > tune > heuristics).

    Returns None only when a plan carries the config (the kernel merges it
    with the plan's chunk metadata via ``_resolve_plan``). ``io_dtype``
    (a dtype or name) routes the measured tier to the right PerfDB
    precision shelf."""
    if config is not None or plan is not None:
        return config
    from repro.core.config_space import canonical_io_dtype
    from repro.core.heuristics import select_config
    return select_config(int(idx_size), int(num_segments), int(feat), op=op,
                         tune=tune,
                         io_dtype=canonical_io_dtype(io_dtype or "float32"))


def segment_reduce(x, idx, num_segments: int, reduce: str = "sum",
                   config: Optional[KernelConfig] = None,
                   max_chunks: Optional[int] = None,
                   interpret: Optional[bool] = None, plan=None,
                   tune: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    config = _resolve_config(config, plan, x.shape[0], num_segments,
                             x.shape[-1], "segment_reduce", tune,
                             io_dtype=x.dtype)
    account("fused", f"segment_reduce_{reduce}")
    if reduce == "mean":
        # the non-gather mean pairs a fused sum launch with a jnp count
        account("unfused", "segment_reduce_mean_count")
    return segment_reduce_pallas(x, idx, num_segments, reduce=reduce,
                                 config=config, max_chunks=max_chunks,
                                 interpret=interpret, plan=plan)


def gather_segment_reduce(h, gather_idx, seg_idx, num_segments: int,
                          weight=None, reduce: str = "sum",
                          config: Optional[KernelConfig] = None,
                          max_chunks: Optional[int] = None,
                          interpret: Optional[bool] = None, plan=None,
                          tune: Optional[bool] = None):
    """Fused gather + segment reduction, one launch per reduce ∈
    {sum, mean, max} (weighted or not) — the mean's count and the max's
    running maximum live inside the kernel, never as a second launch."""
    if reduce not in ("sum", "mean", "max"):
        raise ValueError(f"unknown reduce: {reduce!r} "
                         "(fused gather supports sum/mean/max)")
    interpret = _default_interpret() if interpret is None else interpret
    op = ("gather_segment_reduce" if reduce == "sum"
          else f"gather_segment_reduce_{reduce}")
    config = _resolve_config(config, plan, gather_idx.shape[0], num_segments,
                             h.shape[-1], op, tune, io_dtype=h.dtype)
    account("fused", op if weight is None else f"{op}_weighted")
    return gather_segment_reduce_pallas(h, gather_idx, seg_idx, num_segments,
                                        weight=weight, reduce=reduce,
                                        config=config, max_chunks=max_chunks,
                                        interpret=interpret, plan=plan)


def fused_transform_reduce(h, w, gather_idx, seg_idx, num_segments: int,
                           weight=None, reduce: str = "sum",
                           config: Optional[KernelConfig] = None,
                           max_chunks: Optional[int] = None,
                           interpret: Optional[bool] = None, plan=None,
                           tune: Optional[bool] = None):
    """One-launch SpMM+GEMM: Y[s] = (reduce_{seg[i]==s} wt[i]·H[gidx[i]]) @ W
    — the per-layer dense transform fused into the gather-reduce launch, so
    neither the (|E|, d) edge tensor nor the (S, d_in) aggregate is ever
    materialized. Linear reduces only (sum / mean)."""
    if reduce not in ("sum", "mean"):
        raise ValueError(f"unknown reduce: {reduce!r} "
                         "(fused transform-reduce supports sum/mean)")
    from repro.kernels.fused_transform_reduce import \
        fused_transform_reduce_pallas
    interpret = _default_interpret() if interpret is None else interpret
    config = _resolve_config(config, plan, gather_idx.shape[0], num_segments,
                             h.shape[-1], "fused_transform_reduce", tune,
                             io_dtype=h.dtype)
    account("fused", "fused_transform_reduce"
            if weight is None else "fused_transform_reduce_weighted")
    return fused_transform_reduce_pallas(h, w, gather_idx, seg_idx,
                                         num_segments, weight=weight,
                                         reduce=reduce, config=config,
                                         max_chunks=max_chunks,
                                         interpret=interpret, plan=plan)


def segment_matmul(x, group_sizes, w, config: Optional[KernelConfig] = None,
                   max_groups: Optional[int] = None,
                   interpret: Optional[bool] = None, plan=None,
                   tune: Optional[bool] = None):
    """Grouped GEMM over contiguous row groups — one launch for every
    relation/expert.

    ``plan=`` accepts a :class:`~repro.core.plan.RelationPlan`: its
    precomputed ``offsets`` / ``first_group`` / ``group_count`` leaves
    become the kernel's scalar-prefetch operands (no per-call
    searchsorted) and its tight ``max_groups`` bounds the grid's group
    dimension. A :class:`~repro.core.plan.SegmentPlan` is still accepted
    for backward compatibility (config only — its chunk metadata describes
    a segment index, not group offsets)."""
    interpret = _default_interpret() if interpret is None else interpret
    meta = {}
    if plan is not None and hasattr(plan, "first_group"):
        plan.validate(int(x.shape[0]), int(group_sizes.shape[0]))
        if config is None:
            config = plan.config
        elif (config.m_b, config.n_b) != (plan.config.m_b, plan.config.n_b):
            raise ValueError(
                f"explicit config (m_b={config.m_b}, n_b={config.n_b}) "
                f"conflicts with RelationPlan tiling "
                f"(m_b={plan.config.m_b}, n_b={plan.config.n_b})")
        if max_groups is None:
            max_groups = plan.max_groups
        meta = dict(offsets=plan.offsets, first_group=plan.first_group,
                    group_count=plan.group_count)
    elif config is None and plan is not None:
        config = plan.config
    if config is None:
        from repro.core.heuristics import select_config
        config = select_config(int(x.shape[0]), int(group_sizes.shape[0]),
                               int(w.shape[-1]), op="segment_matmul",
                               tune=tune)
    account("fused", "segment_matmul")
    return segment_matmul_pallas(x, group_sizes, w, m_b=config.m_b,
                                 n_b=config.n_b, max_groups=max_groups,
                                 interpret=interpret, **meta)


def sddmm(a, b, row_idx, col_idx, config: Optional[KernelConfig] = None,
          interpret: Optional[bool] = None, plan=None,
          tune: Optional[bool] = None):
    """Per-edge dot products. ``plan=`` is accepted for API symmetry with
    the reduction ops: only its selected config is consumed (SDDMM is a
    pure gather — a SegmentPlan's chunk metadata describes a sorted segment
    index, which SDDMM neither requires nor reads)."""
    from repro.kernels.sddmm import sddmm_pallas
    interpret = _default_interpret() if interpret is None else interpret
    if config is None and plan is not None:
        config = plan.config
    if config is None:
        from repro.core.heuristics import select_config
        config = select_config(int(row_idx.shape[0]), int(a.shape[0]),
                               int(a.shape[-1]), op="sddmm", tune=tune)
    return sddmm_pallas(a, b, row_idx, col_idx, m_b=config.m_b,
                        n_b=config.n_b, interpret=interpret)


def segment_softmax(x, idx, num_segments: int,
                    config: Optional[KernelConfig] = None,
                    max_chunks: Optional[int] = None,
                    interpret: Optional[bool] = None, plan=None,
                    tune: Optional[bool] = None):
    """Fused plan-aware softmax within sorted segments ((M,) or (M, H))."""
    from repro.kernels.segment_softmax import segment_softmax_pallas
    interpret = _default_interpret() if interpret is None else interpret
    feat = int(x.shape[-1]) if x.ndim > 1 else 1
    config = _resolve_config(config, plan, idx.shape[0], num_segments, feat,
                             "segment_softmax", tune, io_dtype=x.dtype)
    account("fused", "segment_softmax")
    return segment_softmax_pallas(x, idx, num_segments, config=config,
                                  max_chunks=max_chunks, interpret=interpret,
                                  plan=plan)
