"""Jit'd public wrappers around the Pallas kernels.

On this CPU-only container the kernels execute with ``interpret=True``
(Pallas interpreter); on TPU hardware set ``REPRO_PALLAS_INTERPRET=0`` (or
pass ``interpret=False``) to compile via Mosaic. Config selection defaults to
the data-aware generated rules (paper §III-C).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.core.config_space import KernelConfig
from repro.kernels.gather_segment_reduce import gather_segment_reduce_pallas
from repro.kernels.segment_matmul import segment_matmul_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def segment_reduce(x, idx, num_segments: int, reduce: str = "sum",
                   config: Optional[KernelConfig] = None,
                   max_chunks: Optional[int] = None,
                   interpret: Optional[bool] = None, plan=None):
    interpret = _default_interpret() if interpret is None else interpret
    return segment_reduce_pallas(x, idx, num_segments, reduce=reduce,
                                 config=config, max_chunks=max_chunks,
                                 interpret=interpret, plan=plan)


def gather_segment_reduce(h, gather_idx, seg_idx, num_segments: int,
                          weight=None, reduce: str = "sum",
                          config: Optional[KernelConfig] = None,
                          max_chunks: Optional[int] = None,
                          interpret: Optional[bool] = None, plan=None):
    if reduce != "sum":
        raise NotImplementedError("fused gather supports sum (paper §IV)")
    interpret = _default_interpret() if interpret is None else interpret
    return gather_segment_reduce_pallas(h, gather_idx, seg_idx, num_segments,
                                        weight=weight, config=config,
                                        max_chunks=max_chunks,
                                        interpret=interpret, plan=plan)


def segment_matmul(x, group_sizes, w, config: Optional[KernelConfig] = None,
                   max_groups: Optional[int] = None,
                   interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    m_b = config.m_b if config is not None else 128
    n_b = config.n_b if config is not None else 128
    return segment_matmul_pallas(x, group_sizes, w, m_b=m_b, n_b=n_b,
                                 max_groups=max_groups, interpret=interpret)


def sddmm(a, b, row_idx, col_idx, config: Optional[KernelConfig] = None,
          interpret: Optional[bool] = None):
    from repro.kernels.sddmm import sddmm_pallas
    interpret = _default_interpret() if interpret is None else interpret
    m_b = config.m_b if config is not None else 256
    n_b = config.n_b if config is not None else 512
    return sddmm_pallas(a, b, row_idx, col_idx, m_b=m_b, n_b=n_b,
                        interpret=interpret)
