"""Fused plan-aware segment softmax Pallas kernel (GAT attention, §VI).

    out[i, :] = exp(x[i] - m[seg[i]]) / z[seg[i]]
    m[s] = max_{seg[i]==s} x[i],   z[s] = Σ_{seg[i]==s} exp(x[i] - m[s])

replaces the three-pass pure-jnp formulation (segment_max → exp → segment_sum
→ normalize, four HBM round-trips of the (|E|, H) logits) with **one**
launch that consumes the same SegmentPlan chunk metadata as the reduction
kernels.

Schedule: the grid is (out_blocks, 2·max_chunks) — each output block walks
its owned chunk range twice:

  phase 0 (stats) — an SR-style walk with an *online-softmax* accumulator
    (running max m and rescaled sum z: z ← z·e^{m−m'} + e^{x−m'}), flushed
    into (S_b, H) VMEM stat tiles at each segment boundary. One pass gives
    both m and z, numerically stable for arbitrary logit magnitudes.
  phase 1 (emit) — re-walks the same chunks, normalizes each row against its
    segment's stats, and DMAs the finished rows to the per-edge output in
    ANY/HBM memory. Rows are written only by the block owning their segment,
    so shared boundary chunks never clobber a neighbour's rows.

Heads ride the feature (lane) dimension — (E, H) logits are processed as one
lane tile of round_up(H, 128) columns, so multi-head GAT costs the same walk
as single-head. The per-row output DMA has the same sub-512 B granularity
caveat as the fused gather (see ``gather_segment_reduce``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.config_space import KernelConfig
from repro.kernels.segment_reduce import _resolve_plan, _round_up


def _softmax_body(cf_ref, cc_ref, idx_ref, x_ref, o_ref,
                  m_ref, z_ref, am_ref, az_ref, st_ref, obuf_ref, sem,
                  *, s_b: int, m_b: int, max_chunks: int):
    b, kk = pl.program_id(0), pl.program_id(1)
    k = jax.lax.rem(kk, max_chunks)
    in_stats = kk < max_chunks

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        z_ref[...] = jnp.zeros_like(z_ref)
        st_ref[0] = -1

    @pl.when(jnp.logical_and(in_stats, k < cc_ref[b]))
    def _stats():
        seg = idx_ref[0, :]

        def flush():
            p = st_ref[0]
            m_ref[pl.ds(p, 1), :] = am_ref[...]
            z_ref[pl.ds(p, 1), :] = az_ref[...]

        def walk(i, _):
            r = seg[i] - b * s_b
            in_win = jnp.logical_and(r >= 0, r < s_b)
            opened = st_ref[0] >= 0

            @pl.when(jnp.logical_and(opened,
                                     jnp.logical_or(~in_win, r != st_ref[0])))
            def _():
                flush()
                st_ref[0] = -1

            xrow = x_ref[pl.ds(i, 1), :].astype(jnp.float32)

            @pl.when(jnp.logical_and(in_win, st_ref[0] == r))
            def _():  # online-softmax update of the open segment
                new_m = jnp.maximum(am_ref[...], xrow)
                az_ref[...] = (az_ref[...] * jnp.exp(am_ref[...] - new_m)
                               + jnp.exp(xrow - new_m))
                am_ref[...] = new_m

            @pl.when(jnp.logical_and(in_win, st_ref[0] != r))
            def _():  # open a new segment: m = x, z = e^{x-x} = 1
                am_ref[...] = xrow
                az_ref[...] = jnp.ones_like(az_ref)
                st_ref[0] = r

            return 0

        jax.lax.fori_loop(0, m_b, walk, 0, unroll=False)

        @pl.when(jnp.logical_and(k == cc_ref[b] - 1, st_ref[0] >= 0))
        def _():
            flush()
            st_ref[0] = -1

    @pl.when(jnp.logical_and(~in_stats, k < cc_ref[b]))
    def _emit():
        seg = idx_ref[0, :]
        row0 = (cf_ref[b] + k) * m_b

        def row_copy(i):
            # each row is owned by exactly one block's window, and every
            # started copy reads its own obuf row — no slot reuse hazard
            return pltpu.make_async_copy(
                obuf_ref.at[pl.ds(i, 1), :],
                o_ref.at[pl.ds(row0 + i, 1), :],
                sem,
            )

        def compute_and_start(i, _):
            r = seg[i] - b * s_b
            in_win = jnp.logical_and(r >= 0, r < s_b)
            rc = jnp.clip(r, 0, s_b - 1)
            xrow = x_ref[pl.ds(i, 1), :].astype(jnp.float32)
            mrow = m_ref[pl.ds(rc, 1), :]
            zrow = z_ref[pl.ds(rc, 1), :]
            obuf_ref[pl.ds(i, 1), :] = (jnp.exp(xrow - mrow)
                                        / jnp.maximum(zrow, 1e-20)
                                        ).astype(obuf_ref.dtype)

            @pl.when(in_win)
            def _():
                row_copy(i).start()

            return 0

        def drain(i, _):
            r = seg[i] - b * s_b

            @pl.when(jnp.logical_and(r >= 0, r < s_b))
            def _():
                row_copy(i).wait()

            return 0

        # software-pipelined: all in-window row DMAs are in flight before
        # the first wait (cf. _gather_chunk's overlap in the gather kernel)
        jax.lax.fori_loop(0, m_b, compute_and_start, 0, unroll=False)
        jax.lax.fori_loop(0, m_b, drain, 0, unroll=False)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "config", "max_chunks", "interpret"),
)
def _segment_softmax_impl(x, idx, num_segments: int, config: KernelConfig,
                          max_chunks: Optional[int], interpret: bool,
                          plan=None):
    m, h = x.shape
    s_b, m_b = config.s_b, config.m_b
    h_pad = _round_up(max(h, 1), 128)      # heads ride the lane dimension
    m_pad = _round_up(max(m, 1), m_b)
    s_pad = _round_up(num_segments, s_b)

    # logits stay in their io dtype through HBM — each row is upcast to the
    # fp32 online-softmax accumulators only after it lands in VMEM, so bf16
    # attention logits keep the half-bandwidth read (stats stay fp32)
    xp = jnp.pad(x, ((0, m_pad - m), (0, h_pad - h)))
    idxp = jnp.pad(idx.astype(jnp.int32), (0, m_pad - m),
                   constant_values=num_segments)
    idx2d = idxp.reshape(m_pad // m_b, m_b)

    if plan is not None:
        chunk_first, chunk_count = plan.chunk_first, plan.chunk_count
    else:
        from repro.kernels.segment_reduce import chunk_metadata
        chunk_first, chunk_count = chunk_metadata(idxp, num_segments, s_b,
                                                  m_b, m_pad)
    out_blocks = s_pad // s_b
    if max_chunks is None:
        max_chunks = m_pad // m_b

    def row_map(b, kk, cf, cc):
        k = jax.lax.rem(kk, max_chunks)
        return (cf[b] + jnp.minimum(k, jnp.maximum(cc[b] - 1, 0)), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(out_blocks, 2 * max_chunks),
        in_specs=[
            pl.BlockSpec((1, m_b), row_map),               # seg idx
            pl.BlockSpec((m_b, h_pad), row_map),           # logits
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),    # per-edge output
        scratch_shapes=[
            pltpu.VMEM((s_b, h_pad), jnp.float32),         # segment max m
            pltpu.VMEM((s_b, h_pad), jnp.float32),         # segment sum-exp z
            pltpu.VMEM((1, h_pad), jnp.float32),           # open-segment m
            pltpu.VMEM((1, h_pad), jnp.float32),           # open-segment z
            pltpu.SMEM((1,), jnp.int32),                   # open-segment rel
            pltpu.VMEM((m_b, h_pad), x.dtype),             # output chunk stage
            pltpu.SemaphoreType.DMA,
        ],
    )
    # output rides the io dtype too (α ∈ [0, 1] — bf16 holds it to ~2^-8
    # relative, inside the tiered tolerance): the stage buffer is cast right
    # before its row DMA, halving the per-edge write *and* the weighted
    # aggregation's subsequent read for bf16 logits
    out = pl.pallas_call(
        functools.partial(_softmax_body, s_b=s_b, m_b=m_b,
                          max_chunks=max_chunks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_pad, h_pad), x.dtype),
        interpret=interpret,
    )(chunk_first, chunk_count, idx2d, xp)
    out = out[:m, :h]
    # rows of dropped segments (idx >= num_segments, the padding convention
    # of pad_graph / partition) belong to no output block, so no phase-1 DMA
    # ever writes them — the buffer holds garbage there (NaN under the
    # interpreter). Define them as 0: a later weighted aggregation treats α
    # as a per-edge weight, and the PR schedule's one-hot masking multiplies
    # rather than selects, so 0·NaN would poison real outputs.
    out = jnp.where((idx < num_segments)[:, None], out, 0.0)
    return out.astype(x.dtype)


def segment_softmax_pallas(x, idx, num_segments: int,
                           config: Optional[KernelConfig] = None,
                           max_chunks: Optional[int] = None,
                           interpret: bool = False, plan=None):
    """Softmax within sorted segments, (M,) or (M, H) logits, one launch.

    ``plan``: precomputed :class:`repro.core.plan.SegmentPlan` over ``idx``
    (shared with the reduction kernels — same chunk metadata, same tight
    ``max_chunks``).  Only ``s_b``/``m_b`` of the config are consumed (the
    walk is SR-like; heads are a single lane tile)."""
    squeeze = x.ndim == 1
    x2 = x[:, None] if squeeze else x
    config, max_chunks = _resolve_plan(plan, int(idx.shape[0]), num_segments,
                                       config, max_chunks)
    if config is None:
        from repro.core.heuristics import select_config
        config = select_config(int(idx.shape[0]), num_segments,
                               int(x2.shape[1]), op="segment_softmax")
    out = _segment_softmax_impl(x2, idx, num_segments, config, max_chunks,
                                interpret, plan)
    return out[:, 0] if squeeze else out
