"""Grouped (segment) matmul Pallas kernel — GeoT-extension op.

    out[rows of group e, :] = X[rows of group e, :] @ W[e]

with X (M, K) sorted so each group's rows are contiguous (the MoE expert FFN
hot path: tokens sorted by expert id; the heterogeneous-GNN hot path:
edge messages sorted by relation type — FASTEN's critical operator).  Same
sortedness contract as segment reduction.  Oracle: ``jax.lax.ragged_dot``.

Tiling: grid = (m_blocks, n_tiles, max_groups_per_block).  A row block of
M_b rows usually lies inside one group (MoE segments ≫ M_b); boundary blocks
overlap ≤ max_groups groups, enumerated by the innermost grid dim with rows
outside the current group masked to zero *before* the MXU matmul.  The
output block accumulates across the group dim (sequential grid ⇒ race-free).

The per-block group metadata (first group / group count per row block, and
the tight ``max_groups`` bound) is exactly what a
:class:`~repro.core.plan.RelationPlan` precomputes once per typed graph —
:func:`group_metadata` is the single formula both paths evaluate, so plans
can never drift from the per-call computation (the same one-formula
guarantee :func:`repro.kernels.segment_reduce.chunk_metadata` gives
:class:`~repro.core.plan.SegmentPlan`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.segment_reduce import _round_up


def _body(off_ref, fg_ref, gc_ref, x_ref, w_ref, o_ref, *, m_b: int):
    mb, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k < gc_ref[mb])
    def _compute():
        g = fg_ref[mb] + k
        rows = mb * m_b + jax.lax.broadcasted_iota(jnp.int32, (m_b, 1), 0)
        mask = jnp.logical_and(rows >= off_ref[g], rows < off_ref[g + 1])
        xm = jnp.where(mask, x_ref[...], jnp.zeros((), x_ref.dtype))
        # fp32 MXU accumulate, io-dtype store: each output row is owned by
        # exactly one group (foreign rows are masked to zero before the
        # matmul), so the += across the group grid dim only ever adds zeros
        # to already-written rows — storing in the io dtype loses nothing.
        o_ref[...] += jax.lax.dot_general(
            xm, w_ref[0],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(o_ref.dtype)


def group_metadata(group_sizes, num_rows: int, m_b: int):
    """Per-row-block group schedule for the grouped matmul grid.

    Returns ``(offsets, first_group, group_count)``:

      * ``offsets`` (E+1,) — cumulative row offsets per group;
      * ``first_group`` (m_blocks,) — the group owning each block's first
        live row;
      * ``group_count`` (m_blocks,) — how many groups the block overlaps
        (0 for blocks made purely of padding rows).

    One formula for both the per-call trace-time path (jnp on traced
    arrays) and the host-side :class:`~repro.core.plan.RelationPlan`
    construction (jnp on concrete numpy — evaluated eagerly)."""
    group_sizes = jnp.asarray(group_sizes)
    e = group_sizes.shape[0]
    offsets = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(group_sizes.astype(jnp.int32))])
    m_pad = _round_up(max(num_rows, 1), m_b)
    m_blocks = m_pad // m_b
    starts = jnp.arange(m_blocks, dtype=jnp.int32) * m_b
    ends = starts + (m_b - 1)
    # group containing a row r: searchsorted(offsets, r, 'right') - 1
    fg = jnp.clip(jnp.searchsorted(offsets, starts, side="right") - 1,
                  0, e - 1)
    lg = jnp.clip(jnp.searchsorted(offsets,
                                   jnp.minimum(ends, num_rows - 1),
                                   side="right") - 1, 0, e - 1)
    gc = (lg - fg + 1).astype(jnp.int32)
    # blocks made purely of padding rows do no work
    gc = jnp.where(starts >= num_rows, 0, gc).astype(jnp.int32)
    return offsets, fg.astype(jnp.int32), gc


@functools.partial(jax.jit,
                   static_argnames=("m_b", "n_b", "max_groups", "interpret"))
def segment_matmul_pallas(x, group_sizes, w, m_b: int = 128,
                          n_b: int = 128, max_groups: Optional[int] = None,
                          interpret: bool = False, offsets=None,
                          first_group=None, group_count=None):
    """x: (M, K) group-sorted; group_sizes: (E,) with sum ≤ M; w: (E, K, N).

    ``offsets``/``first_group``/``group_count``: precomputed
    :func:`group_metadata` (a RelationPlan's leaves) — when given, the
    per-call searchsorted is skipped entirely; pair them with the plan's
    tight ``max_groups`` so the grid's group dimension is O(actual
    boundary overlap) instead of O(min(E, M_b+1))."""
    m, kdim = x.shape
    e, _, n = w.shape
    n_b = min(n_b, _round_up(max(n, 1), 128))
    m_pad = _round_up(max(m, 1), m_b)
    n_pad = _round_up(max(n, 1), n_b)

    xp = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, n_pad - n)))

    if offsets is None:
        offsets, first_group, group_count = group_metadata(group_sizes, m,
                                                           m_b)
    fg, gc = first_group, group_count

    if max_groups is None:
        max_groups = min(e, m_b + 1)
    m_blocks = m_pad // m_b
    n_tiles = n_pad // n_b

    def x_map(mb, j, k, off, fg_, gc_):
        return (mb, 0)

    def w_map(mb, j, k, off, fg_, gc_):
        return (fg_[mb] + jnp.minimum(k, jnp.maximum(gc_[mb] - 1, 0)), 0, j)

    def o_map(mb, j, k, off, fg_, gc_):
        return (mb, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(m_blocks, n_tiles, max_groups),
        in_specs=[
            pl.BlockSpec((m_b, kdim), x_map),
            pl.BlockSpec((1, kdim, n_b), w_map),
        ],
        out_specs=pl.BlockSpec((m_b, n_b), o_map),
    )

    # out buffer in the io dtype: bf16 grouped matmuls must not materialize
    # a 2x-size fp32 intermediate (the MXU still accumulates fp32 per tile
    # via preferred_element_type in the kernel body).
    out = pl.pallas_call(
        functools.partial(_body, m_b=m_b),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), x.dtype),
        interpret=interpret,
    )(offsets, fg, gc, xp, wp)
    return out[:m, :n]
