"""Fused message+aggregate Pallas kernel (paper §IV, Listing 2):

    Y[s] = reduce_{i: seg[i]==s} (w[i]·) H[gidx[i]]     reduce ∈ {sum, mean, max}

The (|E|, N) message tensor never exists in HBM: each chunk's H rows are
gathered straight into a VMEM staging buffer by per-row async DMA (the TPU
analogue of the fused gather — H stays unblocked in HBM/ANY memory), then the
same PR (MXU one-hot) / SR (VPU walk) reduction as
:mod:`repro.kernels.segment_reduce` consumes the staged tile.

All three reduces are **single-launch** (paper §VI: generalizing the
reduction type does not change the schedule):

  * ``sum``  — the paper's SpMM (weighted) / message-sum (unweighted);
  * ``mean`` — per-segment counts are accumulated inside the same kernel
    (a (S_b, 1) VMEM scratch fed by the one-hot column sums on PR, by a
    per-open-segment counter on SR) and the output block is divided by
    them at its final chunk — no second count launch;
  * ``max``  — SR running-maximum walk with a -inf identity (matching
    ``jax.ops.segment_max`` on empty segments); a PR request falls back to
    SR (a one-hot matmul cannot express max).

Weighted variants reduce over ``w[i]·H[gidx[i]]`` (mean divides by the row
count, matching the reference oracle's "mean of the weighted messages").

Roofline note: per-row DMA granularity is N_b·dtype bytes; below 512 B the
gather runs below peak HBM bandwidth (modelled in
``repro.core.costmodel.spmm_cost`` and visible in §Roofline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.config_space import KernelConfig
from repro.kernels.segment_reduce import _resolve_plan, _round_up, chunk_metadata


def _gather_chunk(gidx_ref, h_ref, xbuf_ref, sem, j: jax.Array, n_b: int):
    """DMA-gather the chunk's H rows (column tile j) into VMEM staging.

    Software-pipelined: row i+1's copy is issued before waiting on row i,
    so each DMA's latency hides behind the next one's issue (the per-row
    granularity penalty below 512 B remains — modelled in
    costmodel.spmm_cost and visible in §Roofline)."""
    m_b = gidx_ref.shape[1]

    def start(i):
        g = gidx_ref[0, i]
        cp = pltpu.make_async_copy(
            h_ref.at[pl.ds(g, 1), pl.ds(j * n_b, n_b)],
            xbuf_ref.at[pl.ds(i, 1), :],
            sem,
        )
        cp.start()
        return cp

    first = start(0)

    def copy_row(i, prev_started):
        # issue row i+1 while row i is in flight, then retire row i
        @pl.when(i + 1 < m_b)
        def _():
            start(i + 1)
        g = gidx_ref[0, i]
        pltpu.make_async_copy(
            h_ref.at[pl.ds(g, 1), pl.ds(j * n_b, n_b)],
            xbuf_ref.at[pl.ds(i, 1), :],
            sem,
        ).wait()
        return prev_started

    jax.lax.fori_loop(0, m_b, copy_row, 0, unroll=False)


def _pr_body(cf_ref, cc_ref, gidx_ref, idx_ref, w_ref, h_ref, o_ref,
             xbuf_ref, sem, *scratch, s_b: int, n_b: int, has_weight: bool,
             reduce: str):
    b, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cnt_ref = scratch[0] if reduce == "mean" else None

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        if reduce == "mean":
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(k < cc_ref[b])
    def _compute():
        _gather_chunk(gidx_ref, h_ref, xbuf_ref, sem, j, n_b)
        xg = xbuf_ref[...]
        if has_weight:
            xg = xg * w_ref[0, :][:, None].astype(xg.dtype)
        seg = idx_ref[0, :]
        m_b = seg.shape[0]
        rel = seg - b * s_b
        cols = jax.lax.broadcasted_iota(jnp.int32, (m_b, s_b), 1)
        onehot = (rel[:, None] == cols).astype(xg.dtype)
        o_ref[...] += jax.lax.dot_general(
            onehot, xg, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=o_ref.dtype).astype(o_ref.dtype)
        if reduce == "mean":
            # column sums of the one-hot == per-segment row counts. Padding
            # rows carry seg == num_segments: when num_segments % s_b != 0
            # they DO land in the last block's window and count into (and
            # divide) the guard row — correct only because the caller
            # slices the output to [:num_segments].
            cnt_ref[...] += jnp.sum(onehot.astype(jnp.float32), axis=0)[:, None]

    if reduce == "mean":
        # normalize once, after the block's last owned chunk accumulated
        @pl.when(k == cc_ref[b] - 1)
        def _normalize():
            o_ref[...] = o_ref[...] / jnp.maximum(cnt_ref[...], 1.0)


def _sr_body(cf_ref, cc_ref, gidx_ref, idx_ref, w_ref, h_ref, o_ref,
             xbuf_ref, sem, acc_ref, st_ref, *scratch, s_b: int, n_b: int,
             has_weight: bool, reduce: str):
    b, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cnt_ref, ca_ref = scratch if reduce == "mean" else (None, None)
    # max identity is -inf, matching jax.ops.segment_max on empty segments
    init_val = -jnp.inf if reduce == "max" else 0.0

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, init_val)
        st_ref[0] = -1
        if reduce == "mean":
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(k < cc_ref[b])
    def _compute():
        _gather_chunk(gidx_ref, h_ref, xbuf_ref, sem, j, n_b)
        seg = idx_ref[0, :]
        m_b = seg.shape[0]

        def flush():
            p = st_ref[0]
            if reduce == "max":
                o_ref[pl.ds(p, 1), :] = jnp.maximum(o_ref[pl.ds(p, 1), :],
                                                    acc_ref[...])
            else:
                o_ref[pl.ds(p, 1), :] += acc_ref[...]
            if reduce == "mean":
                cnt_ref[pl.ds(p, 1), :] += ca_ref[...]

        def walk(i, _):
            r = seg[i] - b * s_b
            in_win = jnp.logical_and(r >= 0, r < s_b)
            opened = st_ref[0] >= 0

            @pl.when(jnp.logical_and(opened,
                                     jnp.logical_or(~in_win, r != st_ref[0])))
            def _():
                flush()
                st_ref[0] = -1

            xrow = xbuf_ref[pl.ds(i, 1), :].astype(acc_ref.dtype)
            if has_weight:
                xrow = xrow * w_ref[0, i].astype(acc_ref.dtype)

            @pl.when(jnp.logical_and(in_win, st_ref[0] == r))
            def _():
                if reduce == "max":
                    acc_ref[...] = jnp.maximum(acc_ref[...], xrow)
                else:
                    acc_ref[...] += xrow
                if reduce == "mean":
                    ca_ref[...] += 1.0

            @pl.when(jnp.logical_and(in_win, st_ref[0] != r))
            def _():
                acc_ref[...] = xrow
                st_ref[0] = r
                if reduce == "mean":
                    ca_ref[...] = jnp.ones_like(ca_ref)

            return 0

        jax.lax.fori_loop(0, m_b, walk, 0, unroll=False)

        @pl.when(jnp.logical_and(k == cc_ref[b] - 1, st_ref[0] >= 0))
        def _():
            flush()
            st_ref[0] = -1

    if reduce == "mean":
        @pl.when(k == cc_ref[b] - 1)
        def _normalize():
            o_ref[...] = o_ref[...] / jnp.maximum(cnt_ref[...], 1.0)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "config", "max_chunks", "interpret",
                     "has_weight", "reduce"),
)
def _gather_segment_reduce_impl(h, gather_idx, seg_idx, weight,
                                num_segments: int, config: KernelConfig,
                                max_chunks: Optional[int], interpret: bool,
                                has_weight: bool, reduce: str = "sum",
                                plan=None):
    m = gather_idx.shape[0]
    v, n = h.shape
    s_b, n_b, m_b = config.s_b, config.n_b, config.m_b
    n_b = min(n_b, _round_up(max(n, 1), 128))
    m_pad = _round_up(max(m, 1), m_b)
    n_pad = _round_up(max(n, 1), n_b)
    s_pad = _round_up(num_segments, s_b)

    hp = jnp.pad(h, ((0, 1), (0, n_pad - n)))        # +1 guard row for padding
    gidxp = jnp.pad(gather_idx.astype(jnp.int32), (0, m_pad - m),
                    constant_values=v)               # padding gathers guard row
    idxp = jnp.pad(seg_idx.astype(jnp.int32), (0, m_pad - m),
                   constant_values=num_segments)
    # weights stay in their io dtype through HBM/VMEM — upcasting happens
    # inside the accumulator (SR walk) or via the MXU's fp32
    # preferred_element_type (PR), so weighted bf16 reduces keep the
    # half-bandwidth win on the weight stream too
    wp = jnp.pad(weight, (0, m_pad - m))
    gidx2d = gidxp.reshape(m_pad // m_b, m_b)
    idx2d = idxp.reshape(m_pad // m_b, m_b)
    w2d = wp.reshape(m_pad // m_b, m_b)

    if plan is not None:
        chunk_first, chunk_count = plan.chunk_first, plan.chunk_count
    else:
        chunk_first, chunk_count = chunk_metadata(idxp, num_segments, s_b,
                                                  m_b, m_pad)
    out_blocks = s_pad // s_b
    n_tiles = n_pad // n_b
    if max_chunks is None:
        max_chunks = m_pad // m_b

    def row_map(b, j, k, cf, cc):
        return (cf[b] + jnp.minimum(k, jnp.maximum(cc[b] - 1, 0)), 0)

    def o_map(b, j, k, cf, cc):
        return (b, j)

    common = dict(
        grid=(out_blocks, n_tiles, max_chunks),
        in_specs=[
            pl.BlockSpec((1, m_b), row_map),                  # gather_idx
            pl.BlockSpec((1, m_b), row_map),                  # seg_idx
            pl.BlockSpec((1, m_b), row_map),                  # weight
            pl.BlockSpec(memory_space=pltpu.ANY),             # H (unblocked)
        ],
        out_specs=pl.BlockSpec((s_b, n_b), o_map),
    )
    scratch = [pltpu.VMEM((m_b, n_b), h.dtype), pltpu.SemaphoreType.DMA]
    # fused mean: per-segment row counts live next to the output block
    cnt_scratch = [pltpu.VMEM((s_b, 1), jnp.float32)] if reduce == "mean" else []

    if config.schedule == "PR":
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, **common,
            scratch_shapes=scratch + cnt_scratch)
        body = functools.partial(_pr_body, s_b=s_b, n_b=n_b,
                                 has_weight=has_weight, reduce=reduce)
    else:
        sr_scratch = [pltpu.VMEM((1, n_b), jnp.float32),
                      pltpu.SMEM((1,), jnp.int32)]
        if reduce == "mean":
            sr_scratch += cnt_scratch + [pltpu.VMEM((1, 1), jnp.float32)]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, **common, scratch_shapes=scratch + sr_scratch)
        body = functools.partial(_sr_body, s_b=s_b, n_b=n_b,
                                 has_weight=has_weight, reduce=reduce)

    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(chunk_first, chunk_count, gidx2d, idx2d, w2d, hp)

    return out[:num_segments, :n].astype(h.dtype)


def gather_segment_reduce_pallas(h, gather_idx, seg_idx, num_segments: int,
                                 weight=None, reduce: str = "sum",
                                 config: Optional[KernelConfig] = None,
                                 max_chunks: Optional[int] = None,
                                 interpret: bool = False, plan=None):
    """Fused Y[s] = reduce_{seg[i]==s} (w[i]·) H[gather_idx[i]] — one launch
    for every reduce ∈ {sum, mean, max} (format-agnostic SpMM when sum +
    weighted).  seg_idx must be sorted non-decreasing. ``plan``: precomputed
    :class:`repro.core.plan.SegmentPlan` over ``seg_idx`` (shared with the
    unfused kernel — both consume the same chunk metadata)."""
    if reduce not in ("sum", "mean", "max"):
        raise ValueError(f"unknown reduce: {reduce!r}")
    config, max_chunks = _resolve_plan(plan, int(gather_idx.shape[0]),
                                       num_segments, config, max_chunks)
    if config is None:
        from repro.core.heuristics import select_config
        config = select_config(int(gather_idx.shape[0]), num_segments,
                               int(h.shape[1]))
    if reduce == "max" and config.schedule == "PR":
        # a one-hot matmul cannot express max; same tiling, SR walk instead
        config = KernelConfig("SR", config.s_b, config.n_b, config.m_b, 1)
    has_weight = weight is not None
    if weight is None:
        # dummy ones ride the io dtype so the unused stream stays narrow
        weight = jnp.ones((gather_idx.shape[0],), h.dtype)
    return _gather_segment_reduce_impl(h, gather_idx, seg_idx, weight,
                                       num_segments, config, max_chunks,
                                       interpret, has_weight, reduce, plan)
