"""Pure-jnp oracles for every Pallas kernel (allclose targets).

These are *semantic* references (XLA scatter/gather based) — independent of
the blocked algorithm in :mod:`repro.core.ops`, so kernel tests validate
against a formulation that shares no code with the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_reduce(x, idx, num_segments: int, reduce: str = "sum"):
    f = {"sum": jax.ops.segment_sum, "max": jax.ops.segment_max}.get(reduce)
    if f is not None:
        return f(x, idx, num_segments, indices_are_sorted=True)
    if reduce == "mean":
        s = jax.ops.segment_sum(x, idx, num_segments, indices_are_sorted=True)
        c = jax.ops.segment_sum(jnp.ones((x.shape[0],), jnp.float32), idx,
                                num_segments, indices_are_sorted=True)
        return (s / jnp.maximum(c, 1.0)[:, None]).astype(x.dtype)
    raise ValueError(reduce)


def gather_segment_reduce(h, gather_idx, seg_idx, num_segments: int,
                          weight=None, reduce: str = "sum"):
    msg = jnp.take(h, gather_idx, axis=0)
    if weight is not None:
        msg = msg * weight[:, None].astype(msg.dtype)
    return segment_reduce(msg, seg_idx, num_segments, reduce)


def segment_matmul(x, group_sizes, w):
    """Grouped GEMM oracle: masked per-group matmuls (O(E·M·K·N), test-scale
    only — deliberately naive and independent of lax.ragged_dot)."""
    m = x.shape[0]
    e = w.shape[0]
    offsets = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                               jnp.cumsum(group_sizes)])
    rows = jnp.arange(m)
    out = jnp.zeros((m, w.shape[-1]), jnp.promote_types(x.dtype, w.dtype))
    for g in range(e):
        mask = ((rows >= offsets[g]) & (rows < offsets[g + 1]))[:, None]
        out = out + jnp.where(mask, x @ w[g], 0.0)
    return out.astype(x.dtype)
