"""Pallas TPU kernels for GeoT's compute hot-spots (paper §III/§IV).

segment_reduce          — SR (VPU walk) + PR (MXU one-hot) schedules
gather_segment_reduce   — fused message+aggregate, reduce ∈ {sum, mean, max}
                          (format-agnostic SpMM when weighted sum)
segment_softmax         — fused plan-aware softmax over sorted segments
segment_matmul          — grouped GEMM over segments (MoE expert FFN)
sddmm                   — per-edge dot products (the SpMM backward)

Validate vs. :mod:`repro.kernels.ref` oracles; interpret=True on CPU.
"""
