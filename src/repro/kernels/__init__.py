"""Pallas TPU kernels for GeoT's compute hot-spots (paper §III/§IV).

segment_reduce          — SR (VPU walk) + PR (MXU one-hot) schedules
gather_segment_reduce   — fused message+aggregate (format-agnostic SpMM)
segment_matmul          — grouped GEMM over segments (MoE expert FFN)

Validate vs. :mod:`repro.kernels.ref` oracles; interpret=True on CPU.
"""
