"""Attribution hooks: every expensive or surprising event — a jit
trace, a plan-cache miss/eviction, an autotuner sweep, a bucket probe —
records a structured *cause*, so "why did step 37 compile?" is
answerable from the telemetry dump alone.

Events are plain dicts in a bounded ring (``attributions()``), each with
``kind`` / ``site`` / ``cause`` plus whatever structured detail the call
site attaches (op key, bucket, io_dtype, treedef hash, step). A counter
per (site, cause) lands in the metrics registry so dashboards can alert
on compile storms without parsing the ring.

Recording respects the observability switch (``repro.obs.disable()``
makes every hook a no-op); the public counter APIs these events annotate
(``CacheStats`` etc.) are vital and keep counting regardless.
"""
from __future__ import annotations

import collections
import os
import threading
import time
from typing import List, Optional

from repro.obs import registry as _registry

__all__ = ["record_compile", "record_cache_event", "record_tune",
           "record_probe", "attributions", "why_compiled", "reset_events"]

_RING_CAP = int(os.environ.get("REPRO_OBS_EVENTS", "1024"))
_EVENTS: collections.deque = collections.deque(maxlen=_RING_CAP)
_LOCK = threading.Lock()


def _counter(name, labels):
    return _registry.get_registry().counter(name, labels=labels)


def _record(kind: str, site: str, cause: str, detail: dict) -> None:
    if not _registry._is_enabled():
        return
    event = {"kind": kind, "site": site, "cause": cause,
             "t_s": time.time(), **detail}
    with _LOCK:
        _EVENTS.append(event)


def record_compile(site: str, cause: str, **detail) -> None:
    """One jit trace fired at ``site`` (serve.forward, train.step, ...)
    because of ``cause`` (warmup, bucket_miss, new_bucket, retrace,
    sampled_ingest, ...). Attach the bucket, op key, io_dtype, treedef
    hash — whatever identifies the traced program."""
    _counter("compile.events", ("site", "cause")).inc(
        site=site, cause=cause)
    _record("compile", site, cause, detail)


def record_cache_event(cache: str, cause: str, **detail) -> None:
    """A plan-cache miss or eviction on ``cache`` (the instance label the
    cache's counters carry). Hits are not recorded here — they are the
    steady state the counters already measure."""
    _record("cache", f"plan_cache:{cache}", cause, detail)


def record_tune(op: str, *, cache_hit: bool, timings: int = 0,
                **detail) -> None:
    """One autotuner consult: a warm PerfDB hit or a paid wall-clock
    sweep (``timings`` kernels executed)."""
    outcome = "hit" if cache_hit else "sweep"
    _counter("autotune.tunes", ("op", "outcome")).inc(op=op,
                                                      outcome=outcome)
    _record("tune", f"autotune:{op}", outcome,
            dict(detail, timings=timings))


def record_probe(site: str, bucket, **detail) -> None:
    """A bucket probe (e.g. warmup schedule discovery): which bucket a
    probed batch landed in, before any traffic pays for it."""
    _record("probe", site, "bucket_probe", dict(detail, bucket=str(bucket)))


def attributions(kind: Optional[str] = None) -> List[dict]:
    """The event ring, oldest first; ``kind`` filters (compile / cache /
    tune / probe)."""
    with _LOCK:
        events = list(_EVENTS)
    if kind is not None:
        events = [e for e in events if e["kind"] == kind]
    return events


def why_compiled() -> List[dict]:
    """Every recorded jit trace with its cause — the compile audit."""
    return attributions("compile")


def reset_events() -> None:
    with _LOCK:
        _EVENTS.clear()
