"""Metric export: JSON-lines and Prometheus text, plus the periodic
flusher behind ``REPRO_METRICS_PATH``.

JSON-lines (the machine-readable artifact CI parses): one JSON object
per line — ``{"record": "metric", ...}`` series rows straight from
:meth:`MetricsRegistry.snapshot`, ``{"record": "event", ...}``
attribution events, and one trailing ``{"record": "meta", ...}`` stamp.

Prometheus text format (scrape endpoint / pushgateway food): metric
names sanitized (``serve.plan_cache.hits`` → ``repro_serve_plan_cache_
hits``), HELP/TYPE headers, histogram series expanded to ``_bucket``
(cumulative, ``le``-labeled) + ``_sum`` + ``_count`` per convention.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional

from repro.obs import hooks as _hooks
from repro.obs import registry as _registry

__all__ = ["to_jsonl", "write_jsonl", "to_prometheus", "write_prometheus",
           "start_flusher", "stop_flusher"]


def _snapshot(registry=None) -> List[dict]:
    reg = registry if registry is not None else _registry.get_registry()
    return reg.snapshot()


def to_jsonl(registry=None, events: bool = True) -> str:
    lines = []
    for row in _snapshot(registry):
        lines.append(json.dumps({"record": "metric", **row}))
    if events:
        for e in _hooks.attributions():
            lines.append(json.dumps({"record": "event", **e},
                                    default=str))
    lines.append(json.dumps({"record": "meta", "t_s": time.time(),
                             "pid": os.getpid()}))
    return "\n".join(lines) + "\n"


def write_jsonl(path: str, registry=None, events: bool = True) -> str:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(to_jsonl(registry, events=events))
    os.replace(tmp, path)         # atomic: readers never see a torn file
    return path


def _sanitize(name: str) -> str:
    out = "".join(c if c.isalnum() else "_" for c in name)
    return f"repro_{out}"


def to_prometheus(registry=None) -> str:
    reg = registry if registry is not None else _registry.get_registry()
    lines = []
    with reg._lock:
        metrics = list(reg._metrics.items())
    for name, m in metrics:
        pname = _sanitize(name)
        if m.help:
            lines.append(f"# HELP {pname} {m.help}")
        lines.append(f"# TYPE {pname} {m.kind}")
        for labels, cell in m.series_items():
            lab = ",".join(f'{k}="{v}"' for k, v in labels.items())
            if m.kind == "histogram":
                cum = 0
                for edge, c in zip(list(m.buckets) + ["+Inf"], cell.counts):
                    cum += c
                    le = f'le="{edge}"'
                    full = ",".join(x for x in (lab, le) if x)
                    lines.append(f"{pname}_bucket{{{full}}} {cum}")
                tail = f"{{{lab}}}" if lab else ""
                lines.append(f"{pname}_sum{tail} {cell.sum}")
                lines.append(f"{pname}_count{tail} {cell.count}")
            else:
                tail = f"{{{lab}}}" if lab else ""
                lines.append(f"{pname}{tail} {cell[0]}")
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, registry=None) -> str:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(to_prometheus(registry))
    os.replace(tmp, path)
    return path


# ---------------------------------------------------------------------------
# periodic flusher (REPRO_METRICS_PATH)
# ---------------------------------------------------------------------------

_FLUSHER: Optional["_Flusher"] = None
_FLUSHER_LOCK = threading.Lock()


class _Flusher:
    """Daemon thread writing the JSON-lines dump every ``every_s``; a
    final write happens at :func:`stop_flusher` (repro.obs registers one
    at process exit)."""

    def __init__(self, path: str, every_s: float):
        self.path = path
        self.every_s = float(every_s)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-obs-flush")
        self._thread.start()

    def _run(self):
        while not self._stop.wait(self.every_s):
            try:
                write_jsonl(self.path)
            except OSError:
                pass              # a transient fs error must not kill obs

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=5.0)
        try:
            write_jsonl(self.path)
        except OSError:
            pass


def start_flusher(path: str, every_s: float = 30.0) -> None:
    """Idempotent: one flusher per process; re-calling re-points it."""
    global _FLUSHER
    with _FLUSHER_LOCK:
        if _FLUSHER is not None:
            _FLUSHER.stop()
        _FLUSHER = _Flusher(path, every_s)


def stop_flusher() -> None:
    global _FLUSHER
    with _FLUSHER_LOCK:
        if _FLUSHER is not None:
            _FLUSHER.stop()
            _FLUSHER = None
