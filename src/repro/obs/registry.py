"""Thread-safe metrics registry — the single store every repro counter
lands in (``docs/observability.md``).

Three instrument kinds, all labeled:

  * :class:`Counter` — monotonically increasing float (``inc``); the
    load-bearing accounting (cache hits, compiles, trace events).
  * :class:`Gauge`   — last-written value (``set`` / ``add``); queue
    depths and other point-in-time levels.
  * :class:`Histogram` — fixed-bucket latency/size distribution **plus**
    a bounded window of raw samples, so ``p50/p95/p99`` are exact over
    the retained window (the buckets only feed the Prometheus export;
    quantiles never interpolate bucket edges).

Instruments are registered once per name (idempotent — asking again with
the same kind/labels returns the same :class:`Metric`) and live for the
process; ``reset()`` zeroes series without unregistering, so long-lived
holders (a serving engine, a plan cache) keep valid handles across
steady-state measurement windows.

Enable/disable semantics: the module-level switch (``repro.obs.disable``)
turns *non-vital* instruments into no-ops — spans, kernel-launch mirrors,
attribution — bounding observability overhead. Instruments created with
``vital=True`` always record: they back public counter APIs
(``CacheStats``, ``GNNServer.stats``, ``PrefetchPipeline.stats``,
``Trainer.traces``) whose correctness tests don't depend on telemetry
being switched on.

Snapshot / delta: ``snapshot()`` returns a list of plain-dict series
(JSON-ready); ``delta(prev)`` subtracts a previous snapshot from the
current one (counters and histogram count/sum), which is how a caller
measures one window of a shared process-global registry.
"""
from __future__ import annotations

import collections
import itertools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry",
           "get_registry", "next_id", "DEFAULT_LATENCY_BUCKETS_S"]

# observability switch — flipped by repro.obs.enable()/disable(); read
# here so the per-call guard is one module-global load
_ENABLED = True


def _set_enabled(flag: bool) -> None:
    global _ENABLED
    _ENABLED = bool(flag)


def _is_enabled() -> bool:
    return _ENABLED


# pow-4-ish ladder from 10µs to ~100s — wide enough for interpret-mode
# CPU kernels and real serving latencies alike
DEFAULT_LATENCY_BUCKETS_S = (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0,
                             30.0, 120.0)

_DEFAULT_WINDOW = 4096          # raw samples retained per histogram series


class _HistSeries:
    """One labeled histogram series: bucket counts + raw-sample window."""

    __slots__ = ("buckets", "counts", "sum", "count", "samples")

    def __init__(self, buckets: Tuple[float, ...], window: int):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)      # +inf overflow bucket
        self.sum = 0.0
        self.count = 0
        self.samples: collections.deque = collections.deque(maxlen=window)

    def observe(self, v: float) -> None:
        i = 0
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.sum += v
        self.count += 1
        self.samples.append(v)

    def percentile(self, q: float) -> float:
        """Exact q-th percentile (0..100) over the retained window."""
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        # nearest-rank on the retained window: exact, no interpolation
        rank = max(int(len(s) * q / 100.0 + 0.5), 1)
        return s[min(rank, len(s)) - 1]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class Metric:
    """One named instrument; holds every labeled series under it."""

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 labelnames: Tuple[str, ...], help: str, *,
                 buckets: Optional[Tuple[float, ...]] = None,
                 window: int = _DEFAULT_WINDOW, vital: bool = False):
        self.registry = registry
        self.name = name
        self.kind = kind                  # counter | gauge | histogram
        self.labelnames = tuple(labelnames)
        self.help = help
        self.vital = bool(vital)
        self.buckets = tuple(buckets) if buckets else \
            (DEFAULT_LATENCY_BUCKETS_S if kind == "histogram" else None)
        self.window = int(window)
        self._series: Dict[Tuple, object] = {}

    # -- series addressing ---------------------------------------------------
    def _key(self, labels: Dict[str, str]) -> Tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def _num(self, key: Tuple) -> List[float]:
        cell = self._series.get(key)
        if cell is None:
            cell = self._series[key] = [0.0]
        return cell

    def _hist(self, key: Tuple) -> _HistSeries:
        cell = self._series.get(key)
        if cell is None:
            cell = self._series[key] = _HistSeries(self.buckets, self.window)
        return cell

    def _on(self) -> bool:
        return self.vital or _ENABLED

    # -- counter / gauge -----------------------------------------------------
    def inc(self, n: float = 1.0, **labels) -> None:
        if not self._on():
            return
        key = self._key(labels)
        with self.registry._lock:
            self._num(key)[0] += n

    def set(self, v: float, **labels) -> None:
        if not self._on():
            return
        key = self._key(labels)
        with self.registry._lock:
            self._num(key)[0] = float(v)

    add = inc                             # gauge alias

    def value(self, **labels) -> float:
        key = self._key(labels)
        with self.registry._lock:
            cell = self._series.get(key)
            return float(cell[0]) if cell is not None else 0.0

    def touch(self, **labels) -> None:
        """Materialize a labeled series at its zero value, so it exports
        before (or without) a first event — a zero counter is data."""
        if not self._on():
            return
        key = self._key(labels)
        with self.registry._lock:
            if self.kind == "histogram":
                self._hist(key)
            else:
                self._num(key)

    # -- histogram -----------------------------------------------------------
    def observe(self, v: float, **labels) -> None:
        if not self._on():
            return
        key = self._key(labels)
        with self.registry._lock:
            self._hist(key).observe(float(v))

    def series(self, **labels) -> Optional[_HistSeries]:
        key = self._key(labels)
        with self.registry._lock:
            return self._series.get(key)

    def count(self, **labels) -> int:
        s = self.series(**labels)
        return s.count if s is not None else 0

    def total(self, **labels) -> float:
        s = self.series(**labels)
        return s.sum if s is not None else 0.0

    def mean(self, **labels) -> float:
        s = self.series(**labels)
        return s.mean if s is not None else 0.0

    def percentile(self, q: float, **labels) -> float:
        s = self.series(**labels)
        return s.percentile(q) if s is not None else 0.0

    def samples(self, **labels) -> list:
        key = self._key(labels)
        with self.registry._lock:
            cell = self._series.get(key)
            return list(cell.samples) if cell is not None else []

    # -- lifecycle -----------------------------------------------------------
    def reset(self, **labels) -> None:
        """Zero one series (with labels) or every series (without)."""
        with self.registry._lock:
            if labels:
                self._series.pop(self._key(labels), None)
            else:
                self._series.clear()

    def series_items(self):
        """[(labels_dict, series_cell)] — snapshot helper."""
        with self.registry._lock:
            return [(dict(zip(self.labelnames, key)), cell)
                    for key, cell in self._series.items()]


class Counter(Metric):
    pass


class Gauge(Metric):
    pass


class Histogram(Metric):
    pass


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """The process-global instrument store (one per process by default —
    :func:`get_registry`). All mutation happens under one RLock; the
    per-event cost is a dict lookup + a float add."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: "collections.OrderedDict[str, Metric]" = \
            collections.OrderedDict()
        self._ids = itertools.count()

    # -- registration --------------------------------------------------------
    def _register(self, name: str, kind: str, labels: Sequence[str],
                  help: str, *, buckets=None, vital=False,
                  window=_DEFAULT_WINDOW) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind or m.labelnames != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}"
                        f"{m.labelnames}; asked for {kind}{tuple(labels)}")
                m.vital = m.vital or vital
                return m
            m = _KINDS[kind](self, name, kind, tuple(labels), help,
                             buckets=buckets, vital=vital, window=window)
            self._metrics[name] = m
            return m

    def counter(self, name: str, labels: Sequence[str] = (), help: str = "",
                *, vital: bool = False) -> Counter:
        return self._register(name, "counter", labels, help, vital=vital)

    def gauge(self, name: str, labels: Sequence[str] = (), help: str = "",
              *, vital: bool = False) -> Gauge:
        return self._register(name, "gauge", labels, help, vital=vital)

    def histogram(self, name: str, labels: Sequence[str] = (),
                  help: str = "", *, buckets=None, vital: bool = False,
                  window: int = _DEFAULT_WINDOW) -> Histogram:
        return self._register(name, "histogram", labels, help,
                              buckets=buckets, vital=vital, window=window)

    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return list(self._metrics)

    def schema(self) -> Dict[str, Tuple[str, ...]]:
        """name -> labelnames for every registered metric (the shape the
        schema-stability test pins)."""
        with self._lock:
            return {n: m.labelnames for n, m in self._metrics.items()}

    def next_id(self, prefix: str) -> str:
        """Process-unique instance label ('engine0', 'cache3', ...)."""
        with self._lock:
            return f"{prefix}{next(self._ids)}"

    # -- snapshot / delta ----------------------------------------------------
    def snapshot(self) -> List[dict]:
        """Every series as a plain JSON-ready dict."""
        out = []
        with self._lock:
            for name, m in self._metrics.items():
                for labels, cell in m.series_items():
                    row = {"name": name, "type": m.kind, "labels": labels}
                    if m.kind == "histogram":
                        row.update(
                            count=cell.count, sum=cell.sum,
                            mean=cell.mean,
                            p50=cell.percentile(50),
                            p95=cell.percentile(95),
                            p99=cell.percentile(99),
                            buckets=[[edge, c] for edge, c in
                                     zip(list(m.buckets) + ["+Inf"],
                                         cell.counts)])
                    else:
                        row["value"] = cell[0]
                    out.append(row)
        return out

    def delta(self, prev: List[dict]) -> List[dict]:
        """Current snapshot minus ``prev`` (counters and histogram
        count/sum; gauges report their current value). Series absent from
        ``prev`` are reported whole."""
        base = {(r["name"], tuple(sorted(r["labels"].items()))): r
                for r in prev}
        out = []
        for row in self.snapshot():
            key = (row["name"], tuple(sorted(row["labels"].items())))
            old = base.get(key)
            row = dict(row)
            if old is not None:
                if row["type"] == "counter":
                    row["value"] = row["value"] - old.get("value", 0.0)
                elif row["type"] == "histogram":
                    row["count"] = row["count"] - old.get("count", 0)
                    row["sum"] = row["sum"] - old.get("sum", 0.0)
                    row.pop("buckets", None)  # deltas of buckets: unused
            out.append(row)
        return out

    def reset(self) -> None:
        """Zero every series; instruments stay registered (long-lived
        holders keep valid handles)."""
        with self._lock:
            for m in self._metrics.values():
                m._series.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def next_id(prefix: str) -> str:
    return _REGISTRY.next_id(prefix)
