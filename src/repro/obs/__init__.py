"""repro.obs — the unified telemetry subsystem (``docs/observability.md``).

Three pillars, zero dependencies beyond the stdlib:

  * **metrics registry** (:mod:`repro.obs.registry`) — thread-safe
    Counter/Gauge/Histogram with labels; exact p50/p95/p99 over a bounded
    sample window; snapshot + delta; JSON-lines and Prometheus export
    (:mod:`repro.obs.export`).
  * **tracing spans** (:mod:`repro.obs.trace`) — ``span("serve.step")``
    context managers building per-request / per-step span trees across
    sample → pad → plan_cache → stamp → device_put → compile → execute,
    with a ring-buffer trace log and Chrome ``trace_event`` export.
  * **attribution hooks** (:mod:`repro.obs.hooks`) — every jit trace,
    plan-cache miss, PerfDB tune, and bucket probe records a structured
    cause, so ``why_compiled()`` answers "why did step 37 compile?".

The pre-existing counter APIs (``fusion_counts``, ``CacheStats``,
``GNNServer.stats``, ``PrefetchPipeline.stats``, ``Trainer.traces``) are
views over this registry — their instruments are *vital* and keep
counting even when :func:`disable` switches the optional instrumentation
(spans, launch mirrors, attribution) off. Nothing here ever runs inside
a traced function: instrumentation is host-side only.

Environment:

  * ``REPRO_OBS=0``            — start disabled (overhead ≈ flag checks)
  * ``REPRO_METRICS_PATH``     — periodic + at-exit JSON-lines flush
  * ``REPRO_METRICS_EVERY_S``  — flush period (default 30)
  * ``REPRO_TRACE_PATH``       — Chrome trace JSON written at exit
"""
from __future__ import annotations

import atexit
import os

from repro.obs import export, hooks, registry, trace
from repro.obs.export import (start_flusher, stop_flusher, to_jsonl,
                              to_prometheus, write_jsonl, write_prometheus)
from repro.obs.hooks import (attributions, record_cache_event,
                             record_compile, record_probe, record_tune,
                             reset_events, why_compiled)
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                get_registry, next_id)
from repro.obs.trace import (Span, chrome_trace, current_span, reset_spans,
                             span, spans, write_chrome_trace)

__all__ = [
    "registry", "trace", "hooks", "export",
    # registry
    "get_registry", "next_id", "Counter", "Gauge", "Histogram",
    "MetricsRegistry",
    # spans
    "span", "spans", "current_span", "reset_spans", "Span",
    "chrome_trace", "write_chrome_trace",
    # attribution
    "record_compile", "record_cache_event", "record_tune", "record_probe",
    "attributions", "why_compiled", "reset_events",
    # export
    "to_jsonl", "write_jsonl", "to_prometheus", "write_prometheus",
    "start_flusher", "stop_flusher",
    # switch + summaries
    "enable", "disable", "enabled", "report", "reset", "OBS_SCHEMA",
]


# ---------------------------------------------------------------------------
# the documented metric schema — renames break this table first
# (tests/test_obs.py pins it; dashboards and check_metrics.py read it)
# ---------------------------------------------------------------------------

OBS_SCHEMA = {
    # kernel launch accounting (trace-time, mirrors fusion_counts)
    "kernel.launches":            ("kind", "op"),
    # serving engine (one label value per GNNServer instance)
    "serve.requests":             ("engine",),
    "serve.batches":              ("engine",),
    "serve.serve_s":              ("engine",),
    "serve.compiles":             ("engine",),
    "serve.request_latency_s":    ("engine",),
    "serve.queue_s":              ("engine",),
    "serve.pad_node_frac":        ("engine",),
    "serve.pad_edge_frac":        ("engine",),
    # batcher admission
    "serve.submitted":            ("batcher",),
    "serve.queue_depth":          ("batcher",),
    # plan/executable cache (one label value per PlanCache instance)
    "serve.plan_cache.hits":         ("cache",),
    "serve.plan_cache.misses":       ("cache",),
    "serve.plan_cache.evictions":    ("cache",),
    "serve.plan_cache.prefills":     ("cache",),
    "serve.plan_cache.plan_builds":  ("cache",),
    "serve.plan_cache.compiles":     ("cache",),
    "serve.plan_cache.plan_build_s": ("cache",),
    "serve.plan_cache.compile_s":    ("cache",),
    # out-of-core pipeline (one label value per PrefetchPipeline)
    "pipeline.batches":           ("pipeline",),
    "pipeline.sync_falls":        ("pipeline",),
    "pipeline.wait_s":            ("pipeline",),
    "pipeline.produce_s":         ("pipeline",),
    # trainer (one label value per Trainer instance)
    "train.steps":                ("trainer",),
    "train.traces":               ("trainer",),
    # attribution counters
    "compile.events":             ("site", "cause"),
    "autotune.tunes":             ("op", "outcome"),
}


# ---------------------------------------------------------------------------
# switch
# ---------------------------------------------------------------------------

def enable() -> None:
    """Switch the optional instrumentation (spans, launch mirrors,
    attribution events) on. Vital counters always count."""
    registry._set_enabled(True)


def disable() -> None:
    """Switch the optional instrumentation off; per-call cost drops to a
    flag check. The public counter APIs keep working (vital)."""
    registry._set_enabled(False)


def enabled() -> bool:
    return registry._is_enabled()


def reset() -> None:
    """Zero metrics, drop spans and attribution events. Registered
    instruments keep their handles (safe for live engines)."""
    get_registry().reset()
    reset_spans()
    reset_events()


# ---------------------------------------------------------------------------
# human summary
# ---------------------------------------------------------------------------

def report() -> str:
    """A human-readable telemetry summary: counters grouped by prefix,
    histogram quantiles, and the most recent compile attributions."""
    reg = get_registry()
    lines = ["== repro.obs report =="]
    snap = reg.snapshot()
    by_prefix: dict = {}
    for row in snap:
        by_prefix.setdefault(row["name"].split(".")[0], []).append(row)
    for prefix in sorted(by_prefix):
        lines.append(f"[{prefix}]")
        for row in by_prefix[prefix]:
            lab = ",".join(f"{k}={v}" for k, v in row["labels"].items())
            lab = f"{{{lab}}}" if lab else ""
            if row["type"] == "histogram":
                lines.append(
                    f"  {row['name']}{lab}  n={row['count']} "
                    f"mean={row['mean']:.6f} p50={row['p50']:.6f} "
                    f"p95={row['p95']:.6f} p99={row['p99']:.6f}")
            else:
                v = row["value"]
                v = int(v) if float(v).is_integer() else v
                lines.append(f"  {row['name']}{lab} = {v}")
    compiles = why_compiled()
    if compiles:
        lines.append(f"[attribution] {len(compiles)} compiles recorded; "
                     "most recent:")
        for e in compiles[-8:]:
            detail = {k: v for k, v in e.items()
                      if k not in ("kind", "site", "cause", "t_s")}
            lines.append(f"  {e['site']} <- {e['cause']} {detail}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# environment wiring
# ---------------------------------------------------------------------------

if os.environ.get("REPRO_OBS", "1") in ("0", "false", "False"):
    disable()

_METRICS_PATH = os.environ.get("REPRO_METRICS_PATH")
if _METRICS_PATH:
    start_flusher(_METRICS_PATH,
                  float(os.environ.get("REPRO_METRICS_EVERY_S", "30")))
    atexit.register(stop_flusher)

_TRACE_PATH = os.environ.get("REPRO_TRACE_PATH")
if _TRACE_PATH:
    atexit.register(lambda: write_chrome_trace(_TRACE_PATH))
