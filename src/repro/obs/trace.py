"""Tracing spans: per-request / per-step span trees with a ring-buffer
trace log and Chrome ``trace_event`` export.

``span("serve.step", bucket=str(b))`` opens a timed stage; nested
``span(...)`` calls in the same thread/context attach as children, so one
serving request or training step yields one tree covering its stages
(sample → pad → plan_cache → stamp → device_put → compile → execute; the
taxonomy table lives in ``docs/observability.md``). Completed **root**
spans land in a bounded ring buffer (old traces fall off; memory is
bounded by construction).

Context propagation uses :mod:`contextvars`: threads have independent
span stacks, so a prefetch producer's ``pipeline.produce`` tree never
interleaves with the consumer's ``serve.step`` tree — each thread's
roots enter the ring independently.

Export: :func:`chrome_trace` renders the ring as Chrome
``trace_event`` JSON ("X" complete events, µs timestamps relative to
process start) loadable in ``chrome://tracing`` / Perfetto;
:func:`write_chrome_trace` writes it to disk (also wired to
``REPRO_TRACE_PATH`` at process exit by :mod:`repro.obs`).

Disabled mode (``repro.obs.disable()``): ``span`` yields a shared no-op
span and records nothing — the per-call cost is one flag check.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import json
import os
import threading
import time
from typing import Dict, List, Optional

from repro.obs import registry as _registry

__all__ = ["Span", "span", "current_span", "spans", "reset_spans",
           "chrome_trace", "write_chrome_trace"]

_T0 = time.perf_counter()         # process-relative timestamp origin

_RING_CAP = int(os.environ.get("REPRO_TRACE_RING", "512"))
_RING: collections.deque = collections.deque(maxlen=_RING_CAP)
_RING_LOCK = threading.Lock()

_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_span", default=None)


class Span:
    """One timed stage. ``attrs`` carry structured context (bucket, step,
    cause, ...); ``children`` make the tree."""

    __slots__ = ("name", "attrs", "t0", "dur_s", "children", "tid",
                 "thread")

    def __init__(self, name: str, attrs: Dict):
        self.name = name
        self.attrs = attrs
        self.t0 = time.perf_counter() - _T0
        self.dur_s = 0.0
        self.children: List["Span"] = []
        self.tid = threading.get_ident()
        self.thread = threading.current_thread().name

    def set(self, **attrs) -> None:
        """Attach attributes mid-span (e.g. the bucket once known)."""
        self.attrs.update(attrs)

    # -- tree queries --------------------------------------------------------
    def find(self, name: str) -> Optional["Span"]:
        """First span named ``name`` in this subtree (depth-first)."""
        if self.name == name:
            return self
        for c in self.children:
            hit = c.find(name)
            if hit is not None:
                return hit
        return None

    def stages(self) -> set:
        """Every span name in this subtree."""
        out = {self.name}
        for c in self.children:
            out |= c.stages()
        return out

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def as_dict(self) -> dict:
        return {"name": self.name, "t0_s": self.t0, "dur_s": self.dur_s,
                "attrs": dict(self.attrs), "thread": self.thread,
                "children": [c.as_dict() for c in self.children]}

    def __repr__(self):
        return (f"Span({self.name!r}, {self.dur_s * 1e3:.2f}ms, "
                f"{len(self.children)} children)")


class _NullSpan:
    """Shared no-op span for disabled mode."""

    name = None
    attrs: Dict = {}
    children: List = []

    def set(self, **attrs) -> None:
        pass

    def find(self, name):
        return None

    def stages(self):
        return set()


_NULL = _NullSpan()


@contextlib.contextmanager
def span(name: str, **attrs):
    """Open one timed stage; yields the live :class:`Span` (a shared
    no-op when observability is disabled)."""
    if not _registry._is_enabled():
        yield _NULL
        return
    s = Span(name, attrs)
    parent = _CURRENT.get()
    token = _CURRENT.set(s)
    try:
        yield s
    finally:
        s.dur_s = (time.perf_counter() - _T0) - s.t0
        _CURRENT.reset(token)
        if parent is not None:
            parent.children.append(s)
        else:
            with _RING_LOCK:
                _RING.append(s)


def current_span() -> Optional[Span]:
    return _CURRENT.get()


def spans(name: Optional[str] = None) -> List[Span]:
    """Completed root spans in the ring (oldest first); ``name`` filters
    by root-span name."""
    with _RING_LOCK:
        roots = list(_RING)
    if name is not None:
        roots = [r for r in roots if r.name == name]
    return roots


def reset_spans() -> None:
    with _RING_LOCK:
        _RING.clear()


# ---------------------------------------------------------------------------
# Chrome trace_event export
# ---------------------------------------------------------------------------

def chrome_trace(roots: Optional[List[Span]] = None) -> dict:
    """The ring (or ``roots``) as a Chrome ``trace_event`` document:
    one "X" (complete) event per span, µs timestamps relative to process
    start, thread ids preserved so producer/consumer lanes separate."""
    if roots is None:
        roots = spans()
    events = []
    for root in roots:
        for s in root.walk():
            args = {k: (v if isinstance(v, (int, float, bool, str))
                        or v is None else str(v))
                    for k, v in s.attrs.items()}
            events.append({
                "name": s.name, "ph": "X", "cat": "repro",
                "ts": s.t0 * 1e6, "dur": s.dur_s * 1e6,
                "pid": os.getpid(), "tid": s.tid, "args": args,
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       roots: Optional[List[Span]] = None) -> str:
    doc = chrome_trace(roots)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
