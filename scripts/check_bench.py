#!/usr/bin/env python
"""CI perf-regression gate: compare a fresh BENCH_*.json against the committed baseline.

The smoke benchmark rows (wall-clock µs per case) are matched by name; the gate fails when
the **geomean** slowdown across common cases exceeds the threshold (default 1.25, i.e. >25%
— wide enough for runner-to-runner noise, tight enough to catch a real hot-path regression).
Rows with ``us <= 0`` are metadata (geomeans, cache counters) and are skipped.

Usage:
    python scripts/check_bench.py \
        [--fresh BENCH_segment_reduce.json] \
        [--baseline benchmarks/baseline/BENCH_segment_reduce.json] \
        [--threshold 1.25]

Exit status: 0 = pass, 1 = regression or unusable inputs.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

REFRESH_HINT = (
    "PYTHONPATH=src python -m benchmarks.bench_segment_reduce --smoke "
    "&& cp BENCH_segment_reduce.json benchmarks/baseline/ "
    "&& PYTHONPATH=src python -m benchmarks.bench_segment_reduce "
    "--ablation --ablation-smoke --json BENCH_ablation.json "
    "&& cp BENCH_ablation.json benchmarks/baseline/"
)


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows", [])
    return {r["name"]: float(r["us"]) for r in rows if float(r.get("us", 0.0)) > 0.0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--fresh",
        default="BENCH_segment_reduce.json",
        help="artifact from the current run",
    )
    ap.add_argument(
        "--baseline",
        default="benchmarks/baseline/BENCH_segment_reduce.json",
        help="committed reference artifact",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=float(os.environ.get("REPRO_BENCH_GATE_TOL", "1.25")),
        help="max allowed geomean slowdown (fresh/baseline)",
    )
    ap.add_argument(
        "--require-rows",
        nargs="+",
        default=[],
        metavar="NAME",
        help="row names (exact) that MUST be present in the fresh artifact — "
        "guards against a smoke section silently disappearing (e.g. the "
        "precision or fused-launch rows) while the geomean still passes",
    )
    ap.add_argument(
        "--json-out",
        default=None,
        metavar="PATH",
        help="write a machine-readable per-row delta report (JSON) here — "
        "the CI artifact dashboards diff across runs",
    )
    args = ap.parse_args(argv)

    try:
        base = load_rows(args.baseline)
        fresh = load_rows(args.fresh)
    except (OSError, ValueError) as exc:
        print(f"check_bench: cannot load artifacts: {exc}", file=sys.stderr)
        return 1

    missing = [n for n in args.require_rows if n not in fresh]
    if missing:
        print(
            f"check_bench: required rows missing from {args.fresh}: "
            f"{', '.join(missing)}",
            file=sys.stderr,
        )
        return 1

    common = sorted(set(base) & set(fresh))
    if not common:
        print(
            f"check_bench: no common rows between {args.baseline} and {args.fresh}",
            file=sys.stderr,
        )
        return 1
    for name in sorted(set(base) - set(fresh)):
        print(f"  warning: row {name!r} in baseline only (renamed case?)")
    for name in sorted(set(fresh) - set(base)):
        print(f"  warning: row {name!r} in fresh run only (refresh the baseline to gate it)")

    width = max(len(n) for n in common)
    print(f"{'case':<{width}}  {'baseline_us':>12}  {'fresh_us':>12}  {'ratio':>7}")
    ratios = []
    for name in common:
        r = fresh[name] / base[name]
        ratios.append(r)
        flag = "  <-- slow" if r > args.threshold else ""
        print(f"{name:<{width}}  {base[name]:>12.1f}  {fresh[name]:>12.1f}  {r:>6.2f}x{flag}")

    geomean = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    verdict = "PASS" if geomean <= args.threshold else "FAIL"
    if args.json_out:
        report = {
            "baseline": args.baseline,
            "fresh": args.fresh,
            "threshold": args.threshold,
            "geomean": geomean,
            "verdict": verdict,
            "rows": [
                {
                    "name": name,
                    "baseline_us": base[name],
                    "fresh_us": fresh[name],
                    "ratio": fresh[name] / base[name],
                    "over_threshold": fresh[name] / base[name] > args.threshold,
                }
                for name in common
            ],
            "baseline_only": sorted(set(base) - set(fresh)),
            "fresh_only": sorted(set(fresh) - set(base)),
        }
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"per-row delta report written to {args.json_out}")
    print(
        f"\ngeomean slowdown: {geomean:.3f}x over {len(ratios)} cases "
        f"(threshold {args.threshold:.2f}x) -> {verdict}"
    )
    if verdict == "FAIL":
        pct = (args.threshold - 1) * 100
        print(
            f"perf gate failed: fresh run is >{pct:.0f}% slower on geomean than "
            f"{args.baseline}. If this is an intentional trade-off, regenerate the "
            f"baseline with:\n  {REFRESH_HINT}",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
