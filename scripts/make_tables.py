"""Generate the EXPERIMENTS.md tables from results/ JSON artifacts."""
import json
import pathlib
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro import configs as cfglib                     # noqa: E402
from repro.configs import shapes as shapelib            # noqa: E402
from benchmarks import roofline                         # noqa: E402

R = pathlib.Path("results")


def dryrun_table():
    print("| arch | shape | mesh | params | compile_s | param B/dev | "
          "HLO flops/dev | coll B/dev | temp B/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in cfglib.ARCH_NAMES:
        for s in shapelib.SHAPE_NAMES:
            for m in ("single", "multi"):
                f = R / "dryrun" / f"{a}__{s}__{m}.json"
                if not f.exists():
                    continue
                d = json.loads(f.read_text())
                if d.get("status") == "skipped":
                    if m == "single":
                        print(f"| {a} | {s} | — | — | SKIP: sub-quadratic-"
                              f"attention arch required | | | | |")
                    continue
                if d.get("status") != "ok":
                    print(f"| {a} | {s} | {m} | ERROR | | | | | |")
                    continue
                ma = d.get("memory_analysis", {})
                print(f"| {a} | {s} | {m} | {d['num_params']/1e9:.2f}B "
                      f"| {d['compile_s']} | {d['param_bytes_per_device']/1e6:.0f}M "
                      f"| {d['cost_analysis'].get('flops', 0):.2e} "
                      f"| {d['collectives']['total_bytes']:.2e} "
                      f"| {ma.get('temp_size_in_bytes', 0):.2e} |")


def roofline_table():
    rows = []
    for a in cfglib.ARCH_NAMES:
        cfg = cfglib.get_config(a)
        for s in shapelib.SHAPE_NAMES:
            if shapelib.cell_applicable(cfg, s):
                continue
            r = roofline.roofline_row(a, s)
            if r:
                rows.append(r)
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck "
          "| MODEL_FLOPS/chip | MODEL/HLO | MFU bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
              f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
              f"| **{r['bottleneck']}** | {r['model_flops']:.2e} "
              f"| {r['model_over_hlo']:.2f} | {r['mfu_bound']:.3f} |")
    return rows


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run table\n")
        dryrun_table()
        print()
    if which in ("all", "roofline"):
        print("### Roofline table\n")
        roofline_table()
