#!/usr/bin/env python
"""CI observability gate: validate a flushed repro.obs telemetry dump.

Checks (all must pass):

  * the JSON-lines metrics file parses line-by-line and contains at least
    one ``{"record": "metric"}`` row and the trailing ``{"record": "meta"}``
    stamp;
  * every ``--require-metrics`` name is present among the metric rows, and
    every metric row's name is in the documented schema
    (``repro.obs.OBS_SCHEMA``) with exactly the documented label set;
  * (optional, ``--trace``) the Chrome trace file is valid ``trace_event``
    JSON — a ``traceEvents`` list of complete ("ph": "X") events with
    numeric ``ts``/``dur`` — and names every ``--require-stages`` stage.

Usage:
    python scripts/check_metrics.py metrics.jsonl \
        [--require-metrics serve.requests serve.plan_cache.hits ...] \
        [--trace trace.json] [--require-stages serve.step serve.execute ...]

Exit status: 0 = pass, 1 = malformed dump / missing names.
"""

from __future__ import annotations

import argparse
import json
import sys

DEFAULT_REQUIRED = [
    "serve.requests",
    "serve.batches",
    "serve.compiles",
    "serve.request_latency_s",
    "serve.plan_cache.hits",
    "serve.plan_cache.misses",
    "kernel.launches",
    "compile.events",
]


def check_metrics(path: str, required: list) -> list:
    errors = []
    metric_names = set()
    records = {"metric": 0, "event": 0, "meta": 0}
    try:
        with open(path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
    except OSError as exc:
        return [f"cannot read {path}: {exc}"]
    if not lines:
        return [f"{path} is empty"]

    rows = []
    for i, ln in enumerate(lines, 1):
        try:
            rows.append(json.loads(ln))
        except ValueError as exc:
            errors.append(f"{path}:{i}: not valid JSON ({exc})")
    for row in rows:
        kind = row.get("record")
        if kind not in records:
            errors.append(f"unknown record kind {kind!r}")
            continue
        records[kind] += 1
        if kind == "metric":
            metric_names.add(row.get("name", ""))

    if not records["metric"]:
        errors.append(f"{path}: no metric records")
    if not records["meta"]:
        errors.append(f"{path}: missing trailing meta record")

    missing = [n for n in required if n not in metric_names]
    if missing:
        errors.append(f"required metrics missing: {', '.join(missing)}")

    # every exported name/label set must match the documented schema
    try:
        from repro.obs import OBS_SCHEMA
    except ImportError:
        OBS_SCHEMA = None
        print("  warning: repro.obs not importable; skipping schema check")
    if OBS_SCHEMA is not None:
        for row in rows:
            if row.get("record") != "metric":
                continue
            name = row.get("name", "")
            if name not in OBS_SCHEMA:
                errors.append(f"metric {name!r} not in OBS_SCHEMA "
                              "(undocumented metric exported)")
            elif set(row.get("labels", {})) != set(OBS_SCHEMA[name]):
                errors.append(
                    f"metric {name!r} labels {sorted(row.get('labels', {}))} "
                    f"!= documented {sorted(OBS_SCHEMA[name])}")

    print(f"{path}: {records['metric']} metric rows "
          f"({len(metric_names)} names), {records['event']} events")
    return errors


def check_trace(path: str, require_stages: list) -> list:
    errors = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as exc:
        return [f"cannot load trace {path}: {exc}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents"]
    names = set()
    for e in events:
        if e.get("ph") != "X":
            errors.append(f"trace event {e.get('name')!r}: ph != 'X'")
            continue
        if not isinstance(e.get("ts"), (int, float)) or \
                not isinstance(e.get("dur"), (int, float)):
            errors.append(f"trace event {e.get('name')!r}: "
                          "non-numeric ts/dur")
        names.add(e.get("name", ""))
    missing = [s for s in require_stages if s not in names]
    if missing:
        errors.append(f"trace stages missing: {', '.join(missing)} "
                      f"(saw {sorted(names)})")
    print(f"{path}: {len(events)} trace events, stages {sorted(names)}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("metrics", help="JSON-lines dump (REPRO_METRICS_PATH)")
    ap.add_argument("--require-metrics", nargs="+", default=DEFAULT_REQUIRED,
                    metavar="NAME", help="metric names that must be present")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace JSON (REPRO_TRACE_PATH) to validate")
    ap.add_argument("--require-stages", nargs="+", default=[],
                    metavar="STAGE",
                    help="span names the trace must contain")
    args = ap.parse_args(argv)

    errors = check_metrics(args.metrics, args.require_metrics)
    if args.trace:
        errors += check_trace(args.trace, args.require_stages)

    for e in errors:
        print(f"check_metrics: {e}", file=sys.stderr)
    print("check_metrics:", "PASS" if not errors else "FAIL")
    return 0 if not errors else 1


if __name__ == "__main__":
    sys.exit(main())
