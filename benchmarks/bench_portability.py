"""Fig. 9 — portability of the data-aware rules across hardware.

The paper trains the performance database on A100 and shows the rules hold
up on H100 / RTX 3090Ti. Our TPU analogue: the committed rules are fitted
under the v5e cost model; here we re-evaluate the *same* rule-selected
configs under v4 and v5p hardware constants and compare against each
generation's exhaustive best — the retention ratio is the portability
metric (paper: "consistent speedup across architectures").
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, geomean
from repro.core import costmodel
from repro.core.config_space import all_configs
from repro.core.costmodel import TpuSpec
from repro.core.heuristics import select_config
from repro.core.perfdb import TABLE_II

GENERATIONS = {
    "v5e": costmodel.V5E,
    "v4": TpuSpec(name="tpu_v4", peak_flops_bf16=275e12,
                  peak_flops_fp32=137.5e12, hbm_bw=1228e9,
                  vpu_flops=4 * 8 * 128 * 1.05e9, ici_bw=50e9,
                  clock=1.05e9),
    "v5p": TpuSpec(name="tpu_v5p", peak_flops_bf16=459e12,
                   peak_flops_fp32=229.5e12, hbm_bw=2765e9,
                   vpu_flops=4 * 8 * 128 * 1.75e9, ici_bw=100e9,
                   clock=1.75e9),
}

FEATS = [1, 16, 64]


def _gflops(m, v, f, cfg, spec):
    cost = costmodel.segment_reduce_cost(m, v, f, cfg, spec=spec)
    return cost.gflops(costmodel.useful_flops(m, f))


def run(quick: bool = False):
    table = TABLE_II[:4] if quick else TABLE_II
    feats = [1, 64] if quick else FEATS
    for gen, spec in GENERATIONS.items():
        ratios = []
        for name, v, m in table:
            for f in feats:
                cfg = select_config(m, v, f)        # v5e-trained rules
                ours = _gflops(m, v, f, cfg, spec)
                best = max(_gflops(m, v, f, c, spec) for c in all_configs(f))
                ratios.append(ours / best)
        emit(f"fig9/{gen}/rules_vs_native_best", 0.0,
             f"{geomean(ratios):.3f}")


if __name__ == "__main__":
    run()
