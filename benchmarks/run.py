"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit).

  fig6  — segment reduction vs scatter/segment_coo baselines (paper Fig. 6)
  fig7  — fused SpMM vs BCOO/unfused baselines (paper Fig. 7)
  fig8  — decision tree vs hand-crafted vs exhaustive best (paper Fig. 8)
  fig9  — rule portability across hardware generations (paper Fig. 9)
  fig10 — GCN aggregation time share (paper Fig. 10)
  fig11 — end-to-end 3-layer GNN inference (paper Fig. 11)
  roofline — §Roofline terms per (arch × shape) from the dry-run artifacts

REPRO_BENCH_QUICK=1 trims datasets/feature sweeps (CI-scale run).
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") not in ("0", "")
    from benchmarks import (bench_decision_tree, bench_end2end,
                            bench_portability, bench_segment_reduce,
                            bench_spmm, roofline)
    print("name,us_per_call,derived")
    bench_segment_reduce.run(quick=quick)
    bench_spmm.run(quick=quick)
    bench_decision_tree.run(quick=quick)
    bench_portability.run(quick=quick)
    bench_end2end.run(quick=quick)
    roofline.run(quick=quick)


if __name__ == "__main__":
    main()
