"""Fig. 11 — end-to-end GNN inference (3-layer GCN/GIN/GraphSAGE) and
Fig. 10 — CUDA-time-breakdown analogue (aggregation share of runtime).

Modes (paper §V-B4):
  dense  — PyG dense mode analogue: normalized dense adjacency matmul
  sparse — PyG sparse mode analogue: BCOO SpMM aggregation
  geot   — fused index_(weight_)segment_reduce aggregation (ours)

derived: speedup vs sparse | aggregation share (fig10).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

from benchmarks.common import emit, geomean, timeit
from repro.core.plan import make_graph_plan
from repro.data.graphs import dataset
from repro.models import gnn

DATASETS = {"flickr": 0.3, "ogbn-arxiv": 0.3, "reddit2": 0.03}
MODELS = ["gcn", "gin", "sage"]
HIDDEN = [32, 64]
REPS = 3


def _model_with_agg(model, params, agg_fn, x, num_nodes):
    """Run the 3-layer model with a pluggable aggregation implementation."""
    h = x
    for i, prm in enumerate(params):
        if model == "gcn":
            hw = h @ prm["w"].value
            h2 = agg_fn(hw, weighted=True) + prm["b"].value
        elif model == "gin":
            agg = agg_fn(h, weighted=False)
            z = (1.0 + prm["eps"].value) * h + agg
            z = jax.nn.relu(z @ prm["mlp1"].value + prm["b1"].value)
            h2 = z @ prm["mlp2"].value + prm["b2"].value
        else:
            agg = agg_fn(h, weighted=False, mean=True)
            h2 = (h @ prm["w_self"].value + agg @ prm["w_neigh"].value
                  + prm["b"].value)
        h = jax.nn.relu(h2) if i < len(params) - 1 else h2
    return h


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    datasets = dict(list(DATASETS.items())[:2]) if quick else DATASETS
    hidden = [32] if quick else HIDDEN
    sp_all = {"dense": [], "geot": []}
    for name, scale in datasets.items():
        g = dataset(name, feat=32, scale=scale)
        v, m = g.num_nodes, g.num_edges
        src = jnp.asarray(g.edge_index[0])
        dst = jnp.asarray(g.edge_index[1])
        dis = jnp.asarray(g.deg_inv_sqrt)
        w = dis[src] * dis[dst]
        coo = jsparse.BCOO((w, jnp.stack([dst, src], 1)), shape=(v, v))
        coo_u = jsparse.BCOO((jnp.ones_like(w), jnp.stack([dst, src], 1)),
                             shape=(v, v))
        deg = jnp.maximum(jax.ops.segment_sum(
            jnp.ones((m,)), dst, v, indices_are_sorted=True), 1.0)
        dense_a = None
        if v <= 20_000:      # PyG-dense analogue only where V² fits memory
            a = np.zeros((v, v), np.float32)
            np.add.at(a, (np.asarray(dst), np.asarray(src)),
                      np.asarray(w))
            dense_a = jnp.asarray(a)

        def agg_sparse(h, weighted=False, mean=False):
            y = (coo if weighted else coo_u) @ h
            return y / deg[:, None] if mean else y

        # one plan per graph, shared by every layer / model / hidden width
        plan = make_graph_plan(g.edge_index, v, feat=max(HIDDEN))

        def agg_geot(h, weighted=False, mean=False):
            from repro.core import ops
            if weighted:
                return ops.index_weight_segment_reduce(h, src, w, dst, v,
                                                       impl="blocked",
                                                       plan=plan)
            return ops.index_segment_reduce(
                h, src, dst, v, reduce="mean" if mean else "sum",
                impl="blocked" if not mean else "ref",
                plan=plan if not mean else None)

        def agg_dense(h, weighted=False, mean=False):
            y = dense_a @ h if weighted else (dense_a != 0) @ h
            return y / deg[:, None] if mean else y

        for model in MODELS:
            for hdim in hidden:
                params = gnn.init(jax.random.PRNGKey(0), model, 32, hdim, 16)
                x = jnp.asarray(rng.standard_normal((v, 32), np.float32))
                run_with = lambda agg: jax.jit(functools.partial(
                    _model_with_agg, model, params, agg, num_nodes=v))
                t_sparse = timeit(run_with(agg_sparse), x, reps=3)
                t_geot = timeit(run_with(agg_geot), x, reps=3)
                emit(f"fig11/{name}/{model}/H{hdim}/sparse", t_sparse, "1.00x")
                emit(f"fig11/{name}/{model}/H{hdim}/geot", t_geot,
                     f"{t_sparse / t_geot:.2f}x")
                sp_all["geot"].append(t_sparse / t_geot)
                if dense_a is not None:
                    t_dense = timeit(run_with(agg_dense), x, reps=3)
                    emit(f"fig11/{name}/{model}/H{hdim}/dense", t_dense,
                         f"{t_sparse / t_dense:.2f}x")
                    sp_all["dense"].append(t_sparse / t_dense)

                # Fig. 10 breakdown: aggregation share of total runtime,
                # timed at each layer's actual width (H, H, out-classes)
                if model == "gcn":
                    from repro.core import ops
                    widths = [hdim, hdim, 16]
                    t_sp = t_ge = 0.0
                    for width in widths:
                        hw = jnp.asarray(rng.standard_normal(
                            (v, width), np.float32))
                        t_sp += timeit(jax.jit(lambda h: coo @ h), hw,
                                       reps=3)
                        t_ge += timeit(jax.jit(
                            lambda h: ops.index_weight_segment_reduce(
                                h, src, w, dst, v, impl="blocked")), hw,
                            reps=3)
                    emit(f"fig10/{name}/H{hdim}/agg_share_sparse", t_sp,
                         f"{min(100.0, 100*t_sp/max(t_sparse,1e-9)):.1f}%")
                    emit(f"fig10/{name}/H{hdim}/agg_share_geot", t_ge,
                         f"{min(100.0, 100*t_ge/max(t_geot,1e-9)):.1f}%")
    emit("fig11/geomean_speedup_vs_sparse", 0.0,
         f"geot={geomean(sp_all['geot']):.2f}x")


if __name__ == "__main__":
    run()
