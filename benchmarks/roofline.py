"""§Roofline — three-term roofline per (arch × shape) from the compiled
dry-run (results/dryrun/*.json):

    compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory_s     = HLO_bytes_per_chip / HBM_bw
    collective_s = collective_bytes_per_chip / link_bw

Caveat handled here: XLA's cost analysis counts a while-loop body ONCE, so
scan-over-layers costs are under-reported by ~num_periods×. We correct by
**differencing**: each arch×shape is re-lowered with 1 and 2 scan periods
(scripts/run_roofline_diff.sh writes results/roofline_diff/*.json); the
difference isolates the per-period cost, and

    corrected = base_1p + (n_periods − 1) × (cell_2p − cell_1p)

MODEL_FLOPS uses 6·N·D (dense) / 6·N_active·D (MoE) + the attention
quadratic term; the ratio MODEL/HLO flags remat/redundancy waste.
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro import configs as cfglib
from repro.configs import shapes as shapelib
from repro.core.costmodel import V5E
from repro.models import lm

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"
CHIPS = {"single": 256, "multi": 512}


def model_flops_per_chip(arch: str, shape: str, chips: int) -> float:
    """Analytic useful FLOPs per chip per step (MFU denominator)."""
    cfg = cfglib.get_config(arch)
    cell = shapelib.SHAPES[shape]
    n_active = active_params(cfg)
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = b * s
        base = 6.0 * n_active * tokens
        attn = 6.0 * attn_layers(cfg) * cfg.num_heads * cfg.head_dim \
            * tokens * s            # causal ≈ S/2 keys ×2 matmuls ×3 f/b
    elif cell.kind == "prefill":
        tokens = b * s
        base = 2.0 * n_active * tokens
        attn = 2.0 * attn_layers(cfg) * cfg.num_heads * cfg.head_dim \
            * tokens * s
    else:  # decode: one token against an s-long cache
        tokens = b
        base = 2.0 * n_active * tokens
        attn = 4.0 * attn_layers(cfg) * cfg.num_heads * cfg.head_dim \
            * tokens * s
    return (base + attn) / chips


def attn_layers(cfg) -> int:
    return sum(1 for i in range(cfg.num_layers) if cfg.is_attn_layer(i))


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    d = cfg.d_model
    n = 2.0 * cfg.padded_vocab * d if not cfg.tie_embeddings \
        else cfg.padded_vocab * d
    per_expert = (3 if cfg.mlp_gated else 2) * d * (cfg.moe_d_ff or cfg.d_ff)
    for i in range(cfg.num_layers):
        if cfg.rwkv:
            n += 5 * d * d + 3 * d * cfg.d_ff
        elif cfg.is_attn_layer(i):
            n += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
        else:  # mamba
            di = cfg.expand * d
            n += 2 * d * di + di * d + di * (d // 16 + 2 * cfg.d_state)
        if not cfg.rwkv:
            if cfg.is_moe_layer(i):
                n += cfg.top_k * per_expert \
                    + cfg.num_shared_experts * per_expert + d * cfg.num_experts
            else:
                n += (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
    return float(n)


def corrected_terms(cell_json: dict, diff: dict | None):
    """Per-chip (flops, bytes, collective bytes).

    With differencing data: corrected = 1p + (n_periods − 1)·(2p − 1p),
    where the kp lowers are *unrolled* (fully counted). Without it, the raw
    full-cell numbers are returned (scan bodies counted once — a lower
    bound, flagged via `corrected=False`)."""
    flops = cell_json["cost_analysis"].get("flops", 0.0)
    byts = cell_json["cost_analysis"].get("bytes accessed", 0.0)
    coll = float(cell_json["collectives"]["total_bytes"])
    if diff and diff.get("status") == "ok":
        n_per = max(diff["n_periods_full"], 1)
        flops = diff["flops_1p"] + (n_per - 1) * max(
            diff["flops_2p"] - diff["flops_1p"], 0.0)
        byts = diff["bytes_1p"] + (n_per - 1) * max(
            diff["bytes_2p"] - diff["bytes_1p"], 0.0)
        coll = diff["coll_1p"] + (n_per - 1) * max(
            diff["coll_2p"] - diff["coll_1p"], 0.0)
    return flops, byts, coll


def load(arch, shape, mesh="single"):
    f = RESULTS / "dryrun" / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def load_diff(arch, shape, mesh="single"):
    f = RESULTS / "roofline_diff" / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def roofline_row(arch, shape, mesh="single", dtype_bytes=2):
    cell = load(arch, shape, mesh)
    if cell is None or cell.get("status") != "ok":
        return None
    diff = load_diff(arch, shape, mesh)
    flops, byts, coll = corrected_terms(cell, diff)
    peak = V5E.peak_flops_bf16 if dtype_bytes == 2 else V5E.peak_flops_fp32
    compute_s = flops / peak
    memory_s = byts / V5E.hbm_bw
    coll_s = coll / V5E.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mflops = model_flops_per_chip(arch, shape, CHIPS[mesh])
    total = max(compute_s, memory_s, coll_s)
    return {
        "arch": arch, "shape": shape, "mesh": mesh,
        "flops": flops, "bytes": byts, "coll_bytes": coll,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bottleneck": bottleneck,
        "model_flops": mflops,
        "model_over_hlo": mflops / max(flops, 1.0),
        "mfu_bound": mflops / peak / max(total, 1e-12),
        "corrected": bool(diff and diff.get("status") == "ok"),
    }


def run(quick: bool = False):
    from benchmarks.common import emit
    rows = []
    for arch in cfglib.ARCH_NAMES:
        cfg = cfglib.get_config(arch)
        for shape in shapelib.SHAPE_NAMES:
            if shapelib.cell_applicable(cfg, shape):
                continue
            r = roofline_row(arch, shape)
            if r is None:
                continue
            rows.append(r)
            emit(f"roofline/{arch}/{shape}", r["compute_s"] * 1e6,
                 f"mem={r['memory_s']*1e6:.0f}us|coll={r['collective_s']*1e6:.0f}us|"
                 f"bound={r['bottleneck']}|mfu_bound={r['mfu_bound']:.2f}|"
                 f"corr={int(r['corrected'])}")
    out = RESULTS / "roofline.json"
    out.write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    run()
