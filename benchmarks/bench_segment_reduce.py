"""Fig. 6 — segment reduction vs baselines across datasets × feature sizes.

Baselines (CPU/XLA analogues of the paper's):
  scatter     — unsorted scatter-add (torch/PyG ``scatter_reduce`` analogue)
  segment_coo — jax.ops.segment_sum with indices_are_sorted=True
                (PyG ``segment_coo`` analogue)
  geot        — GeoT blocked algorithm, decision-tree config (ours)
  geot_hand   — GeoT blocked, hand-crafted static rule (ablation input)

derived column: speedup_vs_scatter | cost-model v5e GFlops for the
tree-selected config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, geomean, timeit
from repro.core import costmodel, ops
from repro.core.heuristics import hand_crafted_config, select_config
from repro.data.graphs import dataset

# reddit2 excluded (paper §V-B: OOM in the original too); the two largest
# graphs are cost-model-only in the fig8/fig9 benches — XLA:CPU wall-clock
# on >1M-edge graphs adds minutes per op without changing the story
DATASETS = ["citeseer", "cora", "ppi", "pubmed", "amazon-photo", "flickr"]
FEATS = [1, 16, 32, 64]


def run(quick: bool = False):
    datasets = DATASETS[:4] if quick else DATASETS
    feats = [1, 32] if quick else FEATS  # reps kept low: timeit reps=3

    speedups = []
    for name in datasets:
        g = dataset(name, feat=1)
        dst = jnp.asarray(g.edge_index[1])
        m, v = g.num_edges, g.num_nodes
        for f in feats:
            x = jnp.asarray(
                np.random.default_rng(0).standard_normal((m, f), np.float32))

            scatter = jax.jit(
                lambda x: jnp.zeros((v, x.shape[1]), x.dtype).at[dst].add(x))
            coo = jax.jit(lambda x: jax.ops.segment_sum(
                x, dst, v, indices_are_sorted=True))
            cfg_tree = select_config(m, v, f)
            cfg_hand = hand_crafted_config(m, v, f)
            # CPU wall-clock runs the SR schedule (the PR one-hot matmul is
            # MXU-shaped — emulating it on CPU costs S_b× extra MACs); the
            # tree config still drives the v5e cost-model `derived` column.
            from repro.core.config_space import KernelConfig
            cpu = lambda c: KernelConfig("SR", c.s_b, c.n_b, c.m_b, 1)
            geot = jax.jit(lambda x: ops.segment_reduce(
                x, dst, v, "sum", "blocked", cpu(cfg_tree)))
            geot_hand = jax.jit(lambda x: ops.segment_reduce(
                x, dst, v, "sum", "blocked", cpu(cfg_hand)))

            t_scatter = timeit(scatter, x, reps=3)
            t_coo = timeit(coo, x, reps=3)
            t_geot = timeit(geot, x, reps=3)
            t_hand = timeit(geot_hand, x, reps=3)

            cost = costmodel.segment_reduce_cost(m, v, f, cfg_tree)
            gflops = cost.gflops(costmodel.useful_flops(m, f))
            sp = t_scatter / t_geot
            speedups.append(sp)
            emit(f"fig6/{name}/F{f}/scatter", t_scatter, "1.00x")
            emit(f"fig6/{name}/F{f}/segment_coo", t_coo,
                 f"{t_scatter / t_coo:.2f}x")
            emit(f"fig6/{name}/F{f}/geot", t_geot,
                 f"{sp:.2f}x|v5e_model={gflops:.1f}GFLOPs")
            emit(f"fig6/{name}/F{f}/geot_hand", t_hand,
                 f"{t_scatter / t_hand:.2f}x")
    emit("fig6/geomean_speedup_vs_scatter", 0.0, f"{geomean(speedups):.2f}x")


if __name__ == "__main__":
    run()
