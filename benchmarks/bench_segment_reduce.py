"""Fig. 6 — segment reduction vs baselines across datasets × feature sizes.

Baselines (CPU/XLA analogues of the paper's):
  scatter     — unsorted scatter-add (torch/PyG ``scatter_reduce`` analogue)
  segment_coo — jax.ops.segment_sum with indices_are_sorted=True
                (PyG ``segment_coo`` analogue)
  geot        — GeoT blocked algorithm, decision-tree config (ours)
  geot_hand   — GeoT blocked, hand-crafted static rule (ablation input)

derived column: speedup_vs_scatter | cost-model v5e GFlops for the
tree-selected config.

``geot_planned`` rows reuse a precomputed SegmentPlan (schedule metadata +
config built once per graph — the amortized hot path); CLI smoke mode
(``python benchmarks/bench_segment_reduce.py --smoke``) writes a
``BENCH_segment_reduce.json`` artifact for CI to upload.

``--ablation`` adds the paper's Fig. 8 selector comparison on the real
Pallas kernel: wall-clock-tuned config vs generated decision-tree rules vs
the hand-crafted static rule. All three are timed inside **one** autotuner
sweep (the tuner seeds its candidate list with both baseline configs), so
``tuned <= generated_rules <= …`` per case holds by construction whenever
the tuner's argmin is honest, and a warm PerfDB replays the whole table
with zero re-timings.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_rng, emit, geomean, timeit, write_json
from repro.core import costmodel, ops
from repro.core.heuristics import hand_crafted_config, select_config
from repro.core.plan import make_plan
from repro.data.graphs import dataset

# reddit2 excluded (paper §V-B: OOM in the original too); the two largest
# graphs are cost-model-only in the fig8/fig9 benches — XLA:CPU wall-clock
# on >1M-edge graphs adds minutes per op without changing the story
DATASETS = ["citeseer", "cora", "ppi", "pubmed", "amazon-photo", "flickr"]
FEATS = [1, 16, 32, 64]


def run(quick: bool = False):
    datasets = DATASETS[:4] if quick else DATASETS
    feats = [1, 32] if quick else FEATS  # reps kept low: timeit reps=3

    speedups = []
    for name in datasets:
        g = dataset(name, feat=1)
        dst = jnp.asarray(g.edge_index[1])
        m, v = g.num_edges, g.num_nodes
        for f in feats:
            x = jnp.asarray(
                bench_rng(0).standard_normal((m, f), np.float32))

            scatter = jax.jit(
                lambda x: jnp.zeros((v, x.shape[1]), x.dtype).at[dst].add(x))
            coo = jax.jit(lambda x: jax.ops.segment_sum(
                x, dst, v, indices_are_sorted=True))
            cfg_tree = select_config(m, v, f)
            cfg_hand = hand_crafted_config(m, v, f)
            # CPU wall-clock runs the SR schedule (the PR one-hot matmul is
            # MXU-shaped — emulating it on CPU costs S_b× extra MACs); the
            # tree config still drives the v5e cost-model `derived` column.
            from repro.core.config_space import KernelConfig

            def cpu(c):
                return KernelConfig("SR", c.s_b, c.n_b, c.m_b, 1)

            geot = jax.jit(lambda x: ops.segment_reduce(
                x, dst, v, "sum", "blocked", cpu(cfg_tree)))
            geot_hand = jax.jit(lambda x: ops.segment_reduce(
                x, dst, v, "sum", "blocked", cpu(cfg_hand)))

            t_scatter = timeit(scatter, x, reps=3)
            t_coo = timeit(coo, x, reps=3)
            t_geot = timeit(geot, x, reps=3)
            t_hand = timeit(geot_hand, x, reps=3)

            # plan build cost + the grid tightening the planned Pallas
            # kernel would get on this graph (the planned-vs-planless
            # *kernel* comparison itself lives in run_smoke — the blocked
            # XLA path consumes no grid, so timing it with a plan would
            # measure nothing plan-specific)
            t0 = time.perf_counter()
            plan = make_plan(np.asarray(dst), v, feat=f, config=cpu(cfg_tree))
            t_plan_build = (time.perf_counter() - t0) * 1e6

            cost = costmodel.segment_reduce_cost(m, v, f, cfg_tree)
            gflops = cost.gflops(costmodel.useful_flops(m, f))
            sp = t_scatter / t_geot
            speedups.append(sp)
            emit(f"fig6/{name}/F{f}/scatter", t_scatter, "1.00x")
            emit(f"fig6/{name}/F{f}/segment_coo", t_coo,
                 f"{t_scatter / t_coo:.2f}x")
            emit(f"fig6/{name}/F{f}/geot", t_geot,
                 f"{sp:.2f}x|v5e_model={gflops:.1f}GFLOPs")
            emit(f"fig6/{name}/F{f}/geot_hand", t_hand,
                 f"{t_scatter / t_hand:.2f}x")
            emit(f"fig6/{name}/F{f}/plan_build", t_plan_build,
                 f"grid={plan.max_chunks}/{plan.worst_case_chunks}"
                 f"|{plan.grid_savings:.1f}x_tighter")
    emit("fig6/geomean_speedup_vs_scatter", 0.0, f"{geomean(speedups):.2f}x")


def run_smoke():
    """CI-scale smoke: one small graph, planned Pallas (interpret) vs refs.

    Exercises the real kernel path — tight grid from the plan — at sizes
    where the interpreter stays in seconds, and records the plan's grid
    tightening so the CI artifact tracks it over time. Also times the
    fused-mean/max/softmax gather kernels (single launch each) and the
    mp_transform transform/aggregate reordering on a widening layer."""
    from repro.core.config_space import KernelConfig

    g = dataset("cora", feat=1, scale=0.25)
    dst = jnp.asarray(g.edge_index[1])
    m, v, f = g.num_edges, g.num_nodes, 16
    x = jnp.asarray(bench_rng(0).standard_normal((m, f), np.float32))
    cfg = KernelConfig("SR", 64, 128, 64, 1)
    plan = make_plan(g.edge_index[1], v, feat=f, config=cfg)

    coo = jax.jit(lambda x: jax.ops.segment_sum(
        x, dst, v, indices_are_sorted=True))
    blocked = jax.jit(lambda x: ops.segment_reduce(
        x, dst, v, "sum", "blocked", None, plan))
    pallas_planned = jax.jit(lambda x: ops.segment_reduce(
        x, dst, v, "sum", "pallas", None, plan))
    pallas_planless = jax.jit(lambda x: ops.segment_reduce(
        x, dst, v, "sum", "pallas", cfg))

    t_coo = timeit(coo, x, reps=3, warmup=1)
    t_blk = timeit(blocked, x, reps=3, warmup=1)
    t_pal = timeit(pallas_planned, x, reps=3, warmup=1)
    t_pll = timeit(pallas_planless, x, reps=3, warmup=1)
    emit("smoke/segment_coo", t_coo, "1.00x")
    emit("smoke/geot_blocked_planned", t_blk, f"{t_coo / t_blk:.2f}x")
    emit("smoke/geot_pallas_planned", t_pal,
         f"grid={plan.max_chunks}/{plan.worst_case_chunks}"
         f"|{plan.grid_savings:.1f}x_tighter")
    emit("smoke/geot_pallas_planless", t_pll,
         f"planned_speedup={t_pll / t_pal:.2f}x")

    # -- fused gather-path reduces (one launch each, plan-aware) ----------
    rng = bench_rng(1)
    h = jnp.asarray(rng.standard_normal((v, f), np.float32))
    src = jnp.asarray(g.edge_index[0])
    w = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    for red in ("mean", "max"):
        fused = jax.jit(lambda h, red=red: ops.index_segment_reduce(
            h, src, dst, v, red, "pallas", None, plan))
        t = timeit(fused, h, reps=3, warmup=1)
        emit(f"smoke/geot_pallas_gather_{red}_fused", t,
             "single_launch|plan_grid")
    wmean = jax.jit(lambda h: ops.index_weight_segment_reduce(
        h, src, w, dst, v, "mean", "pallas", None, plan))
    t = timeit(wmean, h, reps=3, warmup=1)
    emit("smoke/geot_pallas_gather_mean_weighted_fused", t, "single_launch")
    logits = jnp.asarray(rng.standard_normal((m, 4)).astype(np.float32))
    softmax = jax.jit(lambda e: ops.segment_softmax(
        e, dst, v, "pallas", None, plan))
    t = timeit(softmax, logits, reps=3, warmup=1)
    emit("smoke/geot_pallas_segment_softmax", t, "heads=4|single_launch")

    # -- mp_transform reordering on a widening layer (d_in < d_out) -------
    from repro.core.mp import choose_order, mp_transform
    d_in, d_out = 32, 256
    xw = jnp.asarray(rng.standard_normal((v, d_in), np.float32))
    wide_plan = make_plan(g.edge_index[1], v, feat=d_in, config=cfg)
    wmat = jnp.asarray(rng.standard_normal((d_in, d_out), np.float32)
                       / np.sqrt(d_in))
    ei = jnp.asarray(g.edge_index)
    picked = choose_order(d_in, d_out, plan=wide_plan)
    times = {}
    for order in ("aggregate_first", "transform_first"):
        fn = jax.jit(lambda x, order=order: mp_transform(
            x, wmat, ei, v, reduce="sum", impl="pallas", plan=wide_plan,
            order=order))
        # warmup=2: the first post-compile call still pays allocator warmup,
        # which would otherwise swamp the ~2x SpMM-width difference
        times[order] = timeit(fn, xw, reps=5, warmup=2)
    other = ("transform_first" if picked == "aggregate_first"
             else "aggregate_first")
    emit("smoke/mp_reorder/aggregate_first", times["aggregate_first"],
         f"d_in={d_in}_d_out={d_out}")
    emit("smoke/mp_reorder/transform_first", times["transform_first"],
         f"d_in={d_in}_d_out={d_out}")
    emit("smoke/mp_reorder/decision", 0.0,
         f"picked={picked}|picked_faster="
         f"{str(times[picked] < times[other]).lower()}|"
         f"speedup={times[other] / times[picked]:.2f}x")

    # -- precision (io dtype axis): bf16 halves the bandwidth-bound bytes -
    # XLA:CPU caveat: bf16 *compute* under the Pallas interpreter falls off
    # XLA's fast path (emulated via fp32 converts), so a full-op bf16
    # wall-clock on this container measures the emulation, not the kernel.
    # The measured pair therefore isolates the bandwidth-bound stage the io
    # dtype targets — the row gather is a pure memcpy, byte-for-byte the
    # code both dtypes run — and the full-op bf16 row carries the v5e
    # roofline projection in its derived column (the bench-wide convention:
    # wall-clock characterizes algorithms under XLA:CPU, `derived` carries
    # the analytical v5e numbers).
    mg, vg, fg = 120_000, 8192, 256
    gsrc = jnp.asarray(rng.integers(0, vg, mg).astype(np.int32))
    hg32 = jnp.asarray(rng.standard_normal((vg, fg), np.float32))
    hg16 = hg32.astype(jnp.bfloat16)
    gather_fn = jax.jit(lambda hh: jnp.take(hh, gsrc, axis=0))
    t_g32 = timeit(gather_fn, hg32, reps=5, warmup=2)
    t_g16 = timeit(gather_fn, hg16, reps=5, warmup=2)
    emit("smoke/precision/row_gather_fp32", t_g32,
         f"m={mg}|f={fg}|bandwidth_bound_stage")
    emit("smoke/precision/row_gather_bf16", t_g16,
         f"bf16_speedup={t_g32 / t_g16:.2f}x|gate>=1.2x")
    h16 = h.astype(jnp.bfloat16)
    full16 = jax.jit(lambda hh: ops.index_segment_reduce(
        hh, src, dst, v, "sum", "pallas", None, plan))
    t_full16 = timeit(full16, h16, reps=3, warmup=1)
    pr_cfg = KernelConfig("PR", 256, 128, 512, 32)
    c32 = costmodel.spmm_cost(200_000, 20_000, 256, pr_cfg,
                              dtype_bytes=4).total_s
    c16 = costmodel.spmm_cost(200_000, 20_000, 256, pr_cfg,
                              dtype_bytes=2).total_s
    emit("smoke/precision/gather_reduce_bf16", t_full16,
         f"v5e_model_speedup_vs_fp32={c32 / c16:.2f}x|"
         "wall_is_xla_cpu_bf16_emulation")

    # -- fully-fused SpMM+GEMM (one launch) vs the best two-launch order --
    # fp32 interpret wall-clock: the fused win here is *structural* — one
    # launch instead of two, no (S, d_in) aggregate or (E, d_out) edge
    # tensor in HBM, and no per-feature-tile re-walk of the edge index —
    # so the ratio survives the interpreter (and only widens on hardware,
    # where the saved HBM round-trip matters more).
    d_sq = 256
    sq_plan = make_plan(g.edge_index[1], v, feat=d_sq, config=cfg)
    xsq = jnp.asarray(rng.standard_normal((v, d_sq), np.float32))
    wsq = jnp.asarray(rng.standard_normal((d_sq, d_sq), np.float32)
                      / np.sqrt(d_sq))
    tfu = {}
    for order in ("aggregate_first", "transform_first", "fused"):
        fn = jax.jit(lambda x, order=order: mp_transform(
            x, wsq, ei, v, reduce="sum", impl="pallas", plan=sq_plan,
            order=order))
        tfu[order] = timeit(fn, xsq, reps=5, warmup=2)
    best2 = min(tfu["aggregate_first"], tfu["transform_first"])
    picked_f = choose_order(d_sq, d_sq, plan=sq_plan, allow_fused=True)
    emit("smoke/mp_fused/two_launch_best", best2,
         f"d_in={d_sq}|d_out={d_sq}|"
         f"order={'aggregate_first' if best2 == tfu['aggregate_first'] else 'transform_first'}")
    emit("smoke/mp_fused/fused_one_launch", tfu["fused"],
         f"fused_speedup={best2 / tfu['fused']:.2f}x|gate>=1.15x|"
         f"auto_picks={picked_f}")

    # -- heterogeneous: grouped segment_matmul vs per-type Python loop ----
    # FASTEN's argument at CI scale: R per-relation transforms as ONE
    # grouped launch (mp_typed) against the loop-over-types baseline
    # (R masked matmuls + an unfused scatter). Both compute the same
    # typed sum aggregation.
    from repro.core.mp import mp_typed
    from repro.data.graphs import synth_typed_graph
    num_rel = 8
    tg = synth_typed_graph("hetero", v, m, num_relations=num_rel, feat=f,
                           seed=3)
    xt = jnp.asarray(tg.x)
    ei_t = jnp.asarray(tg.edge_index)
    et_t = jnp.asarray(tg.edge_type)
    wrel = jnp.asarray(rng.standard_normal((num_rel, f, f))
                       .astype(np.float32) / np.sqrt(f))
    tplan = tg.make_plan(feat=f, config=cfg)
    rplan = tg.make_relation_plan(feat=f)
    tp = jnp.asarray(tg.type_perm)
    itp = jnp.asarray(tg.inv_type_perm)
    tc = jnp.asarray(tg.type_counts)
    grouped = jax.jit(lambda x: mp_typed(
        x, wrel, ei_t, et_t, tg.num_nodes, type_perm=tp, inv_type_perm=itp,
        type_counts=tc, reduce="sum", plan=tplan, rplan=rplan,
        impl="pallas"))
    idx_per_type = [np.where(tg.edge_type == r)[0]
                    for r in range(num_rel)]
    src_np, dst_np = tg.edge_index
    dst_j = jnp.asarray(dst_np)

    def per_type_loop(x):
        msg = jnp.zeros((tg.num_edges, f), x.dtype)
        for r, idx in enumerate(idx_per_type):
            msg = msg.at[idx].set(jnp.take(x, src_np[idx], axis=0) @ wrel[r])
        return jax.ops.segment_sum(msg, dst_j, tg.num_nodes,
                                   indices_are_sorted=True)

    loop_fn = jax.jit(per_type_loop)
    t_loop = timeit(loop_fn, xt, reps=3, warmup=1)
    t_grp = timeit(grouped, xt, reps=3, warmup=1)
    np.testing.assert_allclose(np.asarray(grouped(xt)),
                               np.asarray(loop_fn(xt)), rtol=2e-4,
                               atol=2e-4)
    emit("smoke/hetero/per_type_loop", t_loop,
         f"relations={num_rel}|launches={num_rel}")
    emit("smoke/hetero/grouped_segment_matmul", t_grp,
         f"single_launch|grid={rplan.max_groups}/"
         f"{rplan.worst_case_groups}|"
         f"loop_speedup={t_loop / t_grp:.2f}x")

    # -- serving engine: bucketed/cached GNN inference over a stream ------
    # deterministic random-shape stream through GNNServer (gcn, planned
    # pallas); throughput is gated (µs/request), the cache/compile row is
    # metadata. Warmup compiles are excluded from the timed section — the
    # row tracks the hot path the engine exists to protect.
    from repro.data.graphs import synth_graph
    from repro.models import gnn as gnn_models
    from repro.serve import BucketPolicy, GNNServer, bucket_for

    srv_rng = bench_rng(2)
    policy = BucketPolicy(min_nodes=64, min_edges=64)
    stream = [synth_graph(f"serve{i}", int(srv_rng.integers(48, 320)),
                          int(srv_rng.integers(96, 900)), feat=16, seed=i)
              for i in range(24)]
    params = gnn_models.init(jax.random.PRNGKey(0), "gcn", 16, 32, 8)
    ladder = sorted({bucket_for(v, e, policy) for v in (64, 128, 256, 512)
                     for e in (128, 256, 512, 1024, 2048, 4096)})
    server = GNNServer(params, "gcn", impl="pallas", policy=policy,
                       max_batch_nodes=512, max_batch_graphs=4,
                       cache_capacity=len(ladder) + 8)
    server.warmup(ladder)
    t0 = time.perf_counter()
    for g_s in stream:
        server.submit(g_s)
    server.run_until_drained()
    dt = time.perf_counter() - t0
    st = server.stats()
    emit("smoke/serving_throughput", dt * 1e6 / len(stream),
         f"requests={len(stream)}|batches={st['batches']}|"
         f"pad_edges=x{st['pad_edge_overhead']:.2f}")
    emit("smoke/serving_cache_hit", 0.0,
         f"hit_rate={st['cache']['hit_rate']:.2f}|"
         f"compiles={st['compiles']}|buckets={st['buckets']}|"
         f"serving_compiles={st['compiles'] - st['cache']['prefills']}")

    # -- observability overhead: instrumented vs disabled serving ---------
    # the same fully-warmed serving pass (every bucket a cache hit), timed
    # with repro.obs enabled and disabled, interleaved min-of-k so runner
    # noise hits both arms equally. The <3% bound is the subsystem's
    # overhead contract (docs/observability.md) — asserted here, so CI
    # fails loudly rather than drifting.
    from repro import obs as obs_mod

    def serve_pass():
        t0 = time.perf_counter()
        for g_s in stream:
            server.submit(g_s)
        server.run_until_drained()
        return time.perf_counter() - t0

    was_enabled = obs_mod.enabled()
    t_on, t_off = [], []
    try:
        obs_mod.enable()
        serve_pass()                  # discard: arm-switch warm pass
        for _ in range(4):
            obs_mod.enable()
            t_on.append(serve_pass())
            obs_mod.disable()
            t_off.append(serve_pass())
    finally:
        obs_mod.enable() if was_enabled else obs_mod.disable()
    overhead = min(t_on) / min(t_off) - 1.0
    assert overhead < 0.03, (
        f"observability overhead {overhead * 100:.2f}% breaks the <3% "
        "contract (docs/observability.md)")
    emit("smoke/obs_overhead", min(t_on) * 1e6 / len(stream),
         f"disabled={min(t_off) * 1e6 / len(stream):.0f}us|"
         f"overhead={overhead * 100:+.2f}%|gate<3%")

    # -- training: the cached hot train step (fwd + bwd + adamw) ----------
    # one Trainer on one shape bucket; fit() pays the single compile, then
    # the row times the cached executable — the steady-state per-step cost
    # the orchestration layer (repro.train) guarantees stays re-plan- and
    # retrace-free (traces is part of the derived column as the audit)
    from repro.optim import adamw as adamw_lib
    from repro.train import (GraphEpochProvider, NodeClassification,
                             Trainer, TrainerConfig)

    tr_data = GraphEpochProvider(shapes=((128, 512),), graphs_per_shape=1,
                                 feat=16, num_classes=8)
    tr_task = NodeClassification.from_provider(tr_data, model="gcn",
                                               hidden=32, impl="pallas")
    trainer = Trainer(tr_task, tr_data, TrainerConfig(
        steps=2, warmup_steps=1, opt=adamw_lib.AdamWConfig(lr=1e-2)))
    tr_res = trainer.fit()
    arrays, static = tr_task.prepare(tr_data.batch(0))
    step_exe = trainer._executable(static)
    t_step = timeit(lambda st: step_exe(st, arrays), tr_res.state,
                    reps=3, warmup=1)
    emit("smoke/train_step", t_step,
         f"fwd+bwd+adamw|traces={trainer.traces}|"
         f"buckets={len(trainer.buckets)}")

    # -- out-of-core sampled pipeline: throughput + prefetch overlap ------
    # sampler_throughput is the host cost of one produced batch (k-hop
    # sample -> bucket pad -> plan stamp -> H2D); prefetch_overlap is the
    # consumer-visible steady-state batch time with depth-2 prefetch, with
    # the blocking depth-0 loader's time in the derived column. The
    # consumer runs impl="ref" on purpose: these rows measure how much
    # host production the pipeline hides, not the kernels (those have
    # their own rows above).
    from repro.data.sampling import NeighborSampler

    big = synth_graph("ooc", 2048, 8192, feat=16, num_classes=8, seed=5)
    sparams = gnn_models.init(jax.random.PRNGKey(1), "gcn", 16, 32, 8)

    def sampled_loop(depth):
        sampler = NeighborSampler(big, fanouts=(8, 4), batch_size=32, seed=3)
        srv = GNNServer(sparams, "gcn", impl="ref", feat=32)
        times = []
        with srv.sampled_pipeline(sampler, depth=depth) as pipe:
            for step in range(14):
                t0 = time.perf_counter()
                b = pipe.batch(step)
                srv.serve_sampled(b)
                times.append(time.perf_counter() - t0)
            pstats = pipe.stats()
        # steady state: the first batches pay compiles + pipeline fill
        return float(np.median(times[4:])), pstats

    t_block, st_block = sampled_loop(0)
    t_pre, st_pre = sampled_loop(2)
    emit("smoke/sampler_throughput",
         st_block["produce_s_median_steady"] * 1e6,
         "batch=32|fanouts=8x4|sample+pad+stamp+h2d")
    emit("smoke/prefetch_overlap", t_pre * 1e6,
         f"depth2|blocking={t_block * 1e6:.0f}us|"
         f"speedup={t_block / t_pre:.2f}x|overlap={st_pre['overlap']:.2f}")

    # -- sharded message passing: 1 vs 4 host shards ----------------------
    # (needs >= 4 devices: main() forces the host device count before jax
    # initializes; locally run with XLA_FLAGS=--xla_force_host_platform_
    # device_count=8 to reproduce the committed rows)
    if len(jax.devices()) >= 4:
        from repro.core.dist_mp import make_shard_mesh, mp_sharded
        for shards in (1, 4):
            pg = g.partition(shards)
            pplan = pg.make_plan(feat=f, config=cfg)
            mesh = make_shard_mesh(shards)
            fn = jax.jit(lambda h, pg=pg, pplan=pplan, mesh=mesh: mp_sharded(
                h, pg, reduce="sum", pplan=pplan, mesh=mesh, impl="pallas"))
            t = timeit(fn, h, reps=3, warmup=1)
            emit(f"smoke/mp_sharded/shards{shards}", t,
                 f"cut={pg.halo.total_cut}"
                 f"|grid={pplan.max_chunks}|psum_merge")
    else:
        emit("smoke/mp_sharded/skipped", 0.0,
             f"devices={len(jax.devices())}<4")


def run_ablation(smoke: bool = True, perfdb_path=None):
    """Fig. 8 — selector ablation on the real (interpreted on CPU) kernel:

      tuned           — argmin of a measured autotuner sweep (PerfDB-cached)
      generated_rules — decision-tree config (``_generated_rules.py``)
      hand_crafted    — static engineering rule (``default_config``)

    All three timings come from the *same* sweep with the same median-of-k
    timer on the same seed-deterministic inputs; the sweep is seeded with
    both baseline configs, so the tuned row can never lose to them on a
    fresh measurement. Smoke mode caps the sweep at 8 configs so the CI
    gate job stays well under its timeout."""
    from repro.core import autotune

    db = autotune.PerfDB(perfdb_path)
    cases = ([("cora", 0.25, 8), ("cora", 0.25, 32)] if smoke
             else [(n, 1.0, f) for n in DATASETS[:4] for f in (16, 64)])
    max_configs = 8 if smoke else 24
    reps, warmup = (3, 1) if smoke else (5, 2)

    rules_ratios, hand_ratios = [], []
    fresh_timings = 0
    for name, scale, f in cases:
        g = dataset(name, feat=1, scale=scale)
        m, v = g.num_edges, g.num_nodes
        cfg_rules = select_config(m, v, f, tune=False)
        cfg_hand = hand_crafted_config(m, v, f)
        res = autotune.tune(op="segment_reduce", idx_size=m, num_segments=v,
                            feat=f, db=db, max_configs=max_configs,
                            reps=reps, warmup=warmup)
        if res.time_of(cfg_rules) is None or res.time_of(cfg_hand) is None:
            # stale cache entry from an older lattice: re-sweep
            res = autotune.tune(op="segment_reduce", idx_size=m,
                                num_segments=v, feat=f, db=db,
                                max_configs=max_configs, reps=reps,
                                warmup=warmup, force=True,
                                extra_configs=(cfg_rules, cfg_hand))
        fresh_timings += res.timings_performed
        t_tuned = res.time_of(res.config)
        t_rules = res.time_of(cfg_rules)
        t_hand = res.time_of(cfg_hand)
        rules_ratios.append(t_rules / t_tuned)
        hand_ratios.append(t_hand / t_tuned)
        tag = "hit" if res.cache_hit else "miss"
        emit(f"fig8/{name}/F{f}/tuned", t_tuned,
             f"cfg={res.config.astuple()}|cache={tag}")
        emit(f"fig8/{name}/F{f}/generated_rules", t_rules,
             f"{t_rules / t_tuned:.2f}x_of_tuned|cfg={cfg_rules.astuple()}")
        emit(f"fig8/{name}/F{f}/hand_crafted", t_hand,
             f"{t_hand / t_tuned:.2f}x_of_tuned|cfg={cfg_hand.astuple()}")
    # us=0 rows are metadata: the CI gate only compares positive timings
    emit("fig8/geomean_rules_over_tuned", 0.0,
         f"{geomean(rules_ratios):.3f}x")
    emit("fig8/geomean_hand_over_tuned", 0.0,
         f"{geomean(hand_ratios):.3f}x")
    emit("fig8/fresh_timings", 0.0,
         f"timings={fresh_timings}|"
         f"{'warm_perfdb' if fresh_timings == 0 else 'cold_perfdb'}")


def main():
    # pin the host device count ahead of backend initialization so the
    # smoke run can time the 4-shard mp_sharded path (no-op when the flag
    # is already set or jax devices were already touched). Smoke mode only:
    # the fig8 ablation's autotuner sweeps feed the persistent PerfDB,
    # which must be measured under the normal single-device environment.
    import os
    import sys
    if "--smoke" in sys.argv and \
            "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run; implies --json BENCH_segment_reduce.json")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ablation", action="store_true",
                    help="add the Fig. 8 selector ablation "
                         "(tuned / generated-rules / hand-crafted)")
    ap.add_argument("--ablation-smoke", action="store_true",
                    help="CI-sized ablation sweep *without* --smoke — keeps "
                         "the process single-device so the autotuner's "
                         "PerfDB measurements stay environment-consistent")
    ap.add_argument("--perfdb", default=None,
                    help="PerfDB path for --ablation (default: "
                         "REPRO_PERFDB_PATH or ~/.cache/repro-perfdb)")
    ap.add_argument("--json", default=None,
                    help="write emitted rows to this JSON artifact")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    elif not (args.ablation and args.ablation_smoke):
        run(quick=args.quick)
    if args.ablation:
        run_ablation(smoke=args.smoke or args.ablation_smoke,
                     perfdb_path=args.perfdb)
    json_path = args.json or ("BENCH_segment_reduce.json" if args.smoke
                              else "BENCH_ablation.json" if args.ablation
                              else None)
    if json_path:
        write_json(json_path, bench="segment_reduce",
                   mode="smoke" if args.smoke else "full")


if __name__ == "__main__":
    main()
