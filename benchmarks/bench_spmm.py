"""Fig. 7 — SpMM (fused message+aggregate) vs sparse baselines.

Baselines:
  bcoo      — jax.experimental.sparse BCOO @ dense (cuSPARSE analogue)
  unfused   — gather → weight → sorted segment_sum (Listing 2 upper path)
  geot      — index_weight_segment_reduce, blocked, tree config (ours, §IV)

derived: speedup_vs_bcoo | v5e cost-model GFlops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

from benchmarks.common import emit, geomean, timeit
from repro.core import costmodel, ops
from repro.core.heuristics import select_config
from repro.data.graphs import dataset

DATASETS = ["citeseer", "cora", "ppi", "pubmed", "amazon-photo", "flickr"]
FEATS = [16, 32, 64, 128]


def run(quick: bool = False):
    datasets = DATASETS[:4] if quick else DATASETS
    feats = [16, 64] if quick else FEATS
    rng = np.random.default_rng(0)
    speedups = []
    for name in datasets:
        g = dataset(name, feat=1)
        src = jnp.asarray(g.edge_index[0])
        dst = jnp.asarray(g.edge_index[1])
        m, v = g.num_edges, g.num_nodes
        w = jnp.asarray(rng.standard_normal(m).astype(np.float32))
        coo = jsparse.BCOO(
            (w, jnp.stack([dst, src], axis=1)), shape=(v, v))

        for f in feats:
            h = jnp.asarray(rng.standard_normal((v, f), np.float32))
            bcoo_mm = jax.jit(lambda h: coo @ h)
            unfused = jax.jit(lambda h: jax.ops.segment_sum(
                jnp.take(h, src, axis=0) * w[:, None], dst, v,
                indices_are_sorted=True))
            cfg = select_config(m, v, f)
            from repro.core.config_space import KernelConfig
            cfg_cpu = KernelConfig("SR", cfg.s_b, cfg.n_b, cfg.m_b, 1)
            geot = jax.jit(lambda h: ops.index_weight_segment_reduce(
                h, src, w, dst, v, impl="blocked", config=cfg_cpu))

            t_bcoo = timeit(bcoo_mm, h, reps=3)
            t_unf = timeit(unfused, h, reps=3)
            t_geot = timeit(geot, h, reps=3)
            cost = costmodel.spmm_cost(m, v, f, cfg)
            gflops = cost.gflops(2.0 * costmodel.useful_flops(m, f))
            sp = t_bcoo / t_geot
            speedups.append(sp)
            emit(f"fig7/{name}/F{f}/bcoo", t_bcoo, "1.00x")
            emit(f"fig7/{name}/F{f}/unfused", t_unf,
                 f"{t_bcoo / t_unf:.2f}x")
            emit(f"fig7/{name}/F{f}/geot_fused", t_geot,
                 f"{sp:.2f}x|v5e_model={gflops:.1f}GFLOPs")
    emit("fig7/geomean_speedup_vs_bcoo", 0.0, f"{geomean(speedups):.2f}x")


if __name__ == "__main__":
    run()
