"""Fig. 8 — decision-tree rules vs hand-crafted rules vs exhaustive best.

For each (dataset × F): v5e cost-model GFlops of the config chosen by
  hand  — static engineering rule (paper's Fig. 8 baseline)
  tree  — the codegen'd decision-tree rules (ours)
  best  — exhaustive sweep of the pruned space (oracle upper bound)

The paper's claim: tree ≈ best ≫ hand. Also measures rule-selection
latency (must be ~ns-scale: if/else only).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, geomean
from repro.core import costmodel
from repro.core.config_space import all_configs
from repro.core.heuristics import hand_crafted_config, select_config
from repro.core.perfdb import TABLE_II

FEATS = [1, 4, 16, 32, 64, 128]


def _gflops(m, v, f, cfg):
    return costmodel.segment_reduce_cost(m, v, f, cfg).gflops(
        costmodel.useful_flops(m, f))


def run(quick: bool = False):
    table = TABLE_II[:4] if quick else TABLE_II
    feats = [1, 32] if quick else FEATS
    ratios_tree, ratios_hand = [], []
    for name, v, m in table:
        for f in feats:
            best = max(_gflops(m, v, f, c) for c in all_configs(f))
            tree = _gflops(m, v, f, select_config(m, v, f))
            hand = _gflops(m, v, f, hand_crafted_config(m, v, f))
            ratios_tree.append(tree / best)
            ratios_hand.append(hand / best)
            emit(f"fig8/{name}/F{f}", 0.0,
                 f"tree={tree:.1f}|hand={hand:.1f}|best={best:.1f}GFLOPs")
    emit("fig8/tree_vs_best_geomean", 0.0, f"{geomean(ratios_tree):.3f}")
    emit("fig8/hand_vs_best_geomean", 0.0, f"{geomean(ratios_hand):.3f}")

    # rule-selection overhead (paper: nanoseconds — pure if/else dispatch)
    n = 20000
    t0 = time.perf_counter()
    for i in range(n):
        select_config(1_000_000 + i, 100_000, 32)
    dt = (time.perf_counter() - t0) / n
    emit("fig8/rule_selection_overhead", dt * 1e6, f"{dt*1e9:.0f}ns/call")


if __name__ == "__main__":
    run()
