"""Benchmark utilities: wall-clock timing of jit'd callables + CSV rows.

This container is CPU-only, so wall-clock numbers characterise the
*algorithms* under XLA:CPU; the `derived` column carries the analytical
v5e numbers (cost model / speedups) that transfer to the target hardware.
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def timeit(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall-clock µs of a jit'd callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def geomean(xs):
    import numpy as np
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
