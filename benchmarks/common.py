"""Benchmark utilities: wall-clock timing of jit'd callables + CSV rows.

This container is CPU-only, so wall-clock numbers characterise the
*algorithms* under XLA:CPU; the `derived` column carries the analytical
v5e numbers (cost model / speedups) that transfer to the target hardware.
"""
from __future__ import annotations

import json
import platform
import time
from typing import Callable, Dict, List

import jax

ROWS: List[str] = []
RECORDS: List[Dict] = []


def bench_rng(seed: int = 0):
    """Deterministic RNG for synthetic benchmark inputs.

    De-flake guard: CI gates fresh runs against a committed baseline
    (scripts/check_bench.py), so inputs must be bit-identical run-to-run —
    every benchmark draws through here with a pinned seed."""
    import numpy as np
    return np.random.default_rng(seed)


def timeit(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median-of-``reps`` wall-clock µs of a jit'd callable (warmup runs
    absorb compilation; the median — not min/mean — is what the CI
    regression gate compares, being robust to scheduler spikes)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us": round(us, 1), "derived": derived})
    print(row, flush=True)


def write_json(path: str, **meta) -> None:
    """Dump every emitted row (plus environment metadata) as a benchmark
    artifact — CI uploads these so the perf trajectory accumulates per PR."""
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "python": platform.python_version(),
            "machine": platform.machine(),
            **meta,
        },
        "rows": RECORDS,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {path} ({len(RECORDS)} rows)", flush=True)


def geomean(xs):
    import numpy as np
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
